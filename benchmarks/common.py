"""Shared benchmark plumbing. Output convention (run.py):
``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def measure_memcpy_bw(nbytes: int = 1 << 26) -> float:
    """Host memcpy bandwidth (bytes/s) — anchors the fabric calibration."""
    import numpy as np
    src = np.random.default_rng(0).integers(0, 255, nbytes, dtype=np.uint8)
    dst = np.empty_like(src)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        dst[:] = src
        best = min(best, time.perf_counter() - t0)
    return nbytes / best


def calibrated_fabric():
    """Fabric with constants tied to this host's memcpy bandwidth so the
    paper's hardware-class ratios hold: Mercury-RPC effective payload path
    ≈ 0.43× memcpy bw; RDMA READ ≈ 1.65× memcpy bw (on the paper's IB
    cluster: ~7 GB/s memcpy, ~3 GB/s RPC payload, ~11.5 GB/s RDMA)."""
    from repro.core import Fabric, FabricConfig
    bw = measure_memcpy_bw()
    return Fabric(FabricConfig(rpc_bw=0.43 * bw, rdma_bw=1.65 * bw))


def timeit(fn, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
