"""Paper Fig. 2 + the cluster dataplane axis + the qos contention axis.

Fig. 2: data transport duration, Thallus vs Thallium RPC, across
column-selectivity (result-set size). Expect up to ~5.5× and a gain that
shrinks as the result set shrinks (constant RDMA setup costs dominate).

Cluster axis (streams × pool): the same bytes pulled through
``repro.cluster`` — 1 stream vs N sharded streams, registered buffer pool
off vs on. Every cluster row is decomposed from the same
:class:`ClusterStats` path: ``us_per_call`` is the modeled critical path
(slowest stream), and ``derived`` carries the measured ``alloc_us`` and the
modeled registration cost the pool amortizes.

Contention axis (clients × quota): N clients in two classes (interactive vs
batch) submit through the ``repro.qos`` gateway against the same 4-shard
cluster, with QoS on (weighted-fair queue + admission quotas + token-bucket
lease metering) vs off (FIFO, unlimited). ``us_per_call`` is the class's
modeled p50 grant latency; ``derived`` carries the full ``QosStats``
summary (queue depth, shed count, per-class throughput). The acceptance
check: with quotas enabled, the interactive class's p50 grant latency drops
under the same heavy-client load.

Sched axes (the ``repro.sched`` adaptive scheduler) and the distributed
admission axis, all self-asserting so CI smoke runs double as acceptance
checks:

* ``--scenario straggler`` — one 4×-slow replica in a 4-replica scan, work
  stealing off vs on. Asserts stealing cuts the modeled critical path by
  ≥ 1.5× (the straggler's remaining range migrates to idle fast replicas).
* ``--scenario sharing`` — N=4 identical queued queries, shared tickets off
  vs on. Asserts the coalesced run costs < 2× ONE query's server-side work
  (one fan-out executes; three subscribers are served by multicast).
* ``--scenario admission`` — centralized ``AdmissionController`` vs
  ``qos.ShardedAdmission`` (one quota shard per server). Asserts the
  N-shard interactive p50 grant latency stays within 1.5× of the
  centralized controller's, the 1-shard run matches it (drop-in), and a
  seeded acquire/release storm with borrowing + reconciles never admits
  past the global per-client quota or cluster-wide cap.
* ``--scenario flap`` — steal hysteresis vs the static threshold under a
  flapping replica (RDMA rate oscillating 4×↔1× every lease round) and a
  straggler that degrades persistently across two scans. Asserts
  history-aware stealing beats no-history by ≥ 1.3× on the repeat
  straggler's scan-2 modeled makespan with ≤ 1 wasted steal, and that a
  thief whose admission shard is at its local quota declines the stolen
  range (never over-admits) until a freed-slot event reopens the shard.
* ``--scenario slo`` — the health/SLO/postmortem loop end to end: burn-rate
  objectives calibrated on a clean fleet, then the straggler+flapper fabric
  degradation from the flap scenario, heartbeat by heartbeat in modeled
  time, with a low-rate interactive side-load riding along. Asserts the
  clean phase fires ZERO alerts, the degradation pages within
  ``SLO_HEARTBEAT_BUDGET`` heartbeats, the flight-recorder postmortem
  bundle it dumps carries the causal ``steal`` / ``steal.decline`` events,
  and the health monitor's quarantine verdicts agree with the
  ``RateHistory``'s, server by server.
* ``--scenario stress`` — the stress workload driver
  (``repro.obs.workload``): a seeded four-population mix (interactive
  lookups / batch analytics / a scan-storm burst / an adversarial
  quota-squatter) submitted through one gateway on one modeled clock,
  per-population ``workload.*`` telemetry plus Jain-fairness and
  latency-inflation gauges judged by per-population burn-rate objectives.
  Asserts the calibrated mix fires ZERO alerts across its clean
  heartbeats, the injected scan-storm overload pages within
  ``STRESS_HEARTBEAT_BUDGET`` beats, the dumped postmortem bundle carries
  the causal ``qos.shed`` / ``qos.backpressure`` events, and the Jain
  index drops under overload.

``--side-load`` additionally rides the contention/flap/slo scenarios with
background ``SideWorkload`` traffic (off by default — the measured
geometries stay exactly as calibrated without it).

Every judged number routes through the continuous-baselining layer
(``repro.obs``): called directly the scenarios self-assert on the constants
above; driven by ``main()`` the constants are bootstrap floors/ceilings and
the verdict comes from the rolling baseline envelope over the run
trajectory. ``--scenario all`` runs every axis, reports a combined pass/fail
summary on stderr and exits nonzero if ANY scenario failed; ``--json DIR``
appends each scenario's ``BENCH_<scenario>.json`` run record and the
``trajectory.jsonl`` line that feeds ``python -m repro.obs.baseline DIR``.

Runnable standalone::

    PYTHONPATH=src python benchmarks/transport_bench.py --scenario straggler
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):          # `python benchmarks/transport_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import Row, calibrated_fabric
else:
    from .common import Row, calibrated_fabric

from repro.cluster import (BufferPool, ClusterCoordinator, FaultSpec,
                           MembershipController, MultiStreamPuller, Nemesis,
                           RepairConfig, ShardRepairer, cluster_scan)
from repro.core import (Fabric, FabricConfig, FlappingFabric, RpcClient,
                        ThallusClient, ThallusServer)
from repro.engine import Engine, make_numeric_table
from repro.qos import (AdmissionConfig, AdmissionController, Backpressure,
                       ClientClass, DistributedConfig, ScanGateway,
                       ScanRequest, ShardedAdmission)
from repro.sched import (AdaptiveScheduler, RateHistory, StealConfig,
                         StealingPuller, TicketTable)
from repro.obs import (QUARANTINED, ClientPopulation, FlightRecorder,
                       HealthMonitor, InteractiveSideLoad, MetricPolicy,
                       MetricsRegistry, PopulationSideWorkload, RunRecord,
                       SloEngine, SloObjective, StressDriver, Tracer,
                       append_run, current_git_sha, detect_events,
                       load_trajectory, population_classes, record_cluster,
                       record_health, record_repair)

TOTAL_COLS = 8
CLUSTER_ROWS = 1 << 20
CLUSTER_BATCH_ROWS = 1 << 15
CONTENTION_ROWS = 1 << 18
CONTENTION_BATCH_ROWS = 1 << 14
CONTENTION_SHARDS = 4
STRAGGLER_REPLICAS = 4
STRAGGLER_SLOWDOWN = 4.0
SHARING_QUERIES = 4


# --------------------------------------------------------------------------
# Continuous baselining: every judged number routes through _metric(). Called
# directly (tests, `from benchmarks import transport_bench; run_flap()`) the
# scenario functions keep their legacy self-asserting contract — the hand-
# tuned constant fails immediately. Driven by main(), the constant is only a
# bootstrap floor/ceiling: the verdict comes from the rolling baseline
# envelope (median ± 3·MAD over the trajectory) once the scenario has
# MIN_RUNS of history, and the run record is appended to the trajectory
# with --json DIR. Metrics with no constant at all are envelope-only —
# pure drift detectors with nothing to hand-tune.

_RUN = None          # the active ScenarioRun while main() drives a scenario


class ScenarioRun:
    """One scenario's judged metrics + the policies that judge them."""

    def __init__(self, scenario: str, out_dir: str | None = None,
                 config: dict | None = None):
        self.scenario = scenario
        self.out_dir = out_dir
        self.config = config or {}
        self.metrics: dict[str, float] = {}
        self.policies: dict[str, MetricPolicy] = {}

    def add(self, name: str, value: float, policy: MetricPolicy) -> None:
        self.metrics[name] = float(value)
        self.policies[name] = policy

    def finalize(self):
        """Judge this run against the trajectory; persist when --json.
        Returns ``(record, events)``."""
        import datetime
        record = RunRecord(
            scenario=self.scenario, metrics=dict(self.metrics),
            policies={n: p.to_dict() for n, p in self.policies.items()},
            git_sha=current_git_sha(), config=dict(self.config),
            timestamp=datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds"))
        history = (load_trajectory(self.out_dir, self.scenario)
                   if self.out_dir else [])
        events = detect_events(record, history, self.policies)
        if self.out_dir:
            append_run(self.out_dir, record)
        return record, events


def _metric(name: str, value: float, *, floor: float | None = None,
            ceiling: float | None = None, better: str | None = None,
            rel_slack: float = 0.10, detail: str = "") -> None:
    """Register a judged benchmark metric.

    Under main()'s ScenarioRun the verdict is deferred to finalize() —
    bootstrap floor/ceiling plus the rolling-baseline envelope. Called
    directly, the constants assert immediately (legacy behavior), and
    envelope-only metrics (no floor, no ceiling) pass through unjudged.
    """
    if better is None:
        better = "higher" if floor is not None else "lower"
    if _RUN is not None:
        _RUN.add(name, value, MetricPolicy(
            name, better=better, floor=floor, ceiling=ceiling,
            rel_slack=rel_slack))
        return
    suffix = f" — {detail}" if detail else ""
    if floor is not None:
        assert value >= floor, (
            f"{name} = {value:.3g} below acceptance floor {floor:g}{suffix}")
    if ceiling is not None:
        assert value <= ceiling, (
            f"{name} = {value:.3g} above acceptance ceiling "
            f"{ceiling:g}{suffix}")


def _server(nrows: int) -> ThallusServer:
    eng = Engine()
    eng.register("/d", make_numeric_table("t", nrows, TOTAL_COLS,
                                          batch_rows=min(nrows, 1 << 18)))
    return ThallusServer(eng, calibrated_fabric())


def run(transport: str = "both") -> list[Row]:
    rows: list[Row] = []
    # -- column-selectivity sweep at a large result set (Fig 2 shape) -------
    for nrows, tag in ((1 << 20, "1M"), (1 << 14, "16k"), (1 << 10, "1k")):
        server = _server(nrows)
        for ncols in (1, 2, 4, 8):
            sql = "SELECT " + ", ".join(f"c{i}" for i in range(ncols)) + " FROM t"

            def med(cls):
                ts = []
                for _ in range(3):
                    c = cls(server)
                    c.run_query(sql, "/d")
                    ts.append(c.transport_seconds())
                return sorted(ts)[1]

            if transport != "both":   # single-transport run: no speedup col
                cls = RpcClient if transport == "rpc" else ThallusClient
                rows.append(Row(f"transport_rows{tag}_cols{ncols}",
                                med(cls) * 1e6, f"transport={transport}"))
                continue
            t_rpc, t_th = med(RpcClient), med(ThallusClient)
            if tag == "1M" and ncols == TOTAL_COLS:
                # host-measured: wide slack, envelope-only (no constant)
                _metric("fig2_speedup_rows1M_cols8", t_rpc / t_th,
                        better="higher", rel_slack=0.35)
            rows.append(Row(
                f"transport_rows{tag}_cols{ncols}", t_th * 1e6,
                f"speedup={t_rpc / t_th:.2f}x rpc_us={t_rpc*1e6:.1f}"))
    if transport != "rpc":
        rows.extend(run_cluster())
    return rows


def run_cluster() -> list[Row]:
    """Streams × pool sweep over the same total bytes (sharded table)."""
    base_cfg = calibrated_fabric().config
    table = make_numeric_table("t", CLUSTER_ROWS, TOTAL_COLS,
                               batch_rows=CLUSTER_BATCH_ROWS)
    sql = "SELECT " + ", ".join(f"c{i}" for i in range(TOTAL_COLS)) + " FROM t"
    rows: list[Row] = []
    crit: dict[tuple[int, bool], float] = {}
    for streams, pooled in ((1, False), (4, False), (4, True), (8, True)):
        coordinator = ClusterCoordinator()
        for i in range(streams):
            coordinator.add_server(f"s{i}", ThallusServer(Engine(),
                                                          Fabric(base_cfg)))
        coordinator.place_shards("/d", table)
        pool = (BufferPool(coordinator.server("s0").fabric)
                if pooled else None)
        stats = cluster_scan(coordinator, sql, "/d", pool=pool)
        derived = (f"streams={streams} pool={'on' if pooled else 'off'} "
                   f"batches={stats.batches} "
                   f"bytes={stats.bytes} "
                   f"alloc_us={stats.alloc_s*1e6:.1f} "
                   f"reg_us={stats.modeled_register_s*1e6:.1f} "
                   f"wire_us={stats.modeled_wire_s*1e6:.1f} "
                   f"work_us={stats.sum_total_s*1e6:.1f}")
        if pool is not None:
            derived += f" pool_hit={pool.stats.hit_rate:.2f}"
        crit[(streams, pooled)] = stats.modeled_critical_path_s
        rows.append(Row(f"cluster_streams{streams}_pool{int(pooled)}",
                        stats.critical_path_s * 1e6, derived))
    _metric("cluster_pool_speedup_streams4",
            crit[(4, False)] / crit[(4, True)],
            better="higher", rel_slack=0.25)
    return rows


HEAVY_SQL = ("SELECT " + ", ".join(f"c{i}" for i in range(TOTAL_COLS))
             + " FROM t")
LIGHT_SQL = "SELECT c0 FROM t"


def _contention_gateway(fabric_cfg, table, admission,
                        fair: bool = True) -> ScanGateway:
    """The shared contention fixture: a CONTENTION_SHARDS-way shard cluster
    behind a two-class gateway — run_contention and run_admission must
    benchmark the SAME workload, so both build it here."""
    coordinator = ClusterCoordinator()
    for i in range(CONTENTION_SHARDS):
        coordinator.add_server(f"s{i}", ThallusServer(Engine(),
                                                      Fabric(fabric_cfg)))
    coordinator.place_shards("/d", table)
    return ScanGateway(
        coordinator,
        classes=[ClientClass("interactive", 4.0), ClientClass("batch", 1.0)],
        admission=admission, fair=fair)


def _submit_contention_mix(gateway: ScanGateway,
                           ui_deadline_s: float | None = None) -> None:
    """The contention shape: a heavy client floods first, interactive
    lookups arrive behind it."""
    for _ in range(4):
        gateway.submit(ScanRequest("heavy", "batch", HEAVY_SQL, "/d",
                                   cost_hint=8.0))
    for _ in range(6):
        gateway.submit(ScanRequest("ui", "interactive", LIGHT_SQL, "/d",
                                   cost_hint=1.0, deadline_s=ui_deadline_s))


def run_contention(side_load: bool = False) -> list[Row]:
    """Clients × quota axis: heavy batch scans vs interactive lookups
    through the qos gateway, QoS off (FIFO, unlimited) vs on (WFQ + quota +
    token bucket). Deterministic: all latencies are modeled. With
    ``side_load``, an ``InteractiveSideLoad`` rides each drain (shard
    placement: no fan-out hint)."""
    base_cfg = calibrated_fabric().config
    table = make_numeric_table("t", CONTENTION_ROWS, TOTAL_COLS,
                               batch_rows=CONTENTION_BATCH_ROWS)
    rows: list[Row] = []
    p50: dict[bool, float] = {}
    for quotas in (False, True):
        admission = AdmissionController(AdmissionConfig(
            max_streams_per_client=2, lease_rate_per_s=1e3,
            lease_burst=4)) if quotas else None
        gateway = _contention_gateway(base_cfg, table, admission,
                                      fair=quotas)
        # ...and a late burst with a deadline so tight it must be shed
        # under any ordering (the shed counter's fixture)
        _submit_contention_mix(gateway, ui_deadline_s=50e-3)
        if side_load:
            InteractiveSideLoad(LIGHT_SQL, "/d",
                                num_streams=None).submit(gateway)
        for _ in range(2):
            gateway.submit(ScanRequest("burst", "batch", HEAVY_SQL, "/d",
                                       cost_hint=8.0, deadline_s=1e-6))
        gateway.run()
        qos = gateway.stats
        p50[quotas] = qos.klass("interactive").p50_grant_latency_s
        for klass in sorted(qos.classes):
            c = qos.classes[klass]
            rows.append(Row(
                f"contention_quotas{int(quotas)}_{klass}",
                c.p50_grant_latency_s * 1e6,
                f"clients=3 quotas={'on' if quotas else 'off'} "
                f"granted={c.granted}/{c.submitted} shed={c.shed} "
                f"tput_MBps={c.throughput_bytes_per_s / 1e6:.1f} | "
                + qos.summary()))
    if p50[False] > 0:
        # the acceptance story: WFQ + quotas must keep cutting interactive
        # p50 grant latency vs FIFO — envelope-only, no hand-tuned constant
        _metric("contention_interactive_p50_ratio", p50[True] / p50[False],
                better="lower", rel_slack=0.25)
    return rows


def run_straggler() -> list[Row]:
    """One slow replica × stealing on/off. Self-asserting: stealing must
    recover ≥ 1.5× of the modeled critical path the straggler costs."""
    base_cfg = calibrated_fabric().config
    table = make_numeric_table("t", CLUSTER_ROWS, TOTAL_COLS,
                               batch_rows=CLUSTER_BATCH_ROWS)
    sql = "SELECT " + ", ".join(f"c{i}" for i in range(TOTAL_COLS)) + " FROM t"

    def make_coord() -> ClusterCoordinator:
        coord = ClusterCoordinator()
        for i in range(STRAGGLER_REPLICAS):
            cfg = base_cfg
            if i == STRAGGLER_REPLICAS - 1:     # the straggler
                cfg = FabricConfig(
                    rpc_bw=base_cfg.rpc_bw / STRAGGLER_SLOWDOWN,
                    rdma_bw=base_cfg.rdma_bw / STRAGGLER_SLOWDOWN)
            coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric(cfg)))
        coord.place_replicas("/d", table)
        return coord

    rows: list[Row] = []
    critical: dict[bool, float] = {}
    for stealing in (False, True):
        coord = make_coord()
        plan = coord.plan(sql, "/d")
        scheduler = AdaptiveScheduler(steal=StealConfig())
        puller = (scheduler.make_puller(coord, plan) if stealing
                  else MultiStreamPuller(coord, plan,
                                         schedule="first_ready"))
        stats = puller.run()
        critical[stealing] = stats.modeled_critical_path_s
        rows.append(Row(
            f"straggler_steal{int(stealing)}",
            stats.modeled_critical_path_s * 1e6,
            f"replicas={STRAGGLER_REPLICAS} "
            f"slowdown={STRAGGLER_SLOWDOWN:g}x steals={stats.steals} "
            f"streams={len(stats.streams)} batches={stats.batches} "
            f"work_us={stats.sum_total_s * 1e6:.1f}"))
    speedup = critical[False] / critical[True]
    rows.append(Row("straggler_speedup", speedup,
                    f"modeled critical path, stealing off/on; "
                    f"bootstrap floor 1.5, then baseline envelope"))
    _metric("straggler_speedup", speedup, floor=1.5,
            detail="work stealing vs the straggler's critical path")
    return rows


def run_sharing() -> list[Row]:
    """N identical queued queries × shared tickets on/off. Self-asserting:
    with tickets, N queries must cost < 2× one query's server-side work."""
    base_cfg = calibrated_fabric().config
    table = make_numeric_table("t", CONTENTION_ROWS, TOTAL_COLS,
                               batch_rows=CONTENTION_BATCH_ROWS)
    sql = "SELECT " + ", ".join(f"c{i}" for i in range(TOTAL_COLS)) + " FROM t"

    def server_side_work(tickets: bool) -> tuple[float, Row]:
        coord = ClusterCoordinator()
        for i in range(CONTENTION_SHARDS):
            coord.add_server(f"s{i}", ThallusServer(Engine(),
                                                    Fabric(base_cfg)))
        coord.place_shards("/d", table)
        scheduler = (AdaptiveScheduler(tickets=TicketTable())
                     if tickets else None)
        gateway = ScanGateway(coord, scheduler=scheduler)
        for i in range(SHARING_QUERIES):
            gateway.submit(ScanRequest(f"c{i}", "interactive", sql, "/d"))
        gateway.run()
        qos = gateway.stats
        # server-side work: modeled wire time summed over every fan-out
        # that actually executed (multicast hits run none)
        work = sum(c.modeled_wire_s for c in qos.cluster)
        row = Row(
            f"sharing_tickets{int(tickets)}", work * 1e6,
            f"queries={SHARING_QUERIES} fanouts={len(qos.cluster)} "
            f"ticket_hits={qos.ticket_hits} granted={qos.granted} "
            f"delivered_bytes={qos.bytes}")
        assert qos.granted == SHARING_QUERIES
        return work, row

    work_off, row_off = server_side_work(False)
    work_on, row_on = server_side_work(True)
    one_query = work_off / SHARING_QUERIES
    ratio = work_on / one_query
    rows = [row_off, row_on,
            Row("sharing_work_ratio", ratio,
                f"N={SHARING_QUERIES} identical queries vs 1 query's "
                f"server-side work; bootstrap ceiling 2, then envelope")]
    _metric("sharing_work_ratio", ratio, ceiling=2.0,
            detail="shared tickets vs one query's server-side work")
    return rows


def run_admission() -> list[Row]:
    """Centralized vs sharded admission, self-asserting twice over.

    1. *Latency*: the contention workload (heavy batch floods, interactive
       lookups behind it) runs through the gateway three times — centralized
       ``AdmissionController``, 1-shard ``ShardedAdmission`` (the drop-in
       deployment; its byte-for-byte replay equivalence is proven
       deterministically in ``tests/test_admission_dist.py``), and one
       shard per server. Both sharded runs must keep the interactive p50
       grant latency within 1.5× of the centralized controller's
       (per-server token buckets grant concurrently, so N shards are
       usually at parity or *faster*; the bound guards borrow and
       reconcile overhead). The fabric is slowed 500× so modeled service
       time dwarfs the measured alloc/assembly noise in each stream clock.
    2. *Safety*: a seeded acquire/release storm across the shards, with
       borrowing on and periodic reconciles, must never over-admit — peak
       concurrent streams per client ≤ the global quota, cluster-wide peak
       ≤ the global cap.
    """
    base_cfg = calibrated_fabric().config
    slow_cfg = FabricConfig(rpc_bw=base_cfg.rpc_bw / 500,
                            rdma_bw=base_cfg.rdma_bw / 500)
    table = make_numeric_table("t", CONTENTION_ROWS, TOTAL_COLS,
                               batch_rows=CONTENTION_BATCH_ROWS)
    admission_cfg = AdmissionConfig(max_streams_per_client=2,
                                    max_streams_total=8,
                                    lease_rate_per_s=1e3, lease_burst=4)

    def p50(num_shards: int | None) -> tuple[float, Row]:
        if num_shards is None:
            admission = AdmissionController(admission_cfg)
        else:
            admission = ShardedAdmission(
                admission_cfg, [f"s{i}" for i in range(num_shards)])
        gateway = _contention_gateway(slow_cfg, table, admission)
        _submit_contention_mix(gateway)
        gateway.run()
        c = gateway.stats.klass("interactive")
        assert c.granted == 6
        tag = "central" if num_shards is None else f"shards{num_shards}"
        return c.p50_grant_latency_s, Row(
            f"admission_{tag}", c.p50_grant_latency_s * 1e6,
            f"granted={gateway.stats.granted}/{gateway.stats.submitted} "
            f"throttle_us={gateway.stats.throttle_wait_s * 1e6:.1f} | "
            + gateway.stats.summary())

    central, row_central = p50(None)
    one_shard, row_one = p50(1)
    sharded, row_n = p50(CONTENTION_SHARDS)
    rows = [row_central, row_one, row_n]
    # the byte-for-byte 1-shard equivalence is proven deterministically in
    # tests/test_admission_dist.py (recorded-trace replay); here both the
    # 1-shard drop-in and the N-shard deployment must hold the latency SLO
    for tag, p in (("1", one_shard), (str(CONTENTION_SHARDS), sharded)):
        ratio = p / central if central > 0 else 1.0
        rows.append(Row(f"admission_p50_ratio_shards{tag}", ratio,
                        f"vs centralized interactive p50; bootstrap "
                        f"ceiling 1.5, then baseline envelope"))
        _metric(f"admission_p50_ratio_shards{tag}", ratio, ceiling=1.5,
                detail=f"{tag}-shard vs centralized interactive p50 "
                       f"grant latency")

    # ---- safety: a seeded storm must never over-admit the global budget
    import numpy as np
    quota, cap = 3, 8
    storm = ShardedAdmission(
        AdmissionConfig(max_streams_per_client=quota, max_streams_total=cap,
                        lease_rate_per_s=1e3, lease_burst=8),
        [f"s{i}" for i in range(CONTENTION_SHARDS)])
    rng = np.random.default_rng(42)
    held: list[tuple[str, str]] = []
    denials, now_s = 0, 0.0
    for _ in range(1000):
        now_s += float(rng.uniform(0, 5e-3))
        client = f"c{rng.integers(4)}"
        server = f"s{rng.integers(CONTENTION_SHARDS)}"
        if held and rng.random() < 0.45:
            c, s = held.pop(int(rng.integers(len(held))))
            storm.release_stream(c, server_id=s, now_s=now_s)
        else:
            try:
                storm.acquire_stream(client, server_id=server)
                held.append((client, server))
            except Backpressure:
                denials += 1
        storm.lease_wait_s(now_s, 1, server_id=server)   # drives reconciles
    agg = storm.stats
    peak_client = max(storm.peak_streams(f"c{i}") for i in range(4))
    rows.append(Row(
        "admission_storm_peak", storm.peak_total,
        f"ops=1000 denials={denials} borrows={agg.borrows} "
        f"reconciles={agg.reconciles} peak_client={peak_client} "
        f"(quota={quota}) peak_total={storm.peak_total} (cap={cap})"))
    assert denials > 0 and agg.borrows > 0 and agg.reconciles > 0, (
        "storm too gentle: limits, borrowing and reconciliation must all "
        "have been exercised")
    assert peak_client <= quota and storm.peak_total <= cap, (
        f"distributed admission over-admitted: peak_client={peak_client} "
        f"(quota {quota}), peak_total={storm.peak_total} (cap {cap})")
    return rows


def run_flap(side_load: bool = False) -> list[Row]:
    """History-aware vs no-history stealing under a flapping replica,
    self-asserting three ways.

    The shape: a 5-replica cluster scanned on 3 streams, so two replicas sit
    idle as steal targets from t=0 — one clean, one **flapping** (RDMA rate
    oscillating 4×↔1× every lease round). The leased straggler degrades
    persistently across two scans (4× in scan 1, 2.1× in scan 2 — under the
    static 2× threshold). Assertions:

    1. *Hysteresis*: with a shared :class:`RateHistory`, scan 1's steal
       lowers the straggler's per-victim factor, so scan 2 steals where the
       static threshold stays blind — and the flap quarantine keeps the
       tail off the oscillating replica. History-aware stealing must beat
       no-history by ≥ 1.3× modeled makespan on scan 2.
    2. *Waste*: the history-aware run may waste at most 1 steal across both
       scans (wasted = a steal from/onto the flapping replica, or a
       re-steal — a migration that had to be undone).
    3. *Shard safety*: rerun the straggler under per-server
       ``ShardedAdmission`` with every candidate thief's shard at its local
       quota: the thief must **decline** (never borrow/over-admit), take
       the next shard only when a freed-slot event opens it, and no shard's
       concurrent streams may ever exceed its local slice.

    Unlike the throughput axes this scenario runs on the FIXED paper-class
    ``FabricConfig`` rather than the host-calibrated one: every assertion
    here is about modeled *decision geometry* (steal split sizes, how many
    pulls a stolen tail makes on the flapping link), and host-calibrated
    bandwidth would move those integer splits between runs.
    """
    base = FabricConfig()
    FLAP_SCHEDULE = (4.0, 1.0)
    STRAGGLER, FLAPPER = "s2", "s3"
    table = make_numeric_table("t", 24 * (1 << 13), 4, batch_rows=1 << 13)
    sql = "SELECT c0, c1 FROM t"

    def make_coord(straggler_factor: float,
                   admission=None) -> ClusterCoordinator:
        coord = ClusterCoordinator(admission=admission)
        for sid in ("s0", "s1", "s4"):
            coord.add_server(sid, ThallusServer(Engine(), Fabric(base)))
        coord.add_server(STRAGGLER, ThallusServer(
            Engine(), FlappingFabric(base, schedule=[straggler_factor])))
        coord.add_server(FLAPPER, ThallusServer(
            Engine(), FlappingFabric(base, schedule=FLAP_SCHEDULE)))
        coord.place_replicas("/d", table)
        return coord

    def wasted(events) -> int:
        return sum(1 for e in events
                   if (e.kind == "re_steal"
                       or (e.kind == "steal"
                           and FLAPPER in (e.victim, e.thief))))

    rows: list[Row] = []
    span: dict[tuple[str, int], float] = {}
    waste: dict[str, int] = {}
    for label, history in (("nohist", None),
                           ("hist", RateHistory(quarantine_rounds=64))):
        waste[label] = 0
        for scan, factor in ((1, 4.0), (2, 2.1)):
            coord = make_coord(factor)
            puller = StealingPuller(coord,
                                    coord.plan(sql, "/d", num_streams=3),
                                    steal=StealConfig(), history=history)
            stats = puller.run()
            span[(label, scan)] = stats.modeled_critical_path_s
            waste[label] += wasted(stats.steal_events)
            rows.append(Row(
                f"flap_{label}_scan{scan}",
                stats.modeled_critical_path_s * 1e6,
                f"straggler={factor:g}x flap={FLAP_SCHEDULE[0]:g}x<->"
                f"{FLAP_SCHEDULE[1]:g}x steals={stats.steals} "
                f"re_steals={stats.re_steals} "
                f"wasted={wasted(stats.steal_events)}"))
        if history is not None:
            assert history.total_flaps > 0 and history.quarantined(FLAPPER), \
                "the flapping replica was never caught flapping"
    speedup = span[("nohist", 2)] / span[("hist", 2)]
    rows.append(Row("flap_speedup", speedup,
                    "scan-2 modeled makespan, history off/on; bootstrap "
                    "floor 1.3, then baseline envelope"))
    _metric("flap_speedup", speedup, floor=1.3,
            detail="steal hysteresis vs the repeat straggler's scan-2 "
                   "makespan")
    _metric("flap_wasted_steals", waste["hist"], ceiling=1,
            detail="steals wasted on the flapping replica")
    # fixed FabricConfig => fully deterministic modeled makespans: the
    # tightest drift detectors in the suite (a fabric/sched change that
    # slows the modeled path moves these immediately)
    _metric("flap_hist_scan2_us", span[("hist", 2)] * 1e6, better="lower")
    _metric("flap_nohist_scan2_us", span[("nohist", 2)] * 1e6,
            better="lower")

    # ---- shard safety: every candidate thief shard at its local quota
    ids = ["s0", "s1", "s2", "s3", "s4"]
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=2 * len(ids)), ids,
        dist=DistributedConfig(borrow_limit=0))
    coord = make_coord(4.0, admission=admission)
    puller = StealingPuller(coord, coord.plan(sql, "/d", num_streams=3),
                            steal=StealConfig(steal_headroom_min=2),
                            history=RateHistory(), client_id="bench")
    for sid in ids:          # a foreign tenant fills every second slot
        admission.acquire_stream("foreign", server_id=sid)
    released, delivered = False, 0
    for _, _ in puller.batches():
        delivered += 1
        stats = puller.stats()
        if stats.declines >= 2 and not released:
            released = True   # one shard drains: the declined steal retries
            admission.release_stream("foreign", server_id="s4")
    stats = puller.stats()
    slices = {sid: shard.config.max_streams_total
              for sid, shard in admission.shards.items()}
    over = {sid: shard.stats.peak_active
            for sid, shard in admission.shards.items()
            if shard.stats.peak_active > slices[sid]}
    rows.append(Row("flap_shard_declines", stats.declines,
                    f"steals={stats.steals} retried={int(released)} "
                    f"peaks<=slices={not over} batches={stats.batches}"))
    assert stats.declines >= 1, "no thief shard ever declined"
    assert released and stats.steals >= 1, (
        "the declined steal never retried on the freed-slot event")
    assert not over, (
        f"a thief shard over-admitted a stolen range: {over} (slices "
        f"{slices})")
    assert delivered == 24, f"dropped batches: {delivered}/24"

    if side_load:
        # background lookups through the SideWorkload protocol on a clean
        # build of the same cluster — AFTER the measured runs, so the
        # steal-geometry assertions above never see the extra traffic
        side_gw = ScanGateway(make_coord(1.0),
                              classes=[ClientClass("interactive", 4.0)])
        side_reqs = submit_side_load(side_gw)
        side_gw.run()
        side = side_gw.stats.klass("interactive")
        assert side.granted == len(side_reqs), (
            f"side load dropped requests: {side.granted}/{len(side_reqs)}")
        rows.append(Row("flap_side_load",
                        side.p50_grant_latency_s * 1e6,
                        f"granted={side.granted}/{side.submitted}"))
    return rows


SLO_HEARTBEAT_BUDGET = 8          # degraded heartbeats before paging is late
SLO_POSTMORTEM_PATH = os.path.join("artifacts", "postmortem",
                                   "slo_postmortem.json")


def submit_side_load(gateway: ScanGateway, *, count: int = 2,
                     client_id: str = "side") -> list[ScanRequest]:
    """Low-rate interactive side-load mixin: a couple of light lookups
    riding along each heartbeat's batch scan (off by default everywhere;
    the slo scenario turns it on). Delegates to the obs ``SideWorkload``
    protocol — ``InteractiveSideLoad`` is the single implementation of
    this shape now, and ``tests/test_obs_workload.py`` conformance-asserts
    the delegation reproduces the original submit schedule exactly."""
    return InteractiveSideLoad(LIGHT_SQL, "/d", count=count,
                               client_id=client_id).submit(gateway)


def run_slo(side_load: bool = False) -> list[Row]:
    """Cluster health + SLO burn rate + flight-recorder postmortem, end to
    end, self-asserting four ways.

    The shape reuses the flap scenario's decision geometry: a 5-replica
    cluster scanned on 3 streams behind the qos gateway, one persistent
    straggler (``s2``, 4×) and one flapping replica (``s3``, 4×↔1× per
    lease round) — but only in the *degraded* phase. A foreign tenant fills
    one admission slot on every shard **except the flapper's**, so the
    first steal lands on the flapper (and gets caught flapping →
    rate-history quarantine) while later steal attempts on ``s4`` decline
    at the local quota: both causal event kinds land in the flight
    recorder. Phases, all on one modeled clock:

    1. *Calibrate* (clean fleet): measure the clean modeled critical path
       and the heartbeat spacing; derive burn-rate objectives from them.
    2. *Clean verify*: more clean heartbeats through the armed engine —
       must fire ZERO alerts (the false-positive gate).
    3. *Degrade*: swap in the straggler+flapper fabrics and heartbeat until
       the engine pages — within ``SLO_HEARTBEAT_BUDGET`` beats — then dump
       the postmortem bundle (events + registry + health + trace) to
       ``SLO_POSTMORTEM_PATH``.
    4. *Conformance*: the health monitor's QUARANTINED verdicts must agree
       with ``RateHistory.quarantined`` for every server.

    Like flap, this runs on the FIXED paper-class ``FabricConfig``: every
    assertion is about modeled decision geometry, and host-calibrated
    bandwidth would move the burn-rate sample values between runs.
    """
    base = FabricConfig()
    FLAP_SCHEDULE = (4.0, 1.0)
    STRAGGLER, FLAPPER = "s2", "s3"
    STRAGGLER_FACTOR = 4.0
    EXPECTED_BATCHES = 24
    ids = ["s0", "s1", "s2", "s3", "s4"]
    table = make_numeric_table("t", EXPECTED_BATCHES * (1 << 13), 4,
                               batch_rows=1 << 13)
    sql = "SELECT c0, c1 FROM t"

    # one observability spine across every phase: the flight recorder, the
    # health monitor fed by it, the SLO engine, the cross-scan rate history
    # and the tracer all outlive the per-phase gateways
    recorder = FlightRecorder(capacity=512)
    health = HealthMonitor(recorder=recorder)
    engine = SloEngine()
    history = RateHistory(quarantine_rounds=64)
    tracer = Tracer()

    def make_gateway(degraded: bool) -> ScanGateway:
        admission = ShardedAdmission(
            AdmissionConfig(max_streams_total=2 * len(ids)), ids,
            dist=DistributedConfig(borrow_limit=0))
        admission.recorder = recorder
        coord = ClusterCoordinator(admission=admission, recorder=recorder,
                                   health=health)
        for sid in ("s0", "s1", "s4"):
            coord.add_server(sid, ThallusServer(Engine(), Fabric(base)))
        coord.add_server(STRAGGLER, ThallusServer(Engine(), FlappingFabric(
            base, schedule=[STRAGGLER_FACTOR]) if degraded else Fabric(base)))
        coord.add_server(FLAPPER, ThallusServer(Engine(), FlappingFabric(
            base, schedule=FLAP_SCHEDULE) if degraded else Fabric(base)))
        coord.place_replicas("/d", table)
        # foreign tenant: one slot on every shard but the flapper's — the
        # first steal lands on the (open) flapper, later thieves decline
        for sid in ids:
            if sid != FLAPPER:
                admission.acquire_stream("foreign", server_id=sid)
        scheduler = AdaptiveScheduler(
            steal=StealConfig(steal_headroom_min=2), history=history)
        health.bind(history=history, admission=admission)
        return ScanGateway(
            coord,
            classes=[ClientClass("interactive", 4.0),
                     ClientClass("batch", 1.0)],
            scheduler=scheduler, tracer=tracer)

    epoch_base = 0.0            # monotonic modeled time across gateways
    last_reg = [MetricsRegistry()]   # the postmortem's registry snapshot
    # --side-load: one extra seeded interactive population riding every
    # beat through the SideWorkload protocol (its window cursor spans the
    # per-phase gateways; the burn targets recalibrate around it)
    extra_load = PopulationSideWorkload(ClientPopulation(
        "interactive", arrival="uniform", rate_per_beat=1.0, sql=LIGHT_SQL,
        cost_hint=1.0, num_streams=2, client_id="side2"),
        seed=11) if side_load else None

    def beat(gateway: ScanGateway):
        """One heartbeat: primary batch scan + interactive side-load →
        drain → coordinator heartbeat → registry snapshot → SLO observe."""
        req = gateway.submit(ScanRequest(
            "primary", "batch", sql, "/d", cost_hint=8.0,
            arrival_s=gateway.clock_s, num_streams=3))
        submit_side_load(gateway)
        if extra_load is not None:
            extra_load.submit(gateway)
        gateway.run()
        result = gateway.results[req.request_id]
        now = epoch_base + gateway.clock_s
        gateway.coordinator.heartbeat(now)
        reg = MetricsRegistry()
        record_cluster(reg, result.cluster)
        record_health(reg, health)
        reg.gauge("scan.delivered", float(len(result.batches)))
        last_reg[0] = reg        # published before observe: an alert's
        #                          postmortem sees THIS beat's snapshot
        fired = engine.observe(now, reg.snapshot())
        gateway.stats.alerts += len(fired)
        return result, fired, now

    rows: list[Row] = []

    # ---- phase 1: calibrate on a clean fleet (engine unarmed: no samples)
    gw = make_gateway(degraded=False)
    clean_cp_us, ticks = [], []
    for _ in range(3):
        result, _, now = beat(gw)
        clean_cp_us.append(result.cluster.modeled_critical_path_s * 1e6)
        ticks.append(now)
    clean_med_us = sorted(clean_cp_us)[len(clean_cp_us) // 2]
    dt = (ticks[-1] - ticks[0]) / (len(ticks) - 1)
    # 1.3×: clean beats sit ~30% under the target, the steal-mitigated
    # degraded beats ~15% over — comfortable margin on BOTH sides of the
    # threshold (1.5× left the first degraded beat within 0.3% of it)
    target_us = 1.3 * clean_med_us
    long_s, short_s = 40.0 * dt, 1.5 * dt
    engine.add(SloObjective(
        "scan-critical-path", "cluster.modeled_critical_path.us",
        target=target_us, better="lower", goal=0.75,
        windows=((long_s, 1.2), (short_s, 1.2)), min_samples=3))
    engine.add(SloObjective(          # never fires: delivery stays complete
        "delivery-completeness", "scan.delivered",
        target=float(EXPECTED_BATCHES), better="higher", goal=0.75,
        windows=((long_s, 1.2), (short_s, 1.2)), min_samples=3))

    # ---- phase 2: clean verify — the armed engine must stay silent
    for _ in range(4):
        beat(gw)
    false_alerts = len(engine.alerts)
    epoch_base += gw.clock_s

    # ---- phase 3: degrade and heartbeat until the engine pages
    dumped: list[str] = []
    engine.subscribe(lambda alert: dumped.append(recorder.dump(
        SLO_POSTMORTEM_PATH, trigger=alert, registry=last_reg[0],
        health=health, tracer=tracer)))
    gw = make_gateway(degraded=True)
    alert, alert_beat, degraded_cp_us = None, None, None
    for hb in range(1, SLO_HEARTBEAT_BUDGET + 1):
        result, fired, _ = beat(gw)
        if degraded_cp_us is None:
            degraded_cp_us = result.cluster.modeled_critical_path_s * 1e6
        if fired:
            alert, alert_beat = fired[0], hb
            break

    # ---- verdicts -------------------------------------------------------
    assert alert is not None, (
        f"SLO engine never paged within {SLO_HEARTBEAT_BUDGET} degraded "
        f"heartbeats (clean median {clean_med_us:.1f}us, "
        f"target {target_us:.1f}us)")
    assert alert.objective == "scan-critical-path", (
        f"wrong objective paged: {alert.objective}")
    assert false_alerts == 0, (
        f"{false_alerts} alert(s) fired on the CLEAN fleet")
    counts = recorder.counts()
    for kind in ("steal", "steal.decline"):
        assert counts.get(kind, 0) >= 1, (
            f"causal event {kind!r} missing from the flight recorder "
            f"(counts={counts})")
    for sid in ids:
        agree = ((health.state(sid) == QUARANTINED)
                 == bool(history.quarantined(sid)))
        assert agree, (
            f"health monitor and rate history disagree on {sid}: "
            f"state={health.state(sid)} "
            f"history.quarantined={history.quarantined(sid)}")
    assert dumped and os.path.exists(dumped[0]), "postmortem never dumped"
    import json as _json
    with open(dumped[0]) as f:
        bundle = _json.load(f)
    for key in ("trigger", "events", "health", "registry", "trace"):
        assert key in bundle, f"postmortem bundle missing {key!r}"
    assert any(e["kind"] == "steal.decline" for e in bundle["events"]), \
        "postmortem event window lost the causal steal.decline"

    _metric("slo_alert_latency_heartbeats", alert_beat,
            ceiling=SLO_HEARTBEAT_BUDGET, better="lower",
            detail="degraded heartbeats until the burn-rate engine paged")
    _metric("slo_false_alerts", false_alerts, ceiling=0,
            detail="alerts fired during the clean-fleet verify phase")
    # fixed FabricConfig => deterministic modeled paths: envelope drift bait
    _metric("slo_clean_cp_us", clean_med_us, better="lower")
    _metric("slo_degraded_cp_us", degraded_cp_us, better="lower")

    rows.append(Row("slo_clean_cp_us", clean_med_us,
                    f"heartbeats=7 target_us={target_us:.1f} "
                    f"false_alerts={false_alerts}"))
    rows.append(Row("slo_degraded_cp_us", degraded_cp_us,
                    f"straggler={STRAGGLER_FACTOR:g}x "
                    f"flap={FLAP_SCHEDULE[0]:g}x<->{FLAP_SCHEDULE[1]:g}x "
                    f"steals={counts.get('steal', 0)} "
                    f"declines={counts.get('steal.decline', 0)}"))
    rows.append(Row(
        "slo_alert_latency", float(alert_beat),
        f"budget={SLO_HEARTBEAT_BUDGET} objective={alert.objective} "
        f"value_us={alert.value:.1f} burns="
        + "/".join(f"{b:.2f}" for b in alert.burns)
        + f" quarantined={[s for s in ids if history.quarantined(s)]} "
        f"postmortem={dumped[0]} events={len(bundle['events'])}"))
    return rows


STRESS_HEARTBEAT_BUDGET = 8   # overload beats before paging counts as late
STRESS_CLEAN_BEATS = 7        # armed clean beats before the storm starts
STRESS_SEED = 7
STRESS_POSTMORTEM_PATH = os.path.join("artifacts", "postmortem",
                                      "stress_postmortem.json")


def run_stress() -> list[Row]:
    """The stress workload driver end to end, self-asserting both ways.

    A seeded four-population mix through ONE gateway on ONE modeled clock
    (``repro.obs.workload.StressDriver``):

    * ``interactive`` — light 2-stream lookups, 3/beat uniformly through
      each beat window, deadline-budgeted (weight 4);
    * ``batch`` — one heavy 3-stream analytics scan per beat (weight 1);
    * ``storm`` — a Poisson scan-storm burst of heavy 2-stream scans with
      lognormal cost jitter, inactive until beat ``STRESS_CLEAN_BEATS``;
    * ``squatter`` — submits nothing; at storm time it seizes both
      admission slots on ``s2``, so every 3-stream batch fan-out declines
      (``qos.backpressure``) while 2-stream traffic squeezes through.

    Phases: (1) *calibrate* — the same mix minus storm/squatter/deadline on
    a probe gateway derives the beat spacing, the clean interactive beat
    p50 and the gateway's service-per-cost estimate; (2) *clean verify* —
    ``STRESS_CLEAN_BEATS`` beats of the calibrated mix through the ARMED
    burn-rate engine must fire ZERO alerts; (3) *overload* — storm +
    squatter activate, and a per-population objective must page within
    ``STRESS_HEARTBEAT_BUDGET`` beats, dumping a postmortem whose event
    window carries the causal ``qos.shed`` (interactive deadline sheds)
    AND ``qos.backpressure`` (batch admission declines) events. The Jain
    fairness index over per-population throughput must drop under
    overload.

    Like flap/slo this runs on the FIXED paper-class ``FabricConfig``:
    every judged number is modeled decision geometry, so the whole run —
    schedule, telemetry, page beat — replays identically and the
    trajectory envelope can hold it tight.
    """
    base = FabricConfig()
    ids = ["s0", "s1", "s2", "s3", "s4"]
    EXPECTED_BATCHES = 24
    table = make_numeric_table("t", EXPECTED_BATCHES * (1 << 13), 4,
                               batch_rows=1 << 13)
    heavy_sql = "SELECT c0, c1, c2, c3 FROM t"

    recorder = FlightRecorder(capacity=1024)
    health = HealthMonitor(recorder=recorder)
    engine = SloEngine()
    tracer = Tracer()

    def base_populations(deadline_s=None):
        return [
            ClientPopulation("interactive", weight=4.0, arrival="uniform",
                             rate_per_beat=3.0, sql=LIGHT_SQL,
                             cost_hint=1.0, num_streams=2,
                             deadline_s=deadline_s),
            ClientPopulation("batch", weight=1.0, arrival="burst",
                             rate_per_beat=1.0, sql=heavy_sql,
                             cost_hint=8.0, num_streams=3),
        ]

    def make_gateway(populations, est_service_s_per_cost=1e-4):
        admission = ShardedAdmission(
            AdmissionConfig(max_streams_total=2 * len(ids)), ids,
            dist=DistributedConfig(borrow_limit=0))
        admission.recorder = recorder
        coord = ClusterCoordinator(admission=admission, recorder=recorder,
                                   health=health)
        for sid in ids:
            coord.add_server(sid, ThallusServer(Engine(), Fabric(base)))
        coord.place_replicas("/d", table)
        health.bind(admission=admission)
        # modeled_service: stream service charged in fabric-modeled wire
        # time, not measured host time — grant latencies, beat windows and
        # the page beat become a pure function of (seed, FabricConfig), so
        # two consecutive runs emit identical trajectories.
        return ScanGateway(coord, classes=population_classes(populations),
                           tracer=tracer, modeled_service=True,
                           est_service_s_per_cost=est_service_s_per_cost)

    # ---- phase 1: calibrate the clean mix on a probe gateway -------------
    calib_pops = base_populations()
    calib = StressDriver(make_gateway(calib_pops), calib_pops,
                         seed=STRESS_SEED, recorder=recorder)
    clean_p50s_us = []
    for _ in range(3):
        calib.beat()
        clean_p50s_us.append(
            calib.beat_stats["interactive"]["p50_grant_us"])
    dt = calib.window_s / 3.0
    clean_p50_us = sorted(clean_p50s_us)[1]
    cost_per_beat = sum(p.rate_per_beat * p.cost_hint for p in calib_pops)
    service_per_cost = dt / cost_per_beat
    assert calib.sheds["interactive"] == 0 and not calib.alerts

    # ---- phase 2+3: the armed mix, storm injected at STRESS_CLEAN_BEATS --
    populations = base_populations(deadline_s=1.5 * dt) + [
        ClientPopulation("storm", weight=2.0, arrival="poisson",
                         rate_per_beat=6.0, sql=heavy_sql, cost_hint=8.0,
                         cost_jitter=0.3, num_streams=2,
                         start_beat=STRESS_CLEAN_BEATS),
        ClientPopulation("squatter", weight=1.0, rate_per_beat=0.0,
                         start_beat=STRESS_CLEAN_BEATS,
                         squat_servers=("s2", "s2")),
    ]
    # the long window spans the overload regime (~3-5 storm beats), not the
    # whole run: diluting burn with the seven clean beats would let a
    # sustained storm hide under the clean prefix
    long_s, short_s = 12.0 * dt, 1.5 * dt
    engine.add(SloObjective(
        "stress-interactive-latency", "workload.interactive.beat.p50_grant_us",
        target=1.3 * clean_p50_us, better="lower", goal=0.75,
        windows=((long_s, 1.2), (short_s, 1.2)), min_samples=3))
    engine.add(SloObjective(
        "stress-interactive-shed", "workload.interactive.beat.shed",
        target=0.5, better="lower", goal=0.75,
        windows=((long_s, 1.2), (short_s, 1.2)), min_samples=3))
    driver = StressDriver(make_gateway(populations, service_per_cost),
                          populations, seed=STRESS_SEED, slo=engine,
                          recorder=recorder)
    dumped: list[str] = []
    engine.subscribe(lambda alert: dumped.append(recorder.dump(
        STRESS_POSTMORTEM_PATH, trigger=alert, registry=driver.registry,
        health=health, tracer=tracer, last_n=128)))

    for _ in range(STRESS_CLEAN_BEATS):
        driver.beat()
    false_alerts = len(driver.alerts)
    jain_clean = driver.fairness()["jain"]

    alert, alert_beat = None, None
    for hb in range(1, STRESS_HEARTBEAT_BUDGET + 1):
        report = driver.beat()
        if report.alerts:
            alert, alert_beat = report.alerts[0], hb
            break
    fair = driver.fairness()
    jain_overload = fair["jain"]
    snap = driver.registry.snapshot()

    # ---- verdicts -------------------------------------------------------
    assert false_alerts == 0, (
        f"{false_alerts} alert(s) fired on the calibrated clean mix")
    assert alert is not None, (
        f"stress overload never paged within {STRESS_HEARTBEAT_BUDGET} "
        f"beats (clean p50 {clean_p50_us:.1f}us, dt {dt * 1e6:.1f}us)")
    assert alert.objective.startswith("stress-interactive"), (
        f"wrong objective paged: {alert.objective}")
    assert driver.sheds["interactive"] >= 1, "no interactive deadline sheds"
    assert driver.declines["batch"] >= 1, (
        "the squatter never forced a batch admission decline")
    assert jain_overload < jain_clean, (
        f"overload did not dent fairness: jain {jain_clean:.3f} -> "
        f"{jain_overload:.3f}")
    for name in ("workload.interactive.grant_latency.p50",
                 "workload.interactive.grant_latency.p99",
                 "workload.storm.throughput_bps",
                 "workload.fairness.jain"):
        assert name in snap, f"missing workload metric {name!r}"
    assert dumped and os.path.exists(dumped[0]), "postmortem never dumped"
    import json as _json
    with open(dumped[0]) as f:
        bundle = _json.load(f)
    for kind in ("qos.shed", "qos.backpressure"):
        assert any(e["kind"] == kind for e in bundle["events"]), (
            f"postmortem event window lost the causal {kind} "
            f"(counts={bundle['event_counts']})")

    _metric("stress_alert_latency_heartbeats", alert_beat,
            ceiling=STRESS_HEARTBEAT_BUDGET, better="lower",
            detail="overload beats until a stress objective paged")
    _metric("stress_false_alerts", false_alerts, ceiling=0,
            detail="alerts fired during the calibrated clean beats")
    # fixed FabricConfig + seeded populations => deterministic: tight
    # envelope drift detectors over the fairness geometry
    _metric("workload_jain_clean", jain_clean, better="higher")
    _metric("workload_jain_overload", jain_overload, better="higher")
    _metric("workload_latency_inflation", fair["latency_inflation"],
            better="lower")
    _metric("stress_interactive_clean_p50_us", clean_p50_us, better="lower")

    rows: list[Row] = []
    for p in populations:
        c = driver.gateway.stats.classes.get(p.name)
        if c is None:
            continue
        rows.append(Row(
            f"stress_{p.name}", c.p50_grant_latency_s * 1e6,
            f"granted={c.granted}/{c.submitted} "
            f"shed_deadline={driver.sheds.get(p.name, 0)} "
            f"declines={driver.declines.get(p.name, 0)} "
            f"tput_MBps={c.throughput_over(driver.window_s) / 1e6:.1f}"))
    rows.append(Row(
        "stress_alert_latency", float(alert_beat),
        f"budget={STRESS_HEARTBEAT_BUDGET} objective={alert.objective} "
        f"value={alert.value:.1f} clean_p50_us={clean_p50_us:.1f} "
        f"dt_us={dt * 1e6:.1f} postmortem={dumped[0]}"))
    rows.append(Row(
        "stress_jain", jain_overload,
        f"clean={jain_clean:.3f} overload={jain_overload:.3f} "
        f"inflation={fair['latency_inflation']:.2f} "
        f"false_alerts={false_alerts} beats={driver.beats}"))
    return rows


NEMESIS_HEARTBEAT_BUDGET = 8  # beats allowed between a fault and its page /
                              # evict / re-admit (the bounded-recovery SLO)
NEMESIS_CLEAN_BEATS = 6       # armed clean beats before the schedule starts
NEMESIS_SEED = 11
NEMESIS_POSTMORTEM_PATH = os.path.join("artifacts", "postmortem",
                                       "nemesis_postmortem.json")


def run_nemesis() -> list[Row]:
    """Elastic membership under a seeded nemesis schedule, self-asserting.

    The PR 8 stress populations (interactive 2-stream lookups + one heavy
    3-stream batch scan per beat) drive a 5-replica cluster while a
    deterministic :class:`repro.cluster.Nemesis` injects the three fault
    kinds on a fixed schedule:

    * ``slow``  — ``s1`` (a serving replica of every 2-stream plan) loses
      8× bandwidth for four beats: the interactive latency objective pages;
    * ``partition`` — ``s2``'s admission shard stops reconciling for two
      beats (overlapping the slow fault);
    * ``kill``  — ``s0`` dies MID-LEASE (``after_batches=1``): in-flight
      leases migrate to a surviving replica via
      ``init_scan(start_batch=delivered)``, the fault storm quarantines
      ``s0``, the :class:`~repro.cluster.MembershipController` evicts it
      (placement repair + admission shard absorbed), and after the nemesis
      heals it the hysteretic health recovery re-admits it.

    Asserts: ZERO alerts in the clean phase; a ``nemesis-*`` objective
    pages within ``NEMESIS_HEARTBEAT_BUDGET`` beats of the first fault;
    evict lands within the budget of the kill and re-admit within the
    budget of the heal; at least one lease actually migrated; EVERY granted
    scan across the whole run delivered its full result byte-identical to a
    direct single-server evaluation (exactly-once through crash, failover,
    eviction and re-admission); and the dumped postmortem carries the
    causal ``nemesis.inject`` → ``stream.migrate`` → ``membership.evict``
    chain plus the membership transition log. Fixed ``FabricConfig`` +
    seeded populations + a literal schedule: the fault timeline and every
    judged number replay identically run over run.
    """
    base = FabricConfig()
    ids = ["s0", "s1", "s2", "s3", "s4"]
    EXPECTED_BATCHES = 24
    table = make_numeric_table("t", EXPECTED_BATCHES * (1 << 13), 4,
                               batch_rows=1 << 13)
    heavy_sql = "SELECT c0, c1, c2, c3 FROM t"

    recorder = FlightRecorder(capacity=2048)
    health = HealthMonitor(recorder=recorder)
    engine = SloEngine()
    tracer = Tracer()

    def base_populations():
        # the stress mix minus storm/squatter/deadline: every granted scan
        # must COMPLETE (exactly-once is the point), so nothing is shed
        return [
            ClientPopulation("interactive", weight=4.0, arrival="uniform",
                             rate_per_beat=3.0, sql=LIGHT_SQL,
                             cost_hint=1.0, num_streams=2),
            ClientPopulation("batch", weight=1.0, arrival="burst",
                             rate_per_beat=1.0, sql=heavy_sql,
                             cost_hint=8.0, num_streams=3),
        ]

    def make_gateway(populations, est_service_s_per_cost=1e-4):
        # 3 slots/shard + borrow headroom so a migrating lease can release
        # its dead shard's slot and re-acquire on the target without a
        # spurious Backpressure mid-failover
        admission = ShardedAdmission(
            AdmissionConfig(max_streams_total=3 * len(ids)), ids,
            dist=DistributedConfig(borrow_limit=2))
        admission.recorder = recorder
        coord = ClusterCoordinator(admission=admission, recorder=recorder,
                                   health=health)
        for sid in ids:
            coord.add_server(sid, ThallusServer(Engine(), Fabric(base)))
        coord.place_replicas("/d", table)
        health.bind(admission=admission)
        gw = ScanGateway(coord, classes=population_classes(populations),
                         tracer=tracer, modeled_service=True,
                         est_service_s_per_cost=est_service_s_per_cost)
        return gw, admission, coord

    # ---- ground truth: one engine pass per sql, no cluster in the loop --
    def reference(sql):
        reader = coordinatorless_engine.execute(sql, "/d")
        out = []
        while (b := reader.read_next()) is not None:
            out.append(b)
        return out

    coordinatorless_engine = Engine()
    coordinatorless_engine.register("/d", table)

    def signature(batches):
        return [tuple(c.values.tobytes() for c in b.columns)
                for b in batches]

    ref_sig = {sql: signature(reference(sql))
               for sql in (LIGHT_SQL, heavy_sql)}

    # ---- phase 1: calibrate the clean mix on a probe gateway ------------
    calib_pops = base_populations()
    calib_gw, _, _ = make_gateway(calib_pops)
    calib = StressDriver(calib_gw, calib_pops, seed=NEMESIS_SEED,
                         recorder=recorder)
    clean_p50s_us = []
    for _ in range(3):
        calib.beat()
        clean_p50s_us.append(
            calib.beat_stats["interactive"]["p50_grant_us"])
    dt = calib.window_s / 3.0
    clean_p50_us = sorted(clean_p50s_us)[1]
    cost_per_beat = sum(p.rate_per_beat * p.cost_hint for p in calib_pops)
    service_per_cost = dt / cost_per_beat
    assert not calib.alerts and calib.sheds.get("interactive", 0) == 0

    # ---- phase 2+3: armed run under the literal nemesis schedule --------
    SLOW_BEAT = NEMESIS_CLEAN_BEATS            # s1 slow, 4 beats
    PART_BEAT = NEMESIS_CLEAN_BEATS + 1        # s2 partition, 2 beats
    KILL_BEAT = NEMESIS_CLEAN_BEATS + 3        # s0 mid-lease death
    HEAL_BEAT = KILL_BEAT + 6                  # s0 process back up
    TOTAL_BEATS = NEMESIS_CLEAN_BEATS + 18
    schedule = (
        FaultSpec("slow", "s1", SLOW_BEAT, stop_beat=SLOW_BEAT + 4,
                  factor=8.0),
        FaultSpec("partition", "s2", PART_BEAT, stop_beat=PART_BEAT + 2),
        FaultSpec("kill", "s0", KILL_BEAT, stop_beat=HEAL_BEAT,
                  after_batches=1),
    )
    populations = base_populations()
    gw, admission, coord = make_gateway(populations, service_per_cost)
    nemesis = Nemesis(coord, schedule, admission=admission)
    membership = MembershipController(coord, health, admission=admission)

    long_s, short_s = 12.0 * dt, 1.5 * dt
    engine.add(SloObjective(
        "nemesis-interactive-latency",
        "workload.interactive.beat.p50_grant_us",
        target=1.3 * clean_p50_us, better="lower", goal=0.75,
        windows=((long_s, 1.2), (short_s, 1.2)), min_samples=3))
    engine.add(SloObjective(
        "nemesis-migrations", "workload.beat.migrations",
        target=0.5, better="lower", goal=0.75,
        windows=((long_s, 1.2), (short_s, 1.2)), min_samples=3))
    driver = StressDriver(gw, populations, seed=NEMESIS_SEED, slo=engine,
                          recorder=recorder, nemesis=nemesis,
                          membership=membership)
    dumped: list[str] = []
    engine.subscribe(lambda alert: dumped.append(recorder.dump(
        NEMESIS_POSTMORTEM_PATH, trigger=alert, registry=driver.registry,
        health=health, tracer=tracer, membership=membership, last_n=256)))

    for _ in range(NEMESIS_CLEAN_BEATS):
        driver.beat()
    false_alerts = len(driver.alerts)

    first_alert, page_beat = None, None
    evict_beat, readmit_beat = None, None
    for index in range(NEMESIS_CLEAN_BEATS, TOTAL_BEATS):
        report = driver.beat()
        if report.alerts and page_beat is None:
            first_alert, page_beat = report.alerts[0], index
        for ev in report.membership:
            if ev.action == "evict" and ev.server_id == "s0" \
                    and evict_beat is None:
                evict_beat = index
            if ev.action == "readmit" and ev.server_id == "s0" \
                    and readmit_beat is None:
                readmit_beat = index

    # the authoritative bundle: dumped AFTER the full chain has played out,
    # so the event window provably carries inject → migrate → evict
    final_dump = recorder.dump(
        NEMESIS_POSTMORTEM_PATH, trigger=first_alert, registry=driver.registry,
        health=health, tracer=tracer, membership=membership, last_n=256)

    # ---- verdicts -------------------------------------------------------
    assert false_alerts == 0, (
        f"{false_alerts} alert(s) fired on the calibrated clean beats")
    assert first_alert is not None, (
        f"no nemesis objective paged within the fault phase "
        f"(clean p50 {clean_p50_us:.1f}us, dt {dt * 1e6:.1f}us)")
    assert first_alert.objective.startswith("nemesis-"), (
        f"wrong objective paged: {first_alert.objective}")
    assert page_beat - SLOW_BEAT <= NEMESIS_HEARTBEAT_BUDGET, (
        f"page at beat {page_beat}, fault at {SLOW_BEAT}: recovery SLO blown")
    assert evict_beat is not None, "s0 was never evicted after its kill"
    assert evict_beat - KILL_BEAT <= NEMESIS_HEARTBEAT_BUDGET, (
        f"evict at beat {evict_beat}, kill at {KILL_BEAT}")
    assert readmit_beat is not None, "s0 was never re-admitted after healing"
    assert readmit_beat - HEAL_BEAT <= NEMESIS_HEARTBEAT_BUDGET, (
        f"readmit at beat {readmit_beat}, heal at {HEAL_BEAT}")
    assert driver.migrations >= 1, "the mid-lease kill migrated no lease"
    assert "s0" not in membership.evicted, "s0 still out at run end"

    # exactly-once byte-identical delivery for EVERY granted scan
    checked = 0
    for result in gw.results.values():
        want = ref_sig[result.request.sql]
        got = signature(result.batches)
        assert got == want, (
            f"scan #{result.request.request_id} ({result.request.sql!r}) "
            f"delivered {len(got)} batch(es), wanted {len(want)} "
            f"byte-identical")
        checked += 1
    for p in populations:
        c = driver.gateway.stats.classes.get(p.name)
        assert c is not None and c.granted == c.submitted, (
            f"{p.name}: {c.submitted - c.granted} scan(s) lost "
            f"(submitted={c.submitted} granted={c.granted})")
    assert checked == sum(
        driver.gateway.stats.classes[p.name].granted for p in populations)

    import json as _json
    with open(final_dump) as f:
        bundle = _json.load(f)
    for kind in ("nemesis.inject", "stream.migrate", "membership.evict",
                 "membership.readmit", "placement.repair"):
        assert any(e["kind"] == kind for e in bundle["events"]), (
            f"postmortem event window lost the causal {kind} "
            f"(counts={bundle['event_counts']})")
    assert bundle["membership"]["events"], "no membership transition log"
    assert dumped and os.path.exists(dumped[0]), (
        "the page never dumped a postmortem")

    _metric("nemesis_alert_latency_beats", page_beat - SLOW_BEAT,
            ceiling=NEMESIS_HEARTBEAT_BUDGET, better="lower",
            detail="beats from first fault to the page")
    _metric("nemesis_false_alerts", false_alerts, ceiling=0,
            detail="alerts fired during the calibrated clean beats")
    _metric("nemesis_evict_latency_beats", evict_beat - KILL_BEAT,
            ceiling=NEMESIS_HEARTBEAT_BUDGET, better="lower",
            detail="beats from the kill to the eviction")
    _metric("nemesis_readmit_latency_beats", readmit_beat - HEAL_BEAT,
            ceiling=NEMESIS_HEARTBEAT_BUDGET, better="lower",
            detail="beats from the heal to the re-admission")
    # deterministic geometry: tight envelope drift detectors
    _metric("nemesis_migrations", float(driver.migrations), floor=1,
            better="higher")
    _metric("nemesis_scans_delivered", float(checked), better="higher")

    rows: list[Row] = []
    for p in populations:
        c = driver.gateway.stats.classes[p.name]
        rows.append(Row(
            f"nemesis_{p.name}", c.p50_grant_latency_s * 1e6,
            f"granted={c.granted}/{c.submitted} "
            f"migrations={driver.migrations} "
            f"tput_MBps={c.throughput_over(driver.window_s) / 1e6:.1f}"))
    rows.append(Row(
        "nemesis_alert_latency", float(page_beat - SLOW_BEAT),
        f"budget={NEMESIS_HEARTBEAT_BUDGET} objective={first_alert.objective} "
        f"page_beat={page_beat} fault_beat={SLOW_BEAT} "
        f"postmortem={final_dump}"))
    rows.append(Row(
        "nemesis_membership", float(readmit_beat - KILL_BEAT),
        f"kill={KILL_BEAT} evict={evict_beat} heal={HEAL_BEAT} "
        f"readmit={readmit_beat} scans={checked} "
        f"timeline={len(nemesis.timeline)}ev false_alerts={false_alerts}"))
    return rows


REPAIR_SEED = 13
REPAIR_BATCHES = 24
REPAIR_CLEAN_BEATS = 4
REPAIR_STORM_BEATS = 4


def run_repair() -> list[Row]:
    """Peer-to-peer re-placement over the registered RDMA path, self-asserting.

    Three phases, all on fixed ``FabricConfig`` + seeded populations so every
    judged number replays identically:

    1. **Live join, peer vs table-copy.** Two identical 4-server shard
       clusters take the same ``s4`` join; one has a
       :class:`~repro.cluster.ShardRepairer` attached (joiner pulls its slice
       server→server over registered pools), the other runs the legacy
       coordinator table-copy path. Asserts every moved batch rode the peer
       RDMA path (zero table copies), both clusters scan byte-identical to a
       coordinatorless engine pass, and the peer path's modeled wire time
       beats the modeled table-copy equivalent (RPC payload bandwidth + fresh
       per-segment pins for the same bytes) by ≥ 2×.
    2. **Evict re-deal, the durability story.** The same clusters lose
       ``s1``; its orphaned batches have no live registered holder (shards
       are disjoint), so every orphan must land via the stored-source-table
       fallback — exactly ``len(orphans)`` table copies — and the scans stay
       byte-identical. A drained-donor micro-cluster then proves the
       background-class metering: the repairer YIELDS (modeled backoff) while
       the donor's token bucket sits under the foreground reserve and absorbs
       the lease wait on its own clock.
    3. **Repair storm under foreground load.** The PR 8 stress driver runs
       an interactive population against a 4-replica cluster; after
       ``REPAIR_CLEAN_BEATS`` calibration beats, every storm beat churns
       ``s3`` (evict + rebalance re-admit), forcing a full-replica peer
       pre-warm per beat. Asserts the storm really moved bytes peer-to-peer
       every beat, the cluster still scans byte-identical afterwards, and
       the foreground interactive p50 inflation (storm/clean median) stays
       bounded — repair is background traffic, not a foreground tax.
    """
    base = FabricConfig()
    ids = ["s0", "s1", "s2", "s3"]
    table = make_numeric_table("t", REPAIR_BATCHES * (1 << 13), 4,
                               batch_rows=1 << 13)
    heavy_sql = "SELECT c0, c1, c2, c3 FROM t"

    def signature(batches):
        return [tuple(c.values.tobytes() for c in b.columns)
                for b in batches]

    ref_engine = Engine()
    ref_engine.register("/d", table)

    def reference(sql):
        reader = ref_engine.execute(sql, "/d")
        out = []
        while (b := reader.read_next()) is not None:
            out.append(b)
        return out

    ref_sig = sorted(signature(reference(heavy_sql)))

    def shard_cluster(with_repairer):
        coord = ClusterCoordinator()
        for sid in ids:
            coord.add_server(sid, ThallusServer(Engine(), Fabric(base)))
        coord.place_shards("/d", table)
        rep = ShardRepairer(coord) if with_repairer else None
        return coord, rep

    def scan_sig(coord):
        got = []
        cluster_scan(coord, heavy_sql, "/d", sink=lambda i, b: got.append(b))
        return sorted(signature(got))

    # ---- phase 1: live join — every moved batch rides the peer path -----
    peer, rep = shard_cluster(True)
    legacy, _ = shard_cluster(False)
    for coord in (peer, legacy):
        coord.add_server("s4", ThallusServer(Engine(), Fabric(base)),
                         rebalance=True)
    want = REPAIR_BATCHES // 5
    assert rep.stats.batches_pulled == want, (
        f"join moved {want} batches but only {rep.stats.batches_pulled} "
        f"rode the peer RDMA path")
    assert rep.stats.table_copies == 0, (
        f"join fell back to {rep.stats.table_copies} table cop(ies) with "
        f"every donor alive")
    assert scan_sig(peer) == scan_sig(legacy) == ref_sig, (
        "peer-repaired cluster is not byte-identical to the table-copy "
        "path / the reference")
    # the modeled table-copy equivalent for the SAME bytes: one RPC payload
    # per batch at RPC-path bandwidth plus fresh per-segment registration
    ncols = len(table.schema)
    copy_equiv_s = (want * (base.rpc_rtt_s + 3 * ncols * base.seg_register_s)
                    + rep.stats.bytes_pulled / base.rpc_bw)
    peer_s = rep.stats.modeled_wire_s
    join_speedup = copy_equiv_s / peer_s
    _metric("repair_peer_vs_copy_speedup", join_speedup, floor=2.0,
            better="higher",
            detail="modeled table-copy cost / peer-pull cost, same bytes")
    _metric("repair_join_pulled_batches", float(rep.stats.batches_pulled),
            floor=want, ceiling=want,
            detail="every moved batch must ride the peer path")

    # ---- phase 2: evict re-deal — sole-holder orphans fall back ---------
    orphans = len(peer._placements["/d"].assignment["s1"])
    copies_before = rep.stats.table_copies
    pulled_before = rep.stats.batches_pulled
    for coord in (peer, legacy):
        coord.remove_server("s1")
    fallbacks = rep.stats.table_copies - copies_before
    assert fallbacks == orphans, (
        f"{orphans} orphaned batches had no live holder but only "
        f"{fallbacks} took the stored-table fallback")
    assert rep.stats.batches_pulled == pulled_before, (
        "a re-deal of sole-holder orphans pulled from a dead peer")
    assert scan_sig(peer) == scan_sig(legacy) == ref_sig, (
        "post-evict repair is not byte-identical")
    _metric("repair_evict_fallback_batches", float(fallbacks),
            floor=orphans, ceiling=orphans,
            detail="dead sole holder: every orphan uses the durability "
                   "fallback")

    # metering micro-check: a drained donor bucket makes repair yield
    # (modeled backoff under the foreground reserve), then wait for tokens
    micro_adm = ShardedAdmission(
        AdmissionConfig(max_streams_total=8, lease_rate_per_s=100.0,
                        lease_burst=8), ["a0", "a1"])
    micro = ClusterCoordinator(admission=micro_adm)
    for sid in ("a0", "a1"):
        micro.add_server(sid, ThallusServer(Engine(), Fabric(base)))
    micro.place_shards("/m", table)
    micro_rep = ShardRepairer(micro)
    micro_adm.lease_wait_s(0.0, 4, server_id="a0")   # drain a0's bucket
    micro.add_server("a2", ThallusServer(Engine(), Fabric(base)),
                     rebalance=True)
    assert micro_rep.stats.yields >= 1, (
        "repair never yielded to the drained donor bucket")
    assert micro_rep.stats.throttle_wait_s > 0.0, (
        "repair paid no lease wait against the drained donor")
    _metric("repair_meter_yields", float(micro_rep.stats.yields), floor=1,
            detail="background class must defer to the foreground reserve")

    # ---- phase 3: repair storm under the stress populations -------------
    recorder = FlightRecorder(capacity=2048)
    health = HealthMonitor(recorder=recorder)
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=3 * len(ids),
                        lease_rate_per_s=2000.0), ids,
        dist=DistributedConfig(borrow_limit=2))
    admission.recorder = recorder
    coord = ClusterCoordinator(admission=admission, recorder=recorder,
                               health=health)
    for sid in ids:
        coord.add_server(sid, ThallusServer(Engine(), Fabric(base)))
    coord.place_replicas("/d", table)
    health.bind(admission=admission)
    storm_rep = ShardRepairer(coord)
    populations = [
        ClientPopulation("interactive", weight=4.0, arrival="uniform",
                         rate_per_beat=3.0, sql=LIGHT_SQL,
                         cost_hint=1.0, num_streams=2),
    ]
    gw = ScanGateway(coord, classes=population_classes(populations),
                     modeled_service=True, est_service_s_per_cost=1e-4)
    driver = StressDriver(gw, populations, seed=REPAIR_SEED,
                          recorder=recorder)
    clean_p50s = []
    for _ in range(REPAIR_CLEAN_BEATS):
        driver.beat()
        clean_p50s.append(driver.beat_stats["interactive"]["p50_grant_us"])
    storm_p50s = []
    storm_pulled = 0
    for beat in range(REPAIR_STORM_BEATS):
        before = storm_rep.stats.batches_pulled
        now_s = float(REPAIR_CLEAN_BEATS + beat)
        churned = coord.remove_server("s3", now_s=now_s)
        coord.add_server("s3", churned, rebalance=True, now_s=now_s)
        storm_pulled += storm_rep.stats.batches_pulled - before
        driver.beat()
        storm_p50s.append(driver.beat_stats["interactive"]["p50_grant_us"])
    clean_p50 = sorted(clean_p50s)[len(clean_p50s) // 2]
    storm_p50 = sorted(storm_p50s)[len(storm_p50s) // 2]
    inflation = storm_p50 / clean_p50 if clean_p50 else 1.0
    assert storm_pulled >= REPAIR_STORM_BEATS * REPAIR_BATCHES, (
        f"the storm only moved {storm_pulled} batches peer-to-peer "
        f"(wanted {REPAIR_STORM_BEATS * REPAIR_BATCHES}: a full replica "
        f"pre-warm per churn beat)")
    assert scan_sig(coord) == ref_sig, (
        "post-storm cluster is not byte-identical to the reference")
    counts = recorder.counts()
    for kind in ("repair.pull", "repair.complete"):
        assert counts.get(kind, 0) >= 1, (
            f"no {kind} event reached the obs funnel (counts={counts})")
    record_repair(driver.registry, storm_rep.stats)
    snap = driver.registry.snapshot()
    assert snap.get("repair.batches_pulled", 0) >= storm_pulled, (
        "repair.* registry metrics missing from the driver registry")
    _metric("repair_fg_p50_inflation", inflation, ceiling=1.5,
            better="lower",
            detail="interactive p50 under a repair storm / clean p50")
    _metric("repair_storm_pulled_batches", float(storm_pulled),
            floor=REPAIR_STORM_BEATS * REPAIR_BATCHES, better="higher",
            detail="a full replica pre-warm per churn beat")

    rows: list[Row] = []
    rows.append(Row(
        "repair_join", peer_s / want * 1e6,
        f"pulled={want} copies=0 speedup_vs_copy={join_speedup:.2f}x "
        f"bytes={rep.stats.bytes_pulled}"))
    rows.append(Row(
        "repair_evict", rep.stats.modeled_copy_s / fallbacks * 1e6,
        f"fallbacks={fallbacks}/{orphans} reused={rep.stats.batches_reused} "
        f"copy_bytes={rep.stats.bytes_copied}"))
    rows.append(Row(
        "repair_storm", storm_p50,
        f"inflation={inflation:.2f}x clean_p50_us={clean_p50:.1f} "
        f"pulled={storm_pulled} yields={storm_rep.stats.yields} "
        f"throttle_us={storm_rep.stats.throttle_wait_s * 1e6:.1f}"))
    return rows


_SCENARIOS = {
    "fig2": lambda transport, side_load=False: run(transport),
    "cluster": lambda transport, side_load=False: run_cluster(),
    "contention": lambda transport, side_load=False:
        run_contention(side_load=side_load),
    "straggler": lambda transport, side_load=False: run_straggler(),
    "sharing": lambda transport, side_load=False: run_sharing(),
    "admission": lambda transport, side_load=False: run_admission(),
    "flap": lambda transport, side_load=False: run_flap(side_load=side_load),
    "slo": lambda transport, side_load=False: run_slo(side_load=side_load),
    "stress": lambda transport, side_load=False: run_stress(),
    "nemesis": lambda transport, side_load=False: run_nemesis(),
    "repair": lambda transport, side_load=False: run_repair(),
}


def main() -> int:
    global _RUN
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--transport", choices=("rpc", "thallus", "both"),
                    default="both")
    ap.add_argument("--scenario", choices=(*_SCENARIOS, "all"),
                    default=None,
                    help="which axis to run (default: fig2, which itself "
                    "appends the cluster axis; 'all' adds every other axis)")
    ap.add_argument("--cluster-only", action="store_true",
                    help="alias for --scenario cluster (back-compat)")
    ap.add_argument("--json", metavar="DIR", default=None, dest="json_dir",
                    help="append each scenario's run record "
                    "(BENCH_<scenario>.json + trajectory.jsonl) to DIR; "
                    "check it later with `python -m repro.obs.baseline DIR`")
    ap.add_argument("--side-load", action="store_true", dest="side_load",
                    help="ride the contention/flap/slo scenarios with "
                    "background SideWorkload traffic (off by default: the "
                    "measured geometries stay exactly as calibrated)")
    args = ap.parse_args()
    if args.cluster_only:
        scenarios = ["cluster"]
    elif args.scenario == "all":
        # fig2 already appends cluster
        scenarios = ["fig2", "contention", "straggler", "sharing",
                     "admission", "flap", "slo", "stress", "nemesis",
                     "repair"]
    elif args.scenario is not None:
        scenarios = [args.scenario]
    else:
        scenarios = ["fig2"]

    cfg = calibrated_fabric().config
    run_cfg = {"transport": args.transport,
               "rpc_bw": cfg.rpc_bw, "rdma_bw": cfg.rdma_bw}
    failures: list[tuple[str, str]] = []
    print("name,us_per_call,derived")
    for name in scenarios:
        _RUN = ScenarioRun(name, out_dir=args.json_dir, config=run_cfg)
        try:
            scenario_rows = _SCENARIOS[name](args.transport,
                                             side_load=args.side_load)
        except AssertionError as exc:       # a hard invariant broke mid-run
            failures.append((name, str(exc)))
            _RUN = None
            continue
        except Exception as exc:            # noqa: BLE001 — keep going
            failures.append((name, f"{type(exc).__name__}: {exc}"))
            _RUN = None
            continue
        for row in scenario_rows:
            print(row.csv(), flush=True)
        _record, events = _RUN.finalize()
        _RUN = None
        for event in events:
            print(f"[{name}] {event}", file=sys.stderr)
        regressions = [e for e in events if e.is_regression]
        if regressions:
            failures.append(
                (name, f"{len(regressions)} regression(s): "
                       + "; ".join(e.metric for e in regressions)))

    # combined verdict (stderr: stdout is the CSV contract)
    passed = len(scenarios) - len(failures)
    print(f"bench: {passed}/{len(scenarios)} scenario(s) passed",
          file=sys.stderr)
    for name, why in failures:
        print(f"  FAIL {name}: {why}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
