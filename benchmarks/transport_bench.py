"""Paper Fig. 2: data transport duration, Thallus vs Thallium RPC, across
column-selectivity (result-set size). Expect up to ~5.5× and a gain that
shrinks as the result set shrinks (constant RDMA setup costs dominate)."""
from __future__ import annotations

from repro.core import RpcClient, ThallusClient, ThallusServer
from repro.engine import Engine, make_numeric_table

from .common import Row, calibrated_fabric

TOTAL_COLS = 8


def _server(nrows: int) -> ThallusServer:
    eng = Engine()
    eng.register("/d", make_numeric_table("t", nrows, TOTAL_COLS,
                                          batch_rows=min(nrows, 1 << 18)))
    return ThallusServer(eng, calibrated_fabric())


def run() -> list[Row]:
    rows: list[Row] = []
    # -- column-selectivity sweep at a large result set (Fig 2 shape) -------
    for nrows, tag in ((1 << 20, "1M"), (1 << 14, "16k"), (1 << 10, "1k")):
        server = _server(nrows)
        for ncols in (1, 2, 4, 8):
            sql = "SELECT " + ", ".join(f"c{i}" for i in range(ncols)) + " FROM t"

            def med(cls):
                ts = []
                for _ in range(3):
                    c = cls(server)
                    c.run_query(sql, "/d")
                    ts.append(c.transport_seconds())
                return sorted(ts)[1]

            t_rpc, t_th = med(RpcClient), med(ThallusClient)
            rows.append(Row(
                f"transport_rows{tag}_cols{ncols}", t_th * 1e6,
                f"speedup={t_rpc / t_th:.2f}x rpc_us={t_rpc*1e6:.1f}"))
    return rows
