"""Roofline table from the dry-run artifacts (launch/dryrun.py must have
populated artifacts/dryrun/*.json). One row per (arch × shape × mesh)."""
from __future__ import annotations

import glob
import json
import os

from .common import Row

ART_DIR = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")


def run() -> list[Row]:
    rows: list[Row] = []
    paths = sorted(glob.glob(os.path.join(ART_DIR, "*.json")))
    if not paths:
        return [Row("roofline_missing", 0.0,
                    "run: PYTHONPATH=src python -m repro.launch.dryrun --all")]
    for p in paths:
        with open(p) as f:
            art = json.load(f)
        name = f"roofline_{art['arch']}_{art['shape']}_" \
               f"{'x'.join(str(v) for v in art['mesh'].values())}"
        if art["status"] != "ok":
            rows.append(Row(name, 0.0, art["status"]))
            continue
        r = art["roofline"]
        rows.append(Row(
            name, r["step_s"] * 1e6,
            f"bottleneck={r['bottleneck']} C={r['compute_s']*1e3:.1f}ms "
            f"M={r['memory_s']*1e3:.1f}ms X={r['collective_s']*1e3:.1f}ms "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"mfu_bound={r['mfu_bound']:.3f} "
            f"fits={art['memory'].get('fits_hbm')}"))
    return rows
