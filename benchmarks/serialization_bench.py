"""Paper §2: serialization overhead in the RPC baseline.

The paper measures ~30 % of RPC duration spent serializing a record batch
and ~0.0004 % deserializing (zero-copy views). We reproduce the measurement
with SELECT-all-columns over a wide numeric table: serialize/deserialize are
REAL memcpys on this host; the wire is the calibrated fabric model.
"""
from __future__ import annotations

from repro.core import RpcClient, ThallusServer
from repro.engine import Engine, make_numeric_table

from .common import Row, calibrated_fabric


def run() -> list[Row]:
    rows = []
    for nrows in (1 << 16, 1 << 20):
        eng = Engine()
        eng.register("/d", make_numeric_table("t", nrows, 8,
                                              batch_rows=min(nrows, 1 << 18)))
        server = ThallusServer(eng, calibrated_fabric())
        client = RpcClient(server)
        client.run_query("SELECT * FROM t", "/d")
        ser = sum(s.serialize_s for s in client.stats)
        de = sum(s.deserialize_s for s in client.stats)
        total = sum(s.total_s for s in client.stats)
        rows.append(Row(f"serialize_fraction_rows{nrows}", ser / len(client.stats) * 1e6,
                        f"ser={ser/total:.1%} (paper ~30%) de={de/total:.2%} "
                        f"(paper ~0%)"))
    return rows
