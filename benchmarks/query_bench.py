"""Paper Fig. 3: END-TO-END query execution duration (engine execution +
transport), Thallus vs RPC. Expect up to ~2.5×: the engine time is common to
both, so the e2e gain is smaller than the transport-only gain — and it
shrinks with the result set, same as Fig. 2."""
from __future__ import annotations

import time

from repro.core import RpcClient, ThallusClient, ThallusServer
from repro.engine import Engine, make_numeric_table

from .common import Row, calibrated_fabric

TOTAL_COLS = 8


def _server(nrows: int) -> ThallusServer:
    eng = Engine()
    eng.register("/d", make_numeric_table("t", nrows, TOTAL_COLS,
                                          batch_rows=min(nrows, 1 << 18)))
    return ThallusServer(eng, calibrated_fabric())


def _e2e_seconds(client_cls, server, sql) -> float:
    """median of 3: engine time measured for real; transport per the
    decomposed stats (host costs measured, NIC costs modeled)."""
    ts = []
    for _ in range(3):
        client = client_cls(server)
        t0 = time.perf_counter()
        client.run_query(sql, "/d")
        wall = time.perf_counter() - t0
        measured_wire = sum(s.wire.measured_copy_s for s in client.stats)
        engine_s = max(wall - client.transport_seconds() - measured_wire, 0.0)
        ts.append(engine_s + client.transport_seconds())
    return sorted(ts)[1]


def run() -> list[Row]:
    rows: list[Row] = []
    for nrows, tag in ((1 << 20, "1M"), (1 << 14, "16k")):
        server = _server(nrows)
        for ncols in (2, 8):
            sql = "SELECT " + ", ".join(f"c{i}" for i in range(ncols)) + " FROM t"
            t_rpc = _e2e_seconds(RpcClient, server, sql)
            t_th = _e2e_seconds(ThallusClient, server, sql)
            rows.append(Row(
                f"query_e2e_rows{tag}_cols{ncols}", t_th * 1e6,
                f"speedup={t_rpc / t_th:.2f}x rpc_us={t_rpc*1e6:.1f}"))
        # filtered query: smaller result set through the same scan
        sql = "SELECT c0, c1 FROM t WHERE c0 > 1.5"
        t_rpc = _e2e_seconds(RpcClient, server, sql)
        t_th = _e2e_seconds(ThallusClient, server, sql)
        rows.append(Row(f"query_e2e_rows{tag}_filtered", t_th * 1e6,
                        f"speedup={t_rpc / t_th:.2f}x"))
    return rows
