"""Device-side kernel micro-bench (beyond paper): the serialization pack the
baseline pays, the take-gather behind column selectivity, bitmap expand.

Wall times here are interpret-mode (CPU) — meaningful for relative shape
scaling only; the derived column reports the DMA-roofline time the tile
layout implies on TPU v5e (bytes / 819 GB/s), which is the perf target.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.pack import pack_segments, packed_nbytes
from repro.kernels.take import expand_validity, take_column
from repro.utils.roofline import HBM_BW

from .common import Row, timeit


def run() -> list[Row]:
    rng = np.random.default_rng(0)
    rows: list[Row] = []

    for nseg, seg_bytes in ((8, 1 << 16), (32, 1 << 20)):
        segs = [rng.integers(0, 255, seg_bytes, dtype=np.uint8)
                for _ in range(nseg)]
        t = timeit(lambda: pack_segments(segs), repeats=3)
        total = packed_nbytes([s.nbytes for s in segs])
        roof_us = 2 * total / HBM_BW * 1e6      # read + write
        rows.append(Row(f"pack_kernel_{nseg}x{seg_bytes}B", t * 1e6,
                        f"tpu_roofline_us={roof_us:.1f}"))

    vals = rng.standard_normal((1 << 14, 128)).astype(np.float32)
    idx = rng.integers(0, 1 << 14, 1 << 12).astype(np.int32)
    t = timeit(lambda: take_column(vals, idx), repeats=3)
    moved = idx.size * 128 * 4 * 2
    rows.append(Row("take_4096rows_w128", t * 1e6,
                    f"tpu_roofline_us={moved / HBM_BW * 1e6:.2f}"))

    bm = np.packbits(rng.integers(0, 2, 1 << 20).astype(bool),
                     bitorder="little")
    t = timeit(lambda: expand_validity(bm, 1 << 20), repeats=3)
    moved = bm.nbytes + (1 << 20)
    rows.append(Row("bitmap_expand_1Mbits", t * 1e6,
                    f"tpu_roofline_us={moved / HBM_BW * 1e6:.2f}"))
    return rows
