"""Benchmark harness — one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV. Paper artifacts:

  serialization_bench — §2   (~30 % serialize / ~0 % deserialize)
  transport_bench     — Fig 2 (transport duration, up to ~5.5×)
  query_bench         — Fig 3 (end-to-end duration, up to ~2.5×)
  kernel_bench        — device-side pack/take/bitmap (beyond paper)
  roofline_bench      — §Roofline table from dry-run artifacts
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (kernel_bench, query_bench, roofline_bench,
                   serialization_bench, transport_bench)

    modules = [
        ("serialization", serialization_bench),
        ("transport", transport_bench),
        ("query", query_bench),
        ("kernel", kernel_bench),
        ("roofline", roofline_bench),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for tag, mod in modules:
        if only and only != tag:
            continue
        for row in mod.run():
            print(row.csv(), flush=True)


if __name__ == "__main__":
    main()
