"""Generate EXPERIMENTS.md from dry-run artifacts + the §Perf log.

    PYTHONPATH=src python scripts/gen_experiments.py
"""
import json
import sys

sys.path.insert(0, "src")

from repro.utils.report import (dryrun_table, load_artifacts, mesh_tag,
                                roofline_table, summary_stats)

PERF_LOG = "scripts/perf_log.md"

HEADER = """# EXPERIMENTS — Thallus on TPU

Environment: CPU-only container (TPU v5e is the *target*), jax 0.8.2.
Dry-runs lower + compile on 512 placeholder host devices
(``--xla_force_host_platform_device_count=512``); kernels validate in Pallas
interpret mode; the wire in the paper benchmarks is the calibrated fabric
model of DESIGN.md §8 with **measured** host memcpys.

Hardware constants (roofline): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI per chip (v5e class, per assignment).

## §Paper-claims validation

``PYTHONPATH=src python -m benchmarks.run`` (see bench_output.txt for the
recorded run; constants calibrated to this host's memcpy bandwidth —
DESIGN.md §8):

| paper claim | repro result | artifact |
|---|---|---|
| §2: ~30 % of RPC duration is serialization | **48–66 % measured** (bench_output.txt): our pack is Python/numpy with a JSON header, ~2× slower than the paper's C++ memcpy pack relative to the wire — the fraction is calibration-dependent; the ASYMMETRY (serialize costly, deserialize free) reproduces exactly | serialization_bench |
| §2: deserialization ~0 % (zero-copy views) | **0.5–3.6 % measured**; unpack is view construction (`test_deserialize_is_zero_copy` asserts the aliasing) | serialization_bench |
| Fig 2: transport up to 5.5×, shrinking with result size | **4.4–7× at 1k–16k rows, up to 9× at 1M** (speedup grows with result size — the paper's trend; the overshoot at 1M tracks the inflated serialize fraction above) | transport_bench |
| Fig 3: end-to-end query up to 2.5× | **1.95–2.21× on 16k-row scans; 1.04–1.25× on filtered (engine-heavy) queries** — squarely the paper's ≤2.5× envelope; select-all over 1M rows overshoots because our engine's share of e2e time is smaller than DuckDB's was | query_bench |
| zero-copy invariants | expose/assemble alias checks + hypothesis property suite (`tests/test_transport.py`, `tests/test_property.py`) | pytest |

## §Dry-run

Every (architecture × shape) cell lowered AND compiled with
``jax.jit(...).lower(...).compile()`` on the production meshes; artifacts in
``artifacts/dryrun*/``. ``memory_analysis()`` / ``cost_analysis()`` excerpts
below; collective counts are trip-count-aware (``repro.utils.hlo_cost``
multiplies ``while`` bodies by their ``known_trip_count`` — XLA's own
cost_analysis counts scan bodies once, see §Roofline notes).
"""

ROOFLINE_INTRO = """
## §Roofline

Terms per device: compute = HLO_FLOPs/197e12; memory = HLO_bytes/819e9;
collective = ring-model wire bytes/50e9. Two memory accountings are
reported: **HLO** (every HLO-level tensor handoff = HBM traffic — what THIS
XLA program would do) and **fused** (attention/SSD interiors marked
``vmem_fused_attention`` are VMEM-resident — the behaviour of the Pallas
flash/SSD kernels on real TPU; kernels/ carries the interpret-validated
kernels). ``useful`` = MODEL_FLOPS (6·N·D train / 2·N·D inference, N_active
for MoE) / HLO FLOPs; ``MFU bound`` = useful work over peak at the
bottleneck-dictated step time, i.e. the roofline fraction the lowered
program permits. `mfu` uses the fused memory term.

`long_500k` runs for zamba2-1.2b and mamba2-780m (sub-quadratic families);
the eight full-attention archs skip it per the assignment rule
(DESIGN.md §4). All other 32 cells compile on both meshes.

**Known multi-pod anomalies** (compile fine — the deliverable — but with
inflated temps): XLA SPMD resolves some MoE dispatch reshapes across the
``pod`` axis by involuntary full rematerialization (its own warning cites
b/433785288): llama4 train temp 17.1 GiB, olmoe prefill temp 66 GiB on the
2×16×16 mesh only. Single-pod numbers are the §Roofline basis; the fix path
is a shard_map dispatch pinned to intra-pod groups (future work, §Perf
pair-2 lever).
"""


def main() -> None:
    base = load_artifacts("artifacts/dryrun_baseline")
    opt = load_artifacts("artifacts/dryrun")
    out = [HEADER]
    s1 = summary_stats([a for a in base if mesh_tag(a) == "16x16"])
    s2 = summary_stats([a for a in base if mesh_tag(a) == "2x16x16"])
    o1 = summary_stats([a for a in opt if mesh_tag(a) == "16x16"])
    o2 = summary_stats([a for a in opt if mesh_tag(a) == "2x16x16"])
    out.append(f"\n**Status.** baseline: single-pod 16×16 {s1['ok']} ok / "
               f"{s1['skipped']} skipped / {s1['errors']} errors; multi-pod "
               f"2×16×16 {s2['ok']} ok / {s2['skipped']} skipped / "
               f"{s2['errors']} errors. Optimized: {o1['ok']}+{o1['skipped']}"
               f" and {o2['ok']}+{o2['skipped']} (0 errors everywhere).\n")
    out.append("\n### Single-pod (16×16 = 256 chips), optimized rules\n")
    out.append(dryrun_table(opt, "16x16"))
    out.append("\n\n### Multi-pod (2×16×16 = 512 chips), optimized rules — "
               "proves the `pod` axis shards\n")
    out.append(dryrun_table(opt, "2x16x16"))

    out.append(ROOFLINE_INTRO)
    out.append("\n### Baseline (paper-faithful rules: head_dim attention "
               "fallback, global MoE dispatch), single-pod\n")
    out.append(roofline_table(base, "16x16"))
    out.append("\n\n### Optimized (beyond-paper rules: padded-head TP, "
               "local MoE dispatch, fused-attention memory model), "
               "single-pod\n")
    out.append(roofline_table(opt, "16x16"))
    out.append("\n\n### Optimized, multi-pod (2×16×16)\n")
    out.append(roofline_table(opt, "2x16x16"))

    with open(PERF_LOG) as f:
        out.append("\n" + f.read())

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(out) + "\n")
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
