"""Export a Chrome trace of one traced gateway workload.

Builds the qos contention fixture (heavy batch floods, interactive lookups
behind it) on a 4-shard cluster, runs it through a ``ScanGateway`` wired to
an ``obs.Tracer``, and writes every scan's spans — admission wait, WFQ queue
wait, lease RPC, RDMA pull, prefetch overlap, reassembly — as Chrome
``trace_event`` JSON. Load the output in ``chrome://tracing`` or
https://ui.perfetto.dev; the per-(cat, span) aggregates print on stdout.

    PYTHONPATH=src python scripts/export_trace.py --out artifacts/trace/scan_trace.json
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.cluster import ClusterCoordinator
from repro.core import Fabric, FabricConfig, ThallusServer
from repro.engine import Engine, make_numeric_table
from repro.obs import Tracer
from repro.qos import (AdmissionConfig, AdmissionController, ClientClass,
                       ScanGateway, ScanRequest)
from repro.utils.report import export_trace, trace_table

ROWS = 1 << 16
BATCH_ROWS = 1 << 13
SHARDS = 4
HEAVY_SQL = "SELECT c0, c1, c2, c3 FROM t"
LIGHT_SQL = "SELECT c0 FROM t"


def build_gateway(tracer: Tracer) -> ScanGateway:
    coordinator = ClusterCoordinator()
    for i in range(SHARDS):
        coordinator.add_server(f"s{i}",
                               ThallusServer(Engine(), Fabric(FabricConfig())))
    coordinator.place_shards("/d", make_numeric_table(
        "t", ROWS, 4, batch_rows=BATCH_ROWS))
    admission = AdmissionController(AdmissionConfig(
        max_streams_per_client=2, lease_rate_per_s=1e3, lease_burst=4))
    return ScanGateway(
        coordinator,
        classes=[ClientClass("interactive", 4.0), ClientClass("batch", 1.0)],
        admission=admission, tracer=tracer)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/trace/scan_trace.json")
    args = ap.parse_args()

    tracer = Tracer()
    gateway = build_gateway(tracer)
    for _ in range(2):
        gateway.submit(ScanRequest("heavy", "batch", HEAVY_SQL, "/d",
                                   cost_hint=8.0))
    for _ in range(3):
        gateway.submit(ScanRequest("ui", "interactive", LIGHT_SQL, "/d",
                                   cost_hint=1.0))
    gateway.run()

    path = export_trace(tracer, args.out)
    events = sum(len(ctx.spans) for ctx in tracer.contexts)
    print(trace_table(tracer))
    print(f"\nwrote {events} events across {len(tracer.contexts)} scan(s) "
          f"to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
