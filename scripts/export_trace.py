"""Export a Chrome trace of traced gateway workloads + the health/SLO lane.

Phase 1 builds the qos contention fixture (heavy batch floods, interactive
lookups behind it) on a 4-shard cluster and runs it through a ``ScanGateway``
wired to an ``obs.Tracer``: every scan's spans — admission wait, WFQ queue
wait, lease RPC, RDMA pull, prefetch overlap, reassembly — land as Chrome
``trace_event`` JSON.

Phase 2 reuses the slo benchmark's degraded geometry (a 5-replica scan with
a persistent straggler, a flapping replica, and a foreign tenant pinning
every admission shard but the flapper's) against the SAME tracer, flight
recorder and health monitor, heartbeat by heartbeat, with a deliberately
tight burn-rate objective so the demo always pages. Health transitions and
SLO alerts are then embedded as **instant events** on dedicated ``health`` /
``slo`` tracks, so the timeline shows the page next to the slow spans that
caused it.

Phase 3 runs a seeded stress-driver population mix (interactive lookups
under a Poisson scan storm) through its own gateway on the same tracer:
each beat lands per-population instants on ``workload.<pop>`` tracks
(grants, sheds, declines, beat p50) plus a ``workload.fairness`` track
carrying the rolling Jain index. Load the output in ``chrome://tracing`` or
https://ui.perfetto.dev; per-(cat, span) aggregates and the health table
print on stdout.

    PYTHONPATH=src python scripts/export_trace.py --out artifacts/trace/scan_trace.json
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.cluster import ClusterCoordinator
from repro.core import Fabric, FabricConfig, FlappingFabric, ThallusServer
from repro.engine import Engine, make_numeric_table
from repro.obs import (ClientPopulation, FlightRecorder, HealthMonitor,
                       MetricsRegistry, SloEngine, SloObjective, StressDriver,
                       Tracer, population_classes, record_cluster,
                       record_health)
from repro.qos import (AdmissionConfig, AdmissionController, ClientClass,
                       DistributedConfig, ScanGateway, ScanRequest,
                       ShardedAdmission)
from repro.sched import AdaptiveScheduler, RateHistory, StealConfig
from repro.utils.report import export_trace, health_table, trace_table

ROWS = 1 << 16
BATCH_ROWS = 1 << 13
SHARDS = 4
HEAVY_SQL = "SELECT c0, c1, c2, c3 FROM t"
LIGHT_SQL = "SELECT c0 FROM t"
REPLICA_IDS = ["r0", "r1", "r2", "r3", "r4"]
STRAGGLER, FLAPPER = "r2", "r3"     # r2 leased (sorted first 3), r3 idle
HEARTBEATS = 4


def build_gateway(tracer: Tracer) -> ScanGateway:
    coordinator = ClusterCoordinator()
    for i in range(SHARDS):
        coordinator.add_server(f"s{i}",
                               ThallusServer(Engine(), Fabric(FabricConfig())))
    coordinator.place_shards("/d", make_numeric_table(
        "t", ROWS, 4, batch_rows=BATCH_ROWS))
    admission = AdmissionController(AdmissionConfig(
        max_streams_per_client=2, lease_rate_per_s=1e3, lease_burst=4))
    return ScanGateway(
        coordinator,
        classes=[ClientClass("interactive", 4.0), ClientClass("batch", 1.0)],
        admission=admission, tracer=tracer)


def build_degraded_gateway(tracer: Tracer, recorder: FlightRecorder,
                           health: HealthMonitor,
                           history: RateHistory) -> ScanGateway:
    """The slo benchmark's decision geometry, on the shared obs spine."""
    base = FabricConfig()
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=2 * len(REPLICA_IDS)), REPLICA_IDS,
        dist=DistributedConfig(borrow_limit=0))
    admission.recorder = recorder
    coordinator = ClusterCoordinator(admission=admission, recorder=recorder,
                                     health=health)
    for sid in REPLICA_IDS:
        if sid == STRAGGLER:
            fabric = FlappingFabric(base, schedule=[4.0])
        elif sid == FLAPPER:
            fabric = FlappingFabric(base, schedule=(4.0, 1.0))
        else:
            fabric = Fabric(base)
        coordinator.add_server(sid, ThallusServer(Engine(), fabric))
    coordinator.place_replicas("/r", make_numeric_table(
        "t", 24 * BATCH_ROWS, 4, batch_rows=BATCH_ROWS))
    for sid in REPLICA_IDS:        # steals land on the flapper, decline on r4
        if sid != FLAPPER:
            admission.acquire_stream("foreign", server_id=sid)
    health.bind(history=history, admission=admission)
    return ScanGateway(
        coordinator,
        classes=[ClientClass("interactive", 4.0), ClientClass("batch", 1.0)],
        scheduler=AdaptiveScheduler(
            steal=StealConfig(steal_headroom_min=2), history=history),
        tracer=tracer)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts/trace/scan_trace.json")
    args = ap.parse_args()

    tracer = Tracer()
    recorder = FlightRecorder()
    health = HealthMonitor(recorder=recorder)
    history = RateHistory(quarantine_rounds=64)
    engine = SloEngine()

    # ---- phase 1: the contention fixture (spans only) ---------------------
    gateway = build_gateway(tracer)
    for _ in range(2):
        gateway.submit(ScanRequest("heavy", "batch", HEAVY_SQL, "/d",
                                   cost_hint=8.0))
    for _ in range(3):
        gateway.submit(ScanRequest("ui", "interactive", LIGHT_SQL, "/d",
                                   cost_hint=1.0))
    gateway.run()

    # ---- phase 2: degraded replicas, heartbeat by heartbeat ---------------
    degraded = build_degraded_gateway(tracer, recorder, health, history)
    for hb in range(HEARTBEATS):
        req = degraded.submit(ScanRequest(
            "probe", "batch", "SELECT c0, c1 FROM t", "/r", cost_hint=8.0,
            arrival_s=degraded.clock_s, num_streams=3))
        degraded.run()
        result = degraded.results[req.request_id]
        now = degraded.clock_s
        degraded.coordinator.heartbeat(now)
        reg = MetricsRegistry()
        record_cluster(reg, result.cluster)
        record_health(reg, health)
        if hb == 0:      # deliberately tight: the demo must page
            engine.add(SloObjective(
                "probe-critical-path", "cluster.modeled_critical_path.us",
                target=0.95 * result.cluster.modeled_critical_path_s * 1e6,
                better="lower", goal=0.75,
                windows=((1e3, 1.2), (1.0, 1.2)), min_samples=3))
        fired = engine.observe(now, reg.snapshot())
        degraded.stats.alerts += len(fired)

    # ---- phase 3: a stress-driver mix, one workload lane per population --
    pops = [
        ClientPopulation("interactive", weight=4.0, arrival="uniform",
                         rate_per_beat=2.0, sql=LIGHT_SQL, dataset="/w",
                         num_streams=2),
        ClientPopulation("storm", weight=2.0, arrival="poisson",
                         rate_per_beat=3.0, sql=HEAVY_SQL, dataset="/w",
                         cost_hint=8.0, cost_jitter=0.3, num_streams=2,
                         start_beat=2),
    ]
    stress_coord = ClusterCoordinator(recorder=recorder)
    for i in range(SHARDS):
        stress_coord.add_server(
            f"w{i}", ThallusServer(Engine(), Fabric(FabricConfig())))
    stress_coord.place_replicas("/w", make_numeric_table(
        "t", 8 * BATCH_ROWS, 4, batch_rows=BATCH_ROWS))
    driver = StressDriver(
        ScanGateway(stress_coord, classes=population_classes(pops),
                    tracer=tracer, modeled_service=True),
        pops, seed=7)
    wl = tracer.begin("workload")
    for _ in range(5):
        report = driver.beat()
        for name, beat in sorted(driver.beat_stats.items()):
            if not (beat["submitted"] or beat["shed"] or beat["declines"]):
                continue
            wl.instant(
                f"{name}: {beat['granted']}/{beat['submitted']} "
                f"p50={beat['p50_grant_us']:.0f}us",
                report.now_s, track=f"workload.{name}", cat="workload",
                shed=beat["shed"], declines=beat["declines"])
        fair = driver.fairness()
        wl.instant(f"jain={fair['jain']:.3f}", report.now_s,
                   track="workload.fairness", cat="workload",
                   inflation=round(fair["latency_inflation"], 2))
    wl.commit()

    # ---- the health/slo lane: transitions + alerts as instant events -----
    lane = tracer.begin("health+slo")
    for t in health.transitions:
        lane.instant(f"{t.server_id}: {t.frm}->{t.to}", t.now_s,
                     track="health", cat="health", reason=t.reason)
    for alert in engine.alerts:
        lane.instant(f"SLO page: {alert.objective}", alert.now_s,
                     track="slo", cat="slo", value=alert.value,
                     target=alert.target,
                     burns=[round(b, 2) for b in alert.burns])
    for sid, state in sorted(health.states().items()):
        lane.instant(f"{sid}={state}", degraded.clock_s,
                     track="health", cat="health", final=True)
    lane.commit()

    path = export_trace(tracer, args.out)
    events = sum(len(ctx.spans) for ctx in tracer.contexts)
    print(trace_table(tracer))
    print()
    print(health_table(health))
    fair = driver.fairness()
    print(f"\nalerts={len(engine.alerts)} "
          f"recorder_events={len(recorder)} "
          f"workload_beats={driver.beats} jain={fair['jain']:.3f}")
    print(f"wrote {events} events across {len(tracer.contexts)} context(s) "
          f"to {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
