"""Training substrate: optimizer math, compression, checkpoint/restart,
loader integration, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Fabric, ThallusServer
from repro.data import ThallusLoader, make_token_table, shift_labels
from repro.engine import Engine
from repro.training import (CheckpointManager, OptimizerConfig, TrainConfig,
                            compress_decompress, compression_wire_bytes,
                            dequantize_int8, global_norm, init_train_state,
                            lr_at, make_train_step, quantize_int8)


def test_lr_schedule():
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    end = float(lr_at(cfg, jnp.int32(100)))
    assert abs(end - 1e-4) < 1e-8
    mid = float(lr_at(cfg, jnp.int32(55)))
    assert end < mid < 1e-3


def test_adamw_descends_quadratic():
    """AdamW on f(w) = |w|^2 must descend."""
    from repro.training import adamw_update, init_opt_state
    cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=0, decay_steps=1000,
                          weight_decay=0.0, grad_clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(cfg, params)
    for step in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, metrics = adamw_update(cfg, grads, state, params,
                                              jnp.int32(step))
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_quantization_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) / 2 + 1e-9          # half-ulp bound
    # error feedback: accumulated deq over steps tracks accumulated x
    ef = jnp.zeros_like(x)
    total_deq = jnp.zeros_like(x)
    for _ in range(20):
        deq, ef = compress_decompress(x, ef)
        total_deq = total_deq + deq
    drift = np.abs(np.asarray(total_deq - 20 * x)).max()
    assert drift <= float(s) + 1e-9            # EF keeps drift bounded


def test_compression_wire_savings():
    params = {"a": jnp.zeros((1000,)), "b": jnp.zeros((50, 50))}
    fp32, int8 = compression_wire_bytes(params)
    assert fp32 == 4 * 3500
    assert int8 < fp32 / 3.9


def test_microbatch_equivalence(rng):
    """grad accumulation over k microbatches == single big batch (linearity
    of mean loss in batch partitions)."""
    cfg = get_config("olmoe-1b-7b").reduced()
    B, S = 4, 8
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    opt = OptimizerConfig(learning_rate=1e-3, warmup_steps=0, decay_steps=10)
    s1 = init_train_state(cfg, TrainConfig(optimizer=opt, remat="none"),
                          jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    out1, m1 = make_train_step(cfg, TrainConfig(optimizer=opt, remat="none"))(s1, batch)
    out2, m2 = make_train_step(cfg, TrainConfig(optimizer=opt, remat="none",
                                                microbatches=2))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_checkpoint_restart_loss_continuity(tmp_path, rng):
    """Kill/restart: the resumed run's next loss equals the uninterrupted
    run's — byte-identical state restore."""
    cfg = get_config("granite-3-2b").reduced()
    tcfg = TrainConfig(optimizer=OptimizerConfig(learning_rate=1e-3,
                                                 warmup_steps=2,
                                                 decay_steps=50),
                       remat="none")
    step_fn = make_train_step(cfg, tcfg)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    batches = []
    for i in range(6):
        t = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
        batches.append({"tokens": t, "labels": t})
    # uninterrupted
    ref = state
    ref_losses = []
    for b in batches:
        ref, m = step_fn(ref, b)
        ref_losses.append(float(m["loss"]))
    # interrupted at step 3
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    cur = state
    for b in batches[:3]:
        cur, m = step_fn(cur, b)
    mgr.save(int(cur["step"]), cur, cursors={"batch_offset": 3})
    restored, man = mgr.restore_latest(like=cur)
    assert man.cursors["batch_offset"] == 3
    resumed_losses = []
    cur = restored
    for b in batches[3:]:
        cur, m = step_fn(cur, b)
        resumed_losses.append(float(m["loss"]))
    np.testing.assert_allclose(resumed_losses, ref_losses[3:], rtol=1e-6)


def test_checkpoint_gc_and_latest(tmp_path):
    cfg = get_config("granite-3-2b").reduced()
    tcfg = TrainConfig(remat="none")
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        state["step"] = jnp.int32(s)
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_loader_end_to_end_and_resume(rng):
    eng = Engine()
    eng.register("/d", make_token_table("tok", 64, 32, 1000, seqs_per_batch=16))
    srv = ThallusServer(eng, Fabric())
    loader = ThallusLoader([srv], "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=8)
    all_batches = list(loader)
    assert len(all_batches) == 8
    assert all(b["tokens"].shape == (8, 32) for b in all_batches)
    lbl = all_batches[0]["labels"]
    np.testing.assert_array_equal(lbl[:, :-1], all_batches[0]["tokens"][:, 1:])
    assert (lbl[:, -1] == -1).all()
    # resume from cursor offset 2: skips the first two record batches
    loader2 = ThallusLoader([srv], "SELECT tokens FROM tok", "/d",
                            seq_len=32, batch_seqs=8, start_batch=2)
    rest = list(loader2)
    assert len(rest) == 4
    np.testing.assert_array_equal(rest[0]["tokens"], all_batches[4]["tokens"])


def test_loader_straggler_backup():
    eng = Engine()
    eng.register("/d", make_token_table("tok", 32, 16, 100, seqs_per_batch=16))
    slow = ThallusServer(eng, Fabric())
    eng2 = Engine()
    eng2.register("/d", make_token_table("tok", 32, 16, 100, seqs_per_batch=16))
    fast = ThallusServer(eng2, Fabric())
    loader = ThallusLoader([slow, fast], "SELECT tokens FROM tok", "/d",
                           seq_len=16, batch_seqs=8,
                           straggler_deadline_s=0.0)    # everything straggles
    out = list(loader)
    assert loader.stats.backup_requests > 0
    assert len(out) == 4
    # regression: backups must substitute the SAME batch (replicas share the
    # seed, so a wrong start_batch index would surface as different tokens)
    ref = list(ThallusLoader([slow], "SELECT tokens FROM tok", "/d",
                             seq_len=16, batch_seqs=8))
    for got, want in zip(out, ref):
        np.testing.assert_array_equal(got["tokens"], want["tokens"])


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint saved unsharded restores onto a (1,1) host mesh with
    param specs — the elastic path."""
    from repro.launch.mesh import make_host_mesh
    from repro.models import param_specs
    cfg = get_config("granite-3-2b").reduced()
    tcfg = TrainConfig(remat="none")
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state)
    mesh = make_host_mesh()
    pspecs = param_specs(cfg, state["params"], mesh)
    from jax.sharding import PartitionSpec as P
    specs = {"params": pspecs, "opt": {k: pspecs for k in state["opt"]},
             "step": P()}
    restored, _ = mgr.restore(7, like=state, mesh=mesh, specs=specs)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
