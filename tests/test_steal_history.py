"""Shard-aware steal hysteresis (repro.sched.RateHistory): EWMA/flap
mechanics, repeat-straggler thresholds across scans, flap quarantine (victim
AND thief side), thief-side admission declines with next-fastest fallback and
freed-slot retry, victim re-steal from a degraded thief (byte-identical, one
re-steal per range), the PR 3 conformance replay, and the per-shard
StealEvent attribution through metrics and report tables."""
import dataclasses
import types

import numpy as np
import pytest
from conftest import (STRAGGLER_SQL, STRAGGLER_TRACE, make_coordinator,
                      reference_batches, steal_event_trace,
                      straggler_coordinator)

from repro.cluster import ClusterCoordinator
from repro.core import Fabric, FabricConfig, FlappingFabric, ThallusServer
from repro.engine import Engine, make_numeric_table
from repro.qos import (AdmissionConfig, AdmissionController,
                       DistributedConfig, ShardedAdmission)
from repro.sched import (AdaptiveScheduler, RateHistory, StealConfig,
                         StealingPuller)

ROWS = 1 << 17
BATCH_ROWS = 1 << 13                     # -> 16 batches of ~128 KiB wire
SQL = STRAGGLER_SQL
TABLE = make_numeric_table("t", ROWS, 4, batch_rows=BATCH_ROWS)
BASE = FabricConfig()
SLOW4 = FabricConfig(rpc_bw=BASE.rpc_bw / 4, rdma_bw=BASE.rdma_bw / 4)


def _assert_batches_equal(got, ref):
    assert len(got) == len(ref)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)
        np.testing.assert_array_equal(g.column("c1").values,
                                      r.column("c1").values)


def _flat(puller, got):
    order = sorted(range(len(puller.pullers)),
                   key=lambda i: puller.pullers[i].endpoint.start_batch)
    return [b for i in order for b in got.get(i, [])]


def _cluster(slow=None, slowdown=4.0, admission=None):
    return make_coordinator(4, "replica", table=TABLE, admission=admission,
                            slow=slow, slowdown=slowdown)


# ------------------------------------------------------------- rate history


def test_history_ewma_tracks_within_observed_bounds():
    hist = RateHistory(alpha=0.4)
    rates = [4.0, 1.0, 2.5, 8.0, 0.5]
    for r in rates:
        hist.observe("s0", r)
    h = hist.server("s0")
    assert h.observations == len(rates)
    assert min(rates) <= h.rate_s <= max(rates)
    assert hist.rate_for("s0") == h.rate_s
    assert hist.rate_for("nobody") is None
    # non-positive rates are ignored, not folded in
    hist.observe("s0", 0.0)
    assert h.observations == len(rates)


def test_history_validation():
    for bad in (dict(alpha=0.0), dict(alpha=1.5), dict(flap_ratio=1.0),
                dict(quarantine_rounds=0), dict(repeat_decay=0.0),
                dict(min_factor=0.9)):
        with pytest.raises(ValueError):
            RateHistory(**bad)
    with pytest.raises(ValueError):
        StealConfig(steal_headroom_min=0)
    with pytest.raises(ValueError):
        StealConfig(resteal_margin=0.9)


def test_flap_quarantine_lasts_exactly_k_rounds():
    K = 5
    hist = RateHistory(flap_ratio=2.0, quarantine_rounds=K)
    hist.observe("s0", 1.0)
    hist.observe("s0", 4.0)              # sharp slow-down: direction set
    assert not hist.quarantined("s0")    # one move is not a flap
    hist.observe("s0", 1.0)              # reversal -> flap
    assert hist.server("s0").flaps == 1
    for round_no in range(K):
        assert hist.quarantined("s0"), f"lifted early at round {round_no}"
        hist.tick()
    assert not hist.quarantined("s0")    # lifts exactly at K
    assert hist.total_flaps == 1


def test_monotonic_degradation_is_not_a_flap():
    hist = RateHistory(flap_ratio=2.0)
    for r in (1.0, 4.0, 16.0, 64.0):     # steadily worse, never reverses
        hist.observe("s0", r)
    assert hist.server("s0").flaps == 0
    assert not hist.quarantined("s0")


def test_repeat_straggler_factor_decays_to_floor():
    hist = RateHistory(repeat_decay=0.6, min_factor=1.1)
    assert hist.factor_for("s0", 2.0) == 2.0
    hist.record_steal("s0")
    assert hist.factor_for("s0", 2.0) == pytest.approx(2.0 * 0.6)
    for _ in range(8):
        hist.record_steal("s0")
    assert hist.factor_for("s0", 2.0) == 1.1     # floored
    assert hist.total_steals == 9
    assert hist.factor_for("s1", 2.0) == 2.0     # per-server, not global


# ----------------------------------------------- hysteresis across two scans


def _mild_straggler_coordinator(factor):
    """Replica cluster whose s3 degrades by ``factor`` on the RDMA path —
    under the static 2x threshold when factor ~1.9 (modeled wire includes
    constant setup/registration terms, so the observed ratio is lower)."""
    coord = ClusterCoordinator()
    for i in range(3):
        coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric(BASE)))
    coord.add_server("s3", ThallusServer(
        Engine(), FlappingFabric(BASE, schedule=[factor])))
    coord.place_replicas("/d", TABLE)
    return coord


def test_repeat_straggler_stolen_earlier_on_second_scan():
    """Scan 1: s3 is 4x slow — both static and history-aware stealing fire.
    Scan 2: s3 degrades only mildly (under the static threshold) — only the
    history, carrying scan 1's verdict, steals; the makespan improves."""
    config = StealConfig(min_batches=1)  # the mild tail is short-lived
    static_runs = {}
    for scan, factor in ((1, 4.0), (2, 2.1)):
        coord = _mild_straggler_coordinator(factor)
        stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                               steal=config).run()
        static_runs[scan] = stats
    assert static_runs[1].steals >= 1
    assert static_runs[2].steals == 0    # static factor is blind to repeats

    hist = RateHistory()
    hist_runs = {}
    for scan, factor in ((1, 4.0), (2, 2.1)):
        coord = _mild_straggler_coordinator(factor)
        hist_runs[scan] = StealingPuller(coord, coord.plan(SQL, "/d"),
                                         steal=config,
                                         history=hist).run()
    assert hist_runs[1].steals >= 1      # scan 1 records the offense
    assert hist.factor_for("s3", 2.0) < 2.0
    assert hist_runs[2].steals >= 1      # ...so scan 2 fires earlier
    assert (hist_runs[2].modeled_critical_path_s
            < static_runs[2].modeled_critical_path_s)


def test_quarantined_server_is_not_a_victim():
    """A 4x straggler that the history has quarantined for flapping is left
    alone — stealing from a server whose rate estimate is untrustworthy is
    churn — and the scan still completes byte-identically."""
    hist = RateHistory(quarantine_rounds=10_000)
    hist.observe("s3", 1.0)
    hist.observe("s3", 4.0)
    hist.observe("s3", 1.0)              # flap -> quarantined
    assert hist.quarantined("s3")
    coord = _cluster(slow=3)
    got = {}
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(), history=hist)
    stats = puller.run(lambda i, b: got.setdefault(i, []).append(b))
    assert stats.steals == 0
    _assert_batches_equal(_flat(puller, got),
                          reference_batches(SQL, table=TABLE))


def test_quarantined_server_is_not_a_thief():
    """With the (otherwise fastest) idle replica quarantined, a stolen tail
    lands on the next candidate instead."""
    hist = RateHistory(quarantine_rounds=10_000)
    hist.observe("s0", 1.0)
    hist.observe("s0", 4.0)
    hist.observe("s0", 1.0)              # s0 flaps -> may not thieve
    coord = _cluster(slow=3)
    stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                           steal=StealConfig(), history=hist).run()
    assert stats.steals >= 1
    assert all(e.thief != "s0" for e in stats.steal_events)
    # the PR 3 trace proves s0 is the thief when nothing is quarantined
    assert STRAGGLER_TRACE[0][1] == "s0"


# ------------------------------------------------- shard-aware steal declines


def _sharded_cluster(total_cap=6):
    """3-replica cluster (s2 4x slow) behind per-server admission shards
    with borrowing off — shard capacities stay at their dealt slices, so
    local headroom is exact."""
    adm = ShardedAdmission(AdmissionConfig(max_streams_total=total_cap),
                           ["s0", "s1", "s2"],
                           dist=DistributedConfig(borrow_limit=0))
    coord = ClusterCoordinator(admission=adm)
    for sid, cfg in (("s0", BASE), ("s1", BASE), ("s2", SLOW4)):
        coord.add_server(sid, ThallusServer(Engine(), Fabric(cfg)))
    coord.place_replicas("/d", TABLE)
    return coord, adm


def test_thief_at_shard_quota_declines_and_next_fastest_is_chosen():
    """Every shard's second slot is held by a foreign tenant; a drained
    thief's own freed slot leaves headroom 1 < steal_headroom_min, so the
    first candidate declines — until one shard's foreign stream closes and
    the steal lands there."""
    coord, adm = _sharded_cluster()
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(steal_headroom_min=2),
                            history=RateHistory(), client_id="c")
    adm.acquire_stream("f", server_id="s0")
    adm.acquire_stream("f", server_id="s1")
    adm.release_stream("f", server_id="s0")   # s0 drains ahead of the scan
    got = {}
    stats = puller.run(lambda i, b: got.setdefault(i, []).append(b))
    kinds = [(e.kind, e.server_id) for e in stats.steal_events]
    assert ("decline", "s1") in kinds          # s1 was full: declined
    assert stats.steals == 1
    steal = next(e for e in stats.steal_events if e.kind == "steal")
    assert steal.thief == "s0" and steal.server_id == "s0"
    assert steal.victim == "s2"
    _assert_batches_equal(_flat(puller, got),
                          reference_batches(SQL, table=TABLE))
    # the foreign slot was never evicted and no shard exceeded its slice
    for sid, shard in adm.shards.items():
        assert shard.stats.peak_active <= shard.config.max_streams_total


def test_declined_steal_retries_on_freed_slot_event():
    """With BOTH candidate shards full, every steal attempt declines and the
    straggler crawls — until a foreign stream closes mid-scan: the freed-slot
    event reopens that shard and the previously declined steal lands on it."""
    coord, adm = _sharded_cluster()
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(steal_headroom_min=2),
                            history=RateHistory(), client_id="c")
    adm.acquire_stream("f", server_id="s0")
    adm.acquire_stream("f", server_id="s1")
    released, got = False, {}
    for idx, batch in puller.batches():
        got.setdefault(idx, []).append(batch)
        if not released and puller.stats().declines >= 2:
            released = True
            adm.release_stream("f", server_id="s1")
    assert released, "both shards should have declined before any release"
    stats = puller.stats()
    assert stats.declines >= 2
    assert stats.steals == 1
    steal = next(e for e in stats.steal_events if e.kind == "steal")
    assert steal.thief == "s1"           # the shard the freed slot reopened
    # the decline for s1 was recorded BEFORE its retry succeeded
    decline_idx = next(i for i, e in enumerate(stats.steal_events)
                       if e.kind == "decline" and e.server_id == "s1")
    steal_idx = stats.steal_events.index(steal)
    assert decline_idx < steal_idx
    _assert_batches_equal(_flat(puller, got),
                          reference_batches(SQL, table=TABLE))


def test_steal_scheduler_unsubscribes_freed_slot_hook_on_drain():
    """Regression: one freed-slot listener per scan on a long-lived
    controller would grow without bound — the puller must retire its
    subscription when the drive loop ends."""
    coord, adm = _sharded_cluster()
    before = len(adm._release_cbs)
    for _ in range(3):
        StealingPuller(coord, coord.plan(SQL, "/d"),
                       steal=StealConfig(), history=RateHistory(),
                       client_id="c").run()
    assert len(adm._release_cbs) == before


def test_headroom_queries_are_local_and_duck_typed():
    adm = ShardedAdmission(AdmissionConfig(max_streams_per_client=4,
                                           max_streams_total=6),
                           ["s0", "s1"])
    # slices: quota 2+2, cap 3+3
    adm.acquire_stream("c", server_id="s0")
    adm.acquire_stream("c", server_id="s0")
    assert adm.headroom("s0", "c") == 0       # local quota slice exhausted...
    assert adm.headroom("s1", "c") == 2       # ...peer slack is NOT counted
    central = AdmissionController(AdmissionConfig(max_streams_per_client=3))
    central.acquire_stream("c")
    assert central.headroom("anywhere", "c") == 2
    assert AdmissionController().headroom() is None      # unlimited
    coord = ClusterCoordinator()
    assert coord.admission_headroom("s0") is None        # no controller
    coord.admission = central
    assert coord.admission_headroom("s0", "c") == 2
    coord.admission = object()                # no headroom query: no opinion
    assert coord.admission_headroom("s0") is None


# ------------------------------------------------------------------ re-steal


def _resteal_cluster(thief_schedule):
    """2 replicas: s0 fast then degrading per ``thief_schedule``; s1 a
    constant 4x straggler whose tail s0 steals."""
    coord = ClusterCoordinator()
    coord.add_server("s0", ThallusServer(
        Engine(), FlappingFabric(BASE, schedule=thief_schedule)))
    coord.add_server("s1", ThallusServer(Engine(), Fabric(SLOW4)))
    coord.place_replicas("/d", TABLE)
    return coord


def test_victim_resteals_degraded_thief_byte_identical():
    """s0 steals s1's tail, then degrades 8x; the recovered victim reclaims
    the remaining tail at s0's next lease boundary, and the re-stolen range
    is byte-identical to the solo scan."""
    coord = _resteal_cluster([1.0] * 8 + [8.0] * 100)
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(max_steals=2),
                            history=RateHistory())
    got = {}
    stats = puller.run(lambda i, b: got.setdefault(i, []).append(b))
    assert stats.steals == 1 and stats.re_steals == 1
    re_steal = next(e for e in stats.steal_events if e.kind == "re_steal")
    assert re_steal.victim == "s0" and re_steal.thief == "s1"
    assert re_steal.server_id == "s1"    # attributed to the reclaiming shard
    assert re_steal.num_batches >= 1
    ref = reference_batches(SQL, table=TABLE)
    _assert_batches_equal(_flat(puller, got), ref)
    # the reclaimed tail specifically matches the solo scan's batches
    back = next(p for p in puller.pullers
                if p.endpoint.start_batch == re_steal.start_batch)
    _assert_batches_equal(
        got[puller.pullers.index(back)],
        ref[re_steal.start_batch:re_steal.start_batch
            + re_steal.num_batches])


def test_one_resteal_per_range_under_adversarial_rates():
    """Adversarial schedule: the thief degrades while holding the tail, then
    recovers to look attractive again. The range still moves back at most
    once — no victim<->thief ping-pong — even with budget to spare."""
    coord = _resteal_cluster([1.0] * 8 + [8.0] * 3 + [1.0] * 100)
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(max_steals=16),
                            history=RateHistory())
    got = {}
    stats = puller.run(lambda i, b: got.setdefault(i, []).append(b))
    assert stats.re_steals <= stats.steals   # every re-steal undoes one steal
    assert stats.batches == 16               # nothing lost in the churn
    _assert_batches_equal(_flat(puller, got),
                          reference_batches(SQL, table=TABLE))


def test_resteal_disabled_without_history():
    """Without a history the degraded thief keeps its tail (PR 3 semantics):
    no re-steal events, scan still byte-identical."""
    coord = _resteal_cluster([1.0] * 8 + [8.0] * 100)
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig())
    got = {}
    stats = puller.run(lambda i, b: got.setdefault(i, []).append(b))
    assert stats.re_steals == 0
    _assert_batches_equal(_flat(puller, got),
                          reference_batches(SQL, table=TABLE))


# ------------------------------------------------------ conformance with PR 3


def test_history_none_replays_pr3_trace():
    """The drop-in guarantee: with history=None the puller's steal events
    match the recorded PR 3 static-factor trace exactly — same victims,
    thieves, ranges and modeled times."""
    coord = straggler_coordinator(table=TABLE)
    stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                           steal=StealConfig()).run()
    assert steal_event_trace(stats) == STRAGGLER_TRACE
    assert all(e.kind == "steal" for e in stats.steal_events)


def test_neutralized_history_replays_pr3_trace():
    """Hysteresis with every threshold disabled (no decay, no flap, floor at
    the static factor) must also replay the PR 3 trace — the stateful paths
    deviate only when their knobs say so."""
    hist = RateHistory(repeat_decay=1.0, min_factor=1.0, flap_ratio=1e9)
    coord = straggler_coordinator(table=TABLE)
    stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                           steal=StealConfig(), history=hist).run()
    assert steal_event_trace(stats) == STRAGGLER_TRACE
    assert hist.total_flaps == 0


# ------------------------------------- shard identity on events (regression)


def test_steal_events_carry_shard_identity():
    """Regression: StealEvents used to carry no shard identity; now every
    event is attributed to the shard it landed on and ClusterStats backfills
    legacy events from their thief when rendering."""
    coord = _cluster(slow=3)
    stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                           steal=StealConfig()).run()
    assert stats.steals >= 1
    for e in stats.steal_events:
        assert e.server_id == e.thief
    attribution = stats.steal_attribution()
    assert attribution[stats.steal_events[0].thief]["steal"] >= 1
    # a legacy event (recorded before kind/server_id existed) backfills
    legacy = types.SimpleNamespace(victim="sX", thief="sY",
                                   start_batch=0, num_batches=3)
    stats.steal_events.append(legacy)
    assert stats.steal_attribution()["sY"] == {"steal": 1, "batches": 3}
    assert stats.steals >= 2             # untagged events count as steals


def test_steal_table_attributes_per_shard():
    from repro.utils.report import steal_table
    coord = _resteal_cluster([1.0] * 8 + [8.0] * 100)
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(), history=RateHistory())
    stats = puller.run()
    out = steal_table(stats)             # bare ClusterStats accepted
    assert "| s0 |" in out and "| s1 |" in out and "*total*" in out
    # the re-steal shows up in the reclaiming shard's column
    s1_row = next(line for line in out.splitlines()
                  if line.startswith("| s1 |"))
    assert s1_row.split("|")[5].strip() == "1"

    class QosLike:                       # QosStats-shaped aggregate
        cluster = [stats, stats]

    doubled = steal_table(QosLike())
    total = next(line for line in doubled.splitlines()
                 if line.startswith("| *total* |"))
    assert total.split("|")[2].strip() == str(2 * stats.steals)


# ----------------------------------------------------- scheduler integration


def test_adaptive_scheduler_persists_history_across_gateway_runs():
    """The history lives on the AdaptiveScheduler, not the per-scan puller:
    a gateway drain records the straggler, and the next drain (a fresh
    fan-out) starts with the decayed per-victim factor."""
    from repro.qos import ScanGateway, ScanRequest
    scheduler = AdaptiveScheduler(steal=StealConfig(),
                                  history=RateHistory())
    ref = reference_batches(SQL, table=TABLE)
    for scan in range(2):
        gateway = ScanGateway(_cluster(slow=3), scheduler=scheduler)
        req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
        gateway.run()
        result = gateway.result(req.request_id)
        assert result.cluster.steals >= 1
        _assert_batches_equal(result.batches, ref)
    assert scheduler.history.total_steals >= 2
    assert scheduler.history.factor_for("s3", 2.0) < 2.0 * 0.75 + 1e-12
    assert scheduler.history.server("s3").observations > 0
    # AdaptiveScheduler.default() wires a history in
    assert AdaptiveScheduler.default().history is not None


def test_qos_stats_surface_decline_and_resteal_counters():
    from repro.qos.metrics import QosStats
    coord, adm = _sharded_cluster()
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(steal_headroom_min=2),
                            history=RateHistory(), client_id="c")
    adm.acquire_stream("f", server_id="s0")
    adm.acquire_stream("f", server_id="s1")
    adm.release_stream("f", server_id="s0")
    stats = puller.run()
    qos = QosStats()
    qos.cluster.append(stats)
    assert qos.declines == stats.declines >= 1
    assert qos.steals == stats.steals
    assert "declines=" in qos.summary()
