"""Query engine vs numpy oracle."""
import numpy as np
import pytest

from repro.engine import Engine, make_mixed_table, make_numeric_table, parse
from repro.core.recordbatch import concat_batches


@pytest.fixture
def eng():
    e = Engine()
    e.register("/d/wide", make_numeric_table("wide", 20_000, 5, batch_rows=4096,
                                             seed=3))
    e.register("/d/mixed", make_mixed_table("mixed", 5_000, seed=3))
    return e


def _all(eng, sql, path):
    return concat_batches(eng.execute(sql, path).read_all())


def _col(eng, path, name):
    t = eng.catalog.get(path)
    return np.concatenate([b.column(name).values for b in t.batches])


def test_projection(eng):
    out = _all(eng, "SELECT c3, c1 FROM wide", "/d/wide")
    assert out.schema.names == ("c3", "c1")
    np.testing.assert_allclose(out.column("c3").values, _col(eng, "/d/wide", "c3"))


def test_filter_matches_numpy(eng):
    out = _all(eng, "SELECT c0 FROM wide WHERE c0 > 0.25 AND c1 < 0.5", "/d/wide")
    c0, c1 = _col(eng, "/d/wide", "c0"), _col(eng, "/d/wide", "c1")
    expect = c0[(c0 > 0.25) & (c1 < 0.5)]
    np.testing.assert_allclose(np.sort(out.column("c0").values), np.sort(expect))


def test_arithmetic_expr(eng):
    out = _all(eng, "SELECT c0 FROM wide WHERE c0 * 2 + 1 >= 2.0", "/d/wide")
    c0 = _col(eng, "/d/wide", "c0")
    assert out.num_rows == int(((c0 * 2 + 1) >= 2.0).sum())


def test_limit_and_or(eng):
    out = _all(eng, "SELECT c0 FROM wide WHERE c0 > 1 OR c0 < -1 LIMIT 100",
               "/d/wide")
    assert out.num_rows == 100
    v = out.column("c0").values
    assert ((v > 1) | (v < -1)).all()


def test_aggregates_match_numpy(eng):
    out = _all(eng, "SELECT count(*), sum(c2), min(c2), max(c2), avg(c2) "
                    "FROM wide", "/d/wide").to_pydict()
    c2 = _col(eng, "/d/wide", "c2")
    assert out["count(*)"] == [20_000]
    np.testing.assert_allclose(out["sum(c2)"][0], c2.sum(), rtol=1e-12)
    np.testing.assert_allclose(out["min(c2)"][0], c2.min())
    np.testing.assert_allclose(out["max(c2)"][0], c2.max())
    np.testing.assert_allclose(out["avg(c2)"][0], c2.mean(), rtol=1e-12)


def test_null_semantics(eng):
    """NULL comparisons never pass WHERE (SQL three-valued logic)."""
    out = _all(eng, "SELECT val FROM mixed WHERE val > 0", "/d/mixed")
    assert out.column("val").null_count() == 0
    out2 = _all(eng, "SELECT id FROM mixed WHERE val IS NULL", "/d/mixed")
    t = eng.catalog.get("/d/mixed")
    nulls = sum(b.column("val").null_count() for b in t.batches)
    assert out2.num_rows == nulls


def test_string_filter(eng):
    out = _all(eng, "SELECT tag FROM mixed WHERE tag = 'beta' LIMIT 7",
               "/d/mixed")
    assert out.to_pydict()["tag"] == ["beta"] * 7


def test_is_not_null(eng):
    out = _all(eng, "SELECT tag FROM mixed WHERE tag IS NOT NULL", "/d/mixed")
    assert out.column("tag").null_count() == 0


def test_parser_errors():
    with pytest.raises(ValueError):
        parse("SELECT FROM t")
    with pytest.raises(ValueError):
        parse("SELECT a FROM t WHERE")
    with pytest.raises(ValueError):
        parse("SELECT a FROM t LIMIT x")
    q = parse("select sum(a), count(*) from t where (a + 1) * 2 = 4 limit 3")
    assert q.is_aggregate and q.limit == 3
