"""The repro.qos layer: admission quotas + token bucket, weighted-fair
queueing, deadline shedding, gateway scatter-gather reassembly, backpressure
propagation through the loader, pool memory budget, and lease-RPC prefetch
pipelining in the streams underneath."""
import numpy as np
import pytest
from conftest import make_coordinator, reference_batches, token_servers

from repro.cluster import BufferPool, ClusterCoordinator, cluster_scan
from repro.core import Fabric, ThallusServer, expose_batch
from repro.data import ThallusLoader, make_token_table
from repro.engine import Engine, make_numeric_table
from repro.qos import (AdmissionConfig, AdmissionController, Backpressure,
                       ClientClass, FifoQueue, ScanGateway, ScanRequest,
                       WeightedFairQueue)

SQL = "SELECT c0, c1 FROM t"
HEAVY_SQL = "SELECT c0, c1, c2, c3 FROM t"


def make_cluster(num_servers: int, placement: str = "shard",
                 admission=None) -> ClusterCoordinator:
    return make_coordinator(num_servers, placement, admission=admission)


def _reference_batches(sql=SQL):
    return reference_batches(sql)


# ------------------------------------------------------------- admission


def test_token_bucket_meters_lease_grants():
    adm = AdmissionController(AdmissionConfig(lease_rate_per_s=100.0,
                                              lease_burst=2))
    assert adm.lease_wait_s(0.0, 2) == 0.0            # burst covers it
    assert adm.lease_wait_s(0.0, 1) == pytest.approx(0.01)   # 1 token @ 100/s
    # after the modeled wait, a grant at that time still finds an empty
    # bucket (the wait consumed the refill); later arrivals are covered
    assert adm.lease_wait_s(0.5, 2) == 0.0
    assert adm.stats.lease_grants == 5
    assert adm.stats.throttle_wait_s == pytest.approx(0.01)


def test_token_bucket_disabled_by_default():
    adm = AdmissionController()
    assert adm.lease_wait_s(0.0, 1000) == 0.0


def test_stream_quota_enforced_with_retry_after():
    adm = AdmissionController(AdmissionConfig(max_streams_per_client=2))
    adm.acquire_stream("c1")
    adm.acquire_stream("c1")
    with pytest.raises(Backpressure) as exc:
        adm.acquire_stream("c1")
    assert exc.value.retry_after_s > 0
    adm.acquire_stream("c2")                 # quota is per client
    adm.release_stream("c1")
    adm.acquire_stream("c1")                 # a release frees a slot
    assert adm.stats.stream_denials == 1
    assert adm.active_streams("c1") == 2


def test_memory_budget_denies_streams_until_eviction():
    pool = BufferPool(max_bytes=1 << 12)
    adm = AdmissionController(AdmissionConfig(), pool=pool)
    assert adm.memory_budget_bytes == 1 << 12    # derived from the pool
    pool.stats.bytes_resident = (1 << 12) + 1    # over budget (all in flight)
    with pytest.raises(Backpressure):
        adm.acquire_stream()
    pool.stats.bytes_resident = 1 << 10          # releases/evictions landed
    adm.acquire_stream()
    assert adm.stats.memory_denials == 1


# ----------------------------------------------------------------- queues


def test_wfq_interleaves_by_weight():
    q = WeightedFairQueue([ClientClass("ui", 4.0), ClientClass("bg", 1.0)])
    for i in range(4):
        q.push(f"bg{i}", "bg", cost=4.0)
    for i in range(4):
        q.push(f"ui{i}", "ui", cost=4.0)
    order = [q.pop() for _ in range(len(q))]
    # weight 4 vs 1: ui finish tags are (1,2,3,4), bg's are (4,8,12,16) —
    # ui drains 4x faster; the tie at tag 4 breaks by arrival (bg0 first)
    assert order == ["ui0", "ui1", "ui2", "bg0", "ui3", "bg1", "bg2", "bg3"]


def test_fifo_queue_ignores_weights():
    q = FifoQueue([ClientClass("ui", 4.0), ClientClass("bg", 1.0)])
    q.push("bg0", "bg", cost=100.0)
    q.push("ui0", "ui", cost=0.1)
    assert [q.pop(), q.pop()] == ["bg0", "ui0"]


def test_wfq_idle_class_is_not_penalized():
    q = WeightedFairQueue([ClientClass("ui", 1.0), ClientClass("bg", 1.0)])
    for i in range(8):
        q.push(f"bg{i}", "bg", cost=1.0)
    for _ in range(8):
        q.pop()                              # bg drains alone; vtime advances
    q.push("bg8", "bg", cost=1.0)
    q.push("ui0", "ui", cost=1.0)            # first ui ever: starts at vtime
    assert q.pop() == "bg8"                  # equal weights, bg arrived first
    assert q.pop() == "ui0"                  # ...but ui owes no history


# ---------------------------------------------------------------- gateway


def test_gateway_reassembles_shard_scan_in_order():
    gateway = ScanGateway(make_cluster(4, "shard"))
    req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    got = gateway.result(req.request_id).batches
    ref = _reference_batches()
    assert len(got) == len(ref)
    for g, r in zip(got, ref):               # exact global scan order
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)


def test_gateway_reassembles_replica_scan_in_order():
    gateway = ScanGateway(make_cluster(3, "replica"))
    req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d",
                                     num_streams=3))
    gateway.run()
    got = gateway.result(req.request_id).batches
    ref = _reference_batches()
    assert len(got) == len(ref)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)


def test_gateway_pooled_results_survive_recycling():
    """With a pool, returned batches must be copies — the slabs they were
    pulled into recycle under later requests."""
    coord = make_cluster(2, "shard")
    pool = BufferPool(coord.server("s0").fabric)
    gateway = ScanGateway(coord, pool=pool)
    r1 = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    r2 = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    ref = _reference_batches()
    for req in (r1, r2):
        got = gateway.result(req.request_id).batches
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(g.column("c0").values,
                                          r.column("c0").values)
    assert pool.outstanding == 0


def test_gateway_wfq_protects_interactive_under_heavy_load():
    """The acceptance shape: a starving heavy client floods first; with the
    fair queue + quotas the interactive class's modeled p50 grant latency
    drops versus the FIFO/no-quota baseline."""
    p50 = {}
    for quotas in (False, True):
        coord = make_cluster(4, "shard")
        admission = AdmissionController(AdmissionConfig(
            max_streams_per_client=2)) if quotas else None
        gateway = ScanGateway(
            coord, classes=[ClientClass("interactive", 4.0),
                            ClientClass("batch", 1.0)],
            admission=admission, fair=quotas)
        for _ in range(4):
            gateway.submit(ScanRequest("heavy", "batch", HEAVY_SQL, "/d",
                                       cost_hint=8.0))
        for _ in range(4):
            gateway.submit(ScanRequest("ui", "interactive", SQL, "/d",
                                       cost_hint=1.0))
        gateway.run()
        stats = gateway.stats
        assert stats.klass("interactive").granted == 4
        assert stats.klass("batch").granted == 4
        p50[quotas] = stats.klass("interactive").p50_grant_latency_s
        # per-request ClusterStats compose into the qos view
        assert len(stats.cluster) == 8
        assert stats.bytes == sum(c.bytes for c in stats.cluster)
    assert p50[True] < p50[False]


def test_gateway_sheds_on_deadline():
    gateway = ScanGateway(make_cluster(2, "shard"))
    gateway.submit(ScanRequest("heavy", "batch", HEAVY_SQL, "/d",
                               cost_hint=8.0))
    kept = gateway.submit(ScanRequest("ui", "interactive", SQL, "/d",
                                      deadline_s=10.0))
    doomed = ScanRequest("late", "batch", HEAVY_SQL, "/d", cost_hint=8.0,
                         deadline_s=1e-9)
    results = None
    if gateway.submit(doomed) is not None:   # survived the submit estimate…
        results = gateway.run()              # …then expires while queued
    else:
        results = gateway.run()
    assert gateway.stats.klass("batch").shed == 1
    assert gateway.stats.klass("interactive").shed == 0
    assert gateway.result(kept.request_id) is not None
    assert len(results) == 2                 # heavy + ui granted, late shed


def test_gateway_survives_malformed_request():
    """Regression: one bad request (impossible num_streams on a shard plan)
    must not abort the drain and drop every other client's queued work."""
    gateway = ScanGateway(make_cluster(4, "shard"))
    bad = gateway.submit(ScanRequest("evil", "batch", SQL, "/d",
                                     num_streams=2))   # < shard count
    good = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    results = gateway.run()
    assert len(results) == 1
    assert gateway.result(good.request_id) is not None
    assert gateway.result(bad.request_id) is None
    assert gateway.stats.klass("batch").failed == 1
    assert "failed=1" in gateway.stats.summary()


def test_gateway_pool_stats_are_per_scan_deltas():
    """Regression: a shared pool's one-time registration cost must be
    attributed to the scan that created the slabs, not re-reported (and
    retroactively grown) on every request's ClusterStats."""
    coord = make_cluster(2, "shard")
    pool = BufferPool(coord.server("s0").fabric)
    gateway = ScanGateway(coord, pool=pool)
    for _ in range(3):
        gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    per_scan = [c.pool.modeled_register_s for c in gateway.stats.cluster]
    assert sum(per_scan) == pytest.approx(pool.stats.modeled_register_s)
    # the first scan warmed the pool; later scans created few/no slabs
    assert per_scan[0] > per_scan[1] + per_scan[2]
    assert gateway.stats.cluster[1].pool.hits > 0


def test_gateway_quota_caps_replica_fanout():
    """A replica plan is elastic: the gateway narrows it to the client's
    stream quota instead of opening (and serializing) every replica."""
    admission = AdmissionController(AdmissionConfig(max_streams_per_client=2))
    gateway = ScanGateway(make_cluster(4, "replica"), admission=admission)
    req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    result = gateway.result(req.request_id)
    assert len(result.cluster.streams) == 2
    ref = _reference_batches()
    for g, r in zip(result.batches, ref):
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)


# ------------------------------------------------- loader backpressure


def _token_servers(n):
    return token_servers(n)


def test_loader_surfaces_backpressure_retry_after():
    adm = AdmissionController(AdmissionConfig(max_streams_per_client=2,
                                              retry_after_hint_s=0.25))
    loader = ThallusLoader(_token_servers(4), "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=8, transport="cluster",
                           admission=adm, client_id="trainer")
    with pytest.raises(Backpressure) as exc:
        list(loader)
    assert exc.value.retry_after_s == 0.25
    # the denial must not leak slots or leases: the partial fan-out closed
    assert adm.active_streams("trainer") == 0
    # "retrying" under the quota succeeds with the same controller
    retry = ThallusLoader(_token_servers(4), "SELECT tokens FROM tok", "/d",
                          seq_len=32, batch_seqs=8, transport="cluster",
                          admission=adm, client_id="trainer", num_streams=2)
    out = list(retry)
    assert len(out) == 12                    # 96 seqs / 8 per chunk
    assert adm.active_streams("trainer") == 0


def test_loader_accounts_transport_on_early_exit():
    """Regression: a consumer that checkpoints and stops mid-stream still
    pulled batches — transport_s must not silently read 0."""
    loader = ThallusLoader(_token_servers(2), "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=8, transport="cluster")
    it = iter(loader)
    next(it)
    it.close()
    assert loader.stats.batches > 0
    assert loader.stats.transport_s > 0


def test_puller_charges_throttle_wait_to_stream_clock():
    adm = AdmissionController(AdmissionConfig(lease_rate_per_s=10.0,
                                              lease_burst=1))
    coord = make_cluster(2, "shard", admission=adm)
    stats = cluster_scan(coord, SQL, "/d", client_id="c")
    assert stats.throttle_wait_s > 0         # bucket ran dry mid-scan
    assert stats.critical_path_s >= stats.throttle_wait_s / len(stats.streams)
    assert adm.stats.lease_grants > 0


# -------------------------------------------------- pool memory budget


def _descs():
    eng = Engine()
    eng.register("/d", make_numeric_table("t", 4096, 2, batch_rows=4096))
    batch = eng.execute(SQL, "/d").read_next()
    return expose_batch(batch).descs


def test_pool_budget_evicts_lru_and_unregisters():
    fabric = Fabric()
    descs = _descs()
    pool = BufferPool(fabric, max_bytes=1 << 16)
    handles = [pool.acquire(descs) for _ in range(4)]
    assert pool.stats.bytes_resident > pool.max_bytes   # all checked out
    assert pool.stats.evictions == 0         # in-flight slabs untouchable
    registered_peak = fabric.registrations
    for h in handles:
        pool.release(h)
    assert pool.stats.bytes_resident <= pool.max_bytes  # converged back
    assert pool.stats.evictions > 0
    assert fabric.registrations == registered_peak - pool.stats.evictions
    assert pool.stats.registered_segments == fabric.registrations


def test_pool_budget_evicts_least_recently_released():
    pool = BufferPool(max_bytes=1 << 30)     # budget never binds yet
    descs = _descs()
    h1 = pool.acquire(descs)
    h2 = pool.acquire(descs)
    pool.release(h1)                          # LRU set
    mru = {id(s) for s in pool._checked_out[h2.handle_id]}
    pool.release(h2)                          # MRU set
    pool.max_bytes = pool.stats.bytes_resident // 2
    pool._evict_over_budget()
    kept = {id(s) for lst in pool._free.values() for s in lst}
    assert pool.stats.evictions > 0
    assert pool.stats.bytes_resident <= pool.max_bytes
    assert kept <= mru                        # the LRU set went first


def test_pool_parity_under_budget_pressure():
    """Evictions change performance, never bytes: a budget-squeezed pooled
    scan still matches the reference."""
    coord = make_cluster(2, "shard")
    pool = BufferPool(coord.server("s0").fabric, max_bytes=1 << 15)
    got = []
    cluster_scan(coord, SQL, "/d", pool=pool,
                 sink=lambda i, b: got.append(b.column("c0").values.copy()))
    ref = np.sort(np.concatenate(
        [b.column("c0").values for b in _reference_batches()]))
    np.testing.assert_array_equal(np.sort(np.concatenate(got)), ref)
    assert pool.stats.evictions > 0
    assert pool.outstanding == 0


# ------------------------------------------------------- prefetch slot


def test_prefetch_hides_lease_rpc_on_critical_path():
    off = cluster_scan(make_cluster(2, "shard"), SQL, "/d", prefetch=False)
    on = cluster_scan(make_cluster(2, "shard"), SQL, "/d", prefetch=True)
    assert on.batches == off.batches and on.bytes == off.bytes
    assert off.prefetch_overlap_s == 0.0
    assert on.prefetch_overlap_s > 0.0
    # the hidden RPC time comes off the charged control time and the clock
    assert on.control_rpc_s < off.control_rpc_s
    assert on.control_rpc_s + on.prefetch_overlap_s == \
        pytest.approx(off.control_rpc_s)
    # per-stream: only the first batch's RPC is ever fully exposed (clock_s
    # itself also carries measured alloc time, so compare modeled terms)
    for s_on, s_off in zip(on.streams, off.streams):
        assert s_on.control_rpc_s < s_off.control_rpc_s or s_on.batches <= 1
    assert on.modeled_wire_s == pytest.approx(off.modeled_wire_s)


def test_prefetch_parity():
    got = []
    cluster_scan(make_cluster(3, "shard"), SQL, "/d", prefetch=True,
                 sink=lambda i, b: got.append(b.column("c0").values.copy()))
    ref = np.sort(np.concatenate(
        [b.column("c0").values for b in _reference_batches()]))
    np.testing.assert_array_equal(np.sort(np.concatenate(got)), ref)


# -------------------------------------------------------- serving path


def test_batcher_ingests_via_gateway():
    table = make_token_table("tok", num_seqs=24, seq_len=8, vocab_size=64,
                             seqs_per_batch=8)
    coord = ClusterCoordinator()
    for i in range(2):
        eng = Engine()
        eng.register("/d", table)
        coord.add_server(f"s{i}", ThallusServer(eng, Fabric()))
    coord.place_replicas("/d", table)
    gateway = ScanGateway(coord)

    import jax.numpy as jnp
    from repro.serving import Batcher

    def prefill(tokens):
        B, S = tokens.shape
        return jnp.ones((B, S, 64)), {"k": jnp.zeros((B, 1, S, 1))}

    def decode(cache, tokens, position):
        return jnp.ones((tokens.shape[0], 1, 64)), cache

    batcher = Batcher(prefill, decode, batch_size=16)
    req = batcher.submit_scan(gateway, "SELECT seq_id, tokens FROM tok",
                              "/d", klass="interactive")
    gateway.run()
    result = gateway.result(req.request_id)
    n = batcher.ingest_batches(result.batches, seq_len=8, max_new_tokens=2)
    assert n == 24
    done = batcher.run()
    assert sorted(c.request_id for c in done) == list(range(24))
    assert all(len(c.tokens) == 2 for c in done)
    assert gateway.stats.klass("interactive").granted == 1


# ------------------------------------------------------------- reporting


def test_report_tables_render():
    from repro.utils.report import pool_table, qos_table
    coord = make_cluster(2, "shard")
    pool = BufferPool(coord.server("s0").fabric, max_bytes=1 << 15)
    gateway = ScanGateway(coord, pool=pool)
    gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    pt = pool_table(pool.stats)
    qt = qos_table(gateway.stats)
    assert "hit rate" in pt and pt.count("\n") == 2
    assert "interactive" in qt and "*gateway*" in qt


# ------------------------------------------------- stats merge / attribution


def test_qos_stats_merge_disjoint_classes():
    from repro.qos import QosStats
    a, b = QosStats(), QosStats()
    ca = a.klass("interactive")
    ca.submitted = ca.granted = 2
    ca.grant_latency_s.extend([1e-3, 3e-3])
    ca.bytes, ca.service_s = 100, 0.5
    cb = b.klass("batch")
    cb.submitted, cb.granted, cb.shed = 3, 2, 1
    cb.bytes = 50
    a.queue_depth_max, b.queue_depth_max = 2, 5
    a.makespan_s, b.makespan_s = 0.4, 0.3
    b.throttle_wait_s = 0.1
    a.merge(b)
    assert set(a.classes) == {"interactive", "batch"}     # clean union
    assert a.submitted == 5 and a.granted == 4 and a.shed == 1
    assert a.bytes == 150
    assert a.queue_depth_max == 5                         # gauges: max
    assert a.makespan_s == 0.4
    assert a.throttle_wait_s == 0.1                       # durations: add
    # the merged summary renders both classes without cross-talk
    s = a.summary()
    assert "interactive[n=2/2" in s and "batch[n=2/3" in s


def test_qos_stats_merge_overlapping_class_percentiles():
    from repro.qos import ClassStats
    a = ClassStats("ui", submitted=2, granted=2,
                   grant_latency_s=[1e-3, 2e-3])
    b = ClassStats("ui", submitted=2, granted=2,
                   grant_latency_s=[3e-3, 4e-3])
    a.merge(b)
    # percentiles come from the UNION of samples, not averaged p50s
    # (_quantile takes the upper-middle sample of an even-length union)
    assert a.grant_latency_s == [1e-3, 2e-3, 3e-3, 4e-3]
    assert a.p50_grant_latency_s == 3e-3
    assert a.max_grant_latency_s == 4e-3
    with pytest.raises(ValueError):
        a.merge(ClassStats("batch"))


def test_qos_stats_zero_request_class_percentiles():
    from repro.qos import QosStats
    qos = QosStats()
    empty = qos.klass("idle")                   # registered, never submitted
    assert empty.p50_grant_latency_s == 0.0
    assert empty.max_grant_latency_s == 0.0
    assert empty.throughput_bytes_per_s == 0.0
    assert "idle[n=0/0" in qos.summary()
    # ...and the registry snapshot keeps the empty percentile keys present
    snap = qos.registry().snapshot()
    assert snap["qos.class.idle.grant_latency.count"] == 0
    assert snap["qos.grant_latency.p50"] == 0.0


def test_steal_attribution_legacy_events_without_server_id():
    import types

    from repro.cluster import ClusterStats
    legacy = types.SimpleNamespace(kind="steal", victim="s3", thief="s0",
                                   num_batches=2)       # pre-server_id event
    tagged = types.SimpleNamespace(kind="decline", victim="s3", thief="s1",
                                   server_id="s1", num_batches=1)
    blank = types.SimpleNamespace(kind="re_steal", victim="s3", thief="s2",
                                  server_id="", num_batches=1)  # empty tag
    stats = ClusterStats(steal_events=[legacy, tagged, blank])
    attr = stats.steal_attribution()
    assert attr["s0"] == {"batches": 2, "steal": 1}     # backfilled: thief
    assert attr["s1"] == {"batches": 0, "decline": 1}   # declines move none
    assert attr["s2"] == {"batches": 1, "re_steal": 1}  # "" falls back too
    from repro.utils.report import steal_table
    st = steal_table(stats)
    assert "| s0 |" in st and "*total*" in st
