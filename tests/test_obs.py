"""repro.obs: end-to-end scan tracing (spans sum to the modeled makespan,
Chrome export shape), the unified metrics registry (stable dotted names,
merge semantics, loader roll-up), and continuous perf baselining (rolling
median+MAD envelopes, bootstrap floors, regression/improvement events)."""
import json
import types

import pytest
from conftest import make_coordinator, straggler_coordinator

from repro.core import Fabric, ThallusServer
from repro.data import ThallusLoader, make_token_table
from repro.engine import Engine
from repro.obs import (MIN_RUNS, MetricPolicy, MetricsRegistry, RunRecord,
                       Tracer, append_run, detect_events, load_trajectory,
                       rolling_baseline)
from repro.qos import (AdmissionConfig, AdmissionController, ClientClass,
                       ScanGateway, ScanRequest)
from repro.sched import AdaptiveScheduler, StealConfig, TicketTable

pytestmark = pytest.mark.obs

SQL = "SELECT c0, c1 FROM t"


def traced_gateway(num_servers: int = 1, **gateway_kwargs):
    tracer = Tracer()
    coord = make_coordinator(num_servers, "replica")
    admission = AdmissionController(AdmissionConfig(
        lease_rate_per_s=1e3, lease_burst=1))
    gateway = ScanGateway(coord,
                          classes=[ClientClass("interactive", 4.0),
                                   ClientClass("batch", 1.0)],
                          admission=admission, tracer=tracer,
                          **gateway_kwargs)
    return tracer, gateway


# ---------------------------------------------------------------- tracing


def test_trace_spans_sum_to_modeled_makespan():
    """The acceptance criterion: one gateway scan's committed spans must
    account for its whole modeled makespan (grant latency + service) within
    1%. Prefetch spans are the overlap lane — hidden time, excluded."""
    tracer, gateway = traced_gateway(1)
    gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()

    (ctx,) = tracer.contexts
    qos = gateway.stats
    expected = (qos.klass("interactive").grant_latency_s[0]
                + qos.cluster[0].streams[0].clock_s)
    spanned = sum(s.dur_s for s in ctx.spans
                  if s.phase == "X" and s.cat != "prefetch")
    assert expected > 0
    assert spanned == pytest.approx(expected, rel=0.01)


def test_chrome_export_shape(tmp_path):
    tracer, gateway = traced_gateway(2)
    for i in range(2):
        gateway.submit(ScanRequest(f"c{i}", "interactive", SQL, "/d"))
    gateway.run()

    doc = tracer.to_chrome()
    events = doc["traceEvents"]
    assert {e["ph"] for e in events} <= {"X", "i", "M"}
    assert len({e["pid"] for e in events}) == 2          # one pid per scan
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
    names = {e["name"] for e in events}
    assert {"submit", "lease.rpc", "rdma.pull", "reassemble"} <= names

    from repro.utils.report import export_trace, trace_table
    path = export_trace(tracer, str(tmp_path / "trace.json"))
    assert json.load(open(path))["traceEvents"] == events
    assert "rdma.pull" in trace_table(tracer)


def test_trace_records_steal_instants():
    """A stolen range shows up as a steal instant on the scan track and the
    thief's spans land at the steal epoch, not t=0."""
    from repro.sched import StealingPuller
    coord = straggler_coordinator()
    tracer = Tracer()
    ctx = tracer.begin("scan")
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig(), trace=ctx)
    stats = puller.run()
    ctx.commit()
    assert stats.steals >= 1
    steal_instants = [s for s in ctx.spans
                      if s.phase == "i" and s.name == "steal"]
    assert len(steal_instants) == stats.steals
    epoch = stats.steal_events[0].epoch_s
    thief_track = f"stream{len(coord.plan(SQL, '/d').endpoints)}"
    thief_spans = [s for s in ctx.spans
                   if s.track.startswith(thief_track) and s.phase == "X"]
    assert thief_spans and min(s.start_s for s in thief_spans) >= epoch


# --------------------------------------------------------------- registry


def test_registry_roundtrip_gateway_workload():
    """registry() snapshots the whole gateway stack under the stable dotted
    namespace, and every value is a plain scalar."""
    from repro.cluster import BufferPool
    coord = make_coordinator(2, "replica", slow=1, slowdown=4.0)
    pool = BufferPool(coord.server("s0").fabric, max_bytes=1 << 15)
    gateway = ScanGateway(
        coord, classes=[ClientClass("interactive", 4.0)],
        scheduler=AdaptiveScheduler(steal=StealConfig(),
                                    tickets=TicketTable()),
        pool=pool)
    for i in range(2):
        gateway.submit(ScanRequest(f"c{i}", "interactive", SQL, "/d"))
    gateway.run()

    snap = gateway.stats.registry().snapshot()
    for key in ("qos.granted", "qos.grant_latency.p50", "qos.makespan.us",
                "qos.class.interactive.granted", "sched.steals.decline",
                "cluster.pull.us", "cluster.batches", "pool.evictions",
                "pool.hit_rate"):
        assert key in snap, key
    assert all(isinstance(v, (int, float)) for v in snap.values())
    assert snap["qos.granted"] == 2
    assert snap["qos.grant_latency.p50"] >= 0


def test_registry_counter_gauge_histogram_merge():
    a = MetricsRegistry()
    a.counter("x.n", 2)
    a.gauge("x.g", 1.5)
    a.histogram("x.h", [1.0, 2.0, 3.0])
    b = MetricsRegistry()
    b.counter("x.n", 3)
    b.gauge("x.g", 2.5)
    b.histogram("x.h", 4.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["x.n"] == 5
    assert snap["x.g"] == 2.5                 # gauges: latest wins
    assert snap["x.h.count"] == 4             # histograms concatenate
    assert snap["x.h.max"] == 4.0
    assert snap["x.h.sum"] == pytest.approx(10.0)


def test_loader_metrics_rollup():
    eng = Engine()
    eng.register("/d", make_token_table("tok", 64, 32, 100,
                                        seqs_per_batch=16))
    loader = ThallusLoader([ThallusServer(eng, Fabric())],
                           "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=8)
    assert len(list(loader)) == 8
    snap = loader.metrics().snapshot()
    # loader.batches counts transport record batches (64 seqs / 16 per
    # record batch), not the training batches the iterator re-cuts
    assert snap["loader.batches"] == loader.stats.batches == 4
    assert snap["loader.transport.us"] > 0


def test_admission_metrics_gauges():
    adm = AdmissionController(AdmissionConfig(max_streams_per_client=2))
    adm.acquire_stream("c1")
    adm.acquire_stream("c1")
    snap = adm.metrics().snapshot()
    assert snap["qos.admission.stream_grants"] == 2
    assert snap["qos.admission.active_total"] == 2
    assert snap["qos.admission.active.c1"] == 2


def test_registry_hardened_against_empty_and_non_numeric():
    """Regression: empty histograms snapshot safely, non-numeric histogram
    elements are skipped, and record_any never raises on awkward objects."""
    from repro.obs.registry import _quantile, record_any
    assert _quantile([], 0.5) == 0.0
    reg = MetricsRegistry()
    reg.histogram("h.empty", [])
    reg.histogram("h.mixed", [1.0, "n/a", None, 3.0])
    reg.histogram("h.scalar", "not-a-number")
    snap = reg.snapshot()
    assert snap["h.empty.count"] == 0
    assert snap["h.empty.p50"] == 0.0
    assert snap["h.mixed.count"] == 2 and snap["h.mixed.max"] == 3.0
    assert snap["h.scalar.count"] == 0

    import numpy as np
    awkward = types.SimpleNamespace(
        none=None, text="hello", arr=np.arange(3), tags={"a", "b"},
        nested={"x": 1.5, "bad": object()}, n=7)
    record_any(reg, "any", awkward)
    snap = reg.snapshot()
    assert snap["any.n"] == 7.0
    assert snap["any.nested.x"] == 1.5
    assert not any(k.startswith("any.text") for k in snap)

    deep = {"a": {"b": {"c": {"d": {"e": {"f": {"g": {"h": {"i": 1.0}}}}}}}}}
    record_any(reg, "deep", deep)          # depth-capped, never recurses away


def test_trace_set_shift_and_commit_edge_cases():
    """Zero-span streams commit cleanly, set_shift on an unknown group is
    inert, and a thief group shifted past the scan end still resolves."""
    tracer = Tracer()
    ctx = tracer.begin("scan")
    ctx.stream("stream0")                   # a stream that never records
    ctx.span("scan.end", 0.0, 10.0)
    thief = ctx.stream("stream1")
    thief.span("rdma.pull", 0.0, 2.0)
    ctx.set_shift(thief.group, 100.0)       # shifted past scan end
    ctx.set_shift("no-such-group", 5.0)
    ctx.base_s = 1.0
    ctx.commit()
    ctx.commit()                            # idempotent: collected once
    assert len(tracer.contexts) == 1
    thief_spans = [s for s in ctx.spans if s.track == "stream1"]
    assert thief_spans[0].start_s == pytest.approx(101.0)
    doc = tracer.to_chrome()
    assert all(e["ts"] >= 0 for e in doc["traceEvents"] if e["ph"] == "X")

    empty = tracer.begin("empty")
    empty.commit()                          # zero-span context exports
    assert tracer.to_chrome()


def test_qos_stats_merge_alert_counters():
    from repro.qos.metrics import QosStats
    a, b = QosStats(), QosStats()
    a.alerts, b.alerts = 2, 1
    a.merge(b)
    assert a.alerts == 3
    assert "alerts=3" in a.summary()
    assert a.registry().snapshot()["qos.alerts"] == 3
    assert QosStats().registry().snapshot()["qos.alerts"] == 0


# -------------------------------------------------------------- baselining


def _record(scenario, **metrics):
    return RunRecord(scenario=scenario, metrics=metrics)


def test_rolling_baseline_median_mad():
    history = [_record("s", m=v) for v in (10.0, 12.0, 11.0, 100.0)]
    base = rolling_baseline(history, "m", window=3)     # drops the 10.0
    assert base.n == 3
    assert base.median == 12.0
    lo, hi = base.envelope(rel_slack=0.10)
    assert lo < 12.0 < hi


def test_append_and_load_trajectory_roundtrip(tmp_path):
    out = str(tmp_path)
    append_run(out, _record("flap", speedup=1.7))
    append_run(out, _record("flap", speedup=1.8))
    append_run(out, _record("other", x=1.0))
    runs = load_trajectory(out, "flap")
    assert [r.metrics["speedup"] for r in runs] == [1.7, 1.8]
    bench = json.load(open(tmp_path / "BENCH_flap.json"))
    assert bench["metrics"]["speedup"] == 1.8            # newest record


def test_bootstrap_floor_flags_regression_without_history():
    policy = MetricPolicy("speedup", better="higher", floor=1.5)
    events = detect_events(_record("s", speedup=1.2), [],
                           {"speedup": policy})
    assert [e.kind for e in events] == ["regression"]
    assert "bootstrap floor" in events[0].detail


def test_envelope_inactive_below_min_runs():
    policy = MetricPolicy("us", better="lower")          # envelope-only
    history = [_record("s", us=100.0)] * (MIN_RUNS - 1)
    assert detect_events(_record("s", us=500.0), history,
                         {"us": policy}) == []


def test_injected_slowdown_flags_regression():
    """The acceptance criterion: a stable 2-run trajectory passes, a 2×
    slowdown on the third run is a regression event; a 2× speedup on a
    better=higher metric is an improvement."""
    policies = {"us": MetricPolicy("us", better="lower"),
                "speedup": MetricPolicy("speedup", better="higher")}
    history = [_record("s", us=100.0, speedup=1.7),
               _record("s", us=101.0, speedup=1.72)]
    assert detect_events(_record("s", us=102.0, speedup=1.69),
                         history, policies) == []
    events = detect_events(_record("s", us=201.0, speedup=3.4),
                           history, policies)
    kinds = {e.metric: e.kind for e in events}
    assert kinds == {"us": "regression", "speedup": "improvement"}
    assert all(e.n_runs == 2 for e in events)


def test_ticket_table_metrics():
    table = TicketTable()
    key = table.key_for(SQL, "/d")
    table.subscribe(key, 1)          # primary: runs the fan-out
    table.subscribe(key, 2)          # rides the multicast
    snap = table.metrics().snapshot()
    assert snap["sched.tickets.in_flight"] == 1
    assert snap["sched.tickets.hit_rate"] == table.stats.hit_rate
