"""The repro.cluster dataplane: planner determinism, shard/replica
partitioning, the registered buffer pool, multi-stream pulls, and per-stream
fault recovery."""
import numpy as np
import pytest
from conftest import make_coordinator, reference_batches, token_servers

from repro.cluster import (BufferPool, ClusterCoordinator, MultiStreamPuller,
                           cluster_scan, plan_scan, size_class)
from repro.core import Fabric, ThallusServer, expose_batch
from repro.data import ThallusLoader
from repro.engine import Engine, make_numeric_table

ROWS = 40_000
SQL = "SELECT c0, c1 FROM t"


def make_cluster(num_servers: int, placement: str = "shard",
                 server_cls=ThallusServer) -> ClusterCoordinator:
    return make_coordinator(num_servers, placement, server_cls=server_cls)


# ---------------------------------------------------------------- planner


def test_plan_deterministic():
    coord = make_cluster(4)
    p1 = coord.plan(SQL, "/d")
    p2 = coord.plan(SQL, "/d")
    assert p1 == p2
    assert p1.query_id == p2.query_id
    assert [e.server_id for e in p1.endpoints] == ["s0", "s1", "s2", "s3"]


def test_plan_replica_ranges_cover_stream():
    coord = make_cluster(2, placement="replica")
    plan = coord.plan(SQL, "/d", num_streams=3)
    # 40_000 rows / 4096 per batch = 10 batches, split 4/3/3
    spans = [(e.start_batch, e.max_batches) for e in plan.endpoints]
    assert spans == [(0, 4), (4, 3), (7, 3)]
    assert plan.placement == "replica"


def test_plan_rejects_unknown_placement():
    coord = make_cluster(2)
    with pytest.raises(ValueError):
        plan_scan(SQL, "/d", dict(coord.servers), placement="bogus")
    with pytest.raises(ValueError):
        plan_scan(SQL, "/d", {}, placement="shard")


def test_plan_shard_rejects_fewer_streams_than_shards():
    """Regression: capping shard streams would silently drop whole shards."""
    coord = make_cluster(4)
    with pytest.raises(ValueError, match="one stream per shard"):
        coord.plan(SQL, "/d", num_streams=2)
    # num_streams >= shard count is fine (and capped at one per shard)
    assert coord.plan(SQL, "/d", num_streams=4).num_streams == 4


# --------------------------------------------------------------- parity


def _reference_rows() -> np.ndarray:
    batches = reference_batches(SQL)
    return np.sort(np.concatenate([b.column("c0").values for b in batches]))


@pytest.mark.parametrize("placement", ["shard", "replica"])
@pytest.mark.parametrize("pooled", [False, True])
def test_cluster_scan_parity(placement, pooled):
    coord = make_cluster(4, placement=placement)
    pool = BufferPool(coord.server("s0").fabric) if pooled else None
    got = []

    def sink(idx, batch):   # copy: pooled buffers recycle after this returns
        got.append(batch.column("c0").values.copy())

    stats = cluster_scan(coord, SQL, "/d", pool=pool, sink=sink)
    np.testing.assert_array_equal(np.sort(np.concatenate(got)),
                                  _reference_rows())
    assert stats.bytes == sum(v.nbytes * 2 for v in got)  # c0 + c1
    # every lease was finalized
    for server in coord.servers.values():
        assert not server.reader_map


def test_first_ready_schedule_parity():
    coord = make_cluster(3)
    plan = coord.plan(SQL, "/d")
    puller = MultiStreamPuller(coord, plan, schedule="first_ready",
                               lease_batches=2)
    got = []
    puller.run(lambda idx, b: got.append(b.column("c0").values.copy()))
    np.testing.assert_array_equal(np.sort(np.concatenate(got)),
                                  _reference_rows())


# ------------------------------------------------------------ buffer pool


def test_size_class_rounding():
    assert size_class(1) == 64
    assert size_class(64) == 64
    assert size_class(65) == 128
    assert size_class(4096) == 4096
    assert size_class(4097) == 8192


def test_pool_reuse_returns_same_slab():
    eng = Engine()
    eng.register("/d", make_numeric_table("t", 1000, 2, batch_rows=1000))
    batch = eng.execute("SELECT c0, c1 FROM t", "/d").read_next()
    descs = expose_batch(batch).descs

    def addr(seg):
        return seg.__array_interface__["data"][0]

    pool = BufferPool()
    h1 = pool.acquire(descs)
    addrs1 = [addr(seg) for seg in h1.segments]
    assert pool.stats.misses == len(descs) and pool.stats.hits == 0
    assert h1.registered
    pool.release(h1)
    h2 = pool.acquire(descs)
    addrs2 = [addr(seg) for seg in h2.segments]
    # free lists are LIFO per size class: same memory, maybe permuted
    assert sorted(addrs2) == sorted(addrs1)      # recycled memory, not fresh
    assert pool.stats.hits == len(descs)
    assert pool.stats.slabs_created == len(descs)
    with pytest.raises(KeyError):
        pool.release(h1)    # already released


def test_pool_registration_amortized():
    """Pool-on: registration charged once per slab (via Fabric.register),
    and pulls take the registered fast path (no per-segment term)."""
    coord_off = make_cluster(2)
    off = cluster_scan(coord_off, SQL, "/d")
    coord_on = make_cluster(2)
    pool = BufferPool(coord_on.server("s0").fabric)
    on = cluster_scan(coord_on, SQL, "/d", pool=pool)
    assert on.batches == off.batches
    # charged-per-pull registration is zero on the pooled path
    assert sum(s.modeled_register_s for s in on.streams) == 0.0
    assert pool.stats.modeled_register_s > 0      # one-time, amortized
    assert on.modeled_register_s < off.modeled_register_s
    assert on.modeled_wire_s < off.modeled_wire_s
    assert pool.stats.hit_rate > 0.5


def test_abandoned_iteration_releases_pool_and_leases():
    """Regression: a consumer that walks away mid-scan must not leak pool
    slabs or server-side reader-map entries."""
    coord = make_cluster(2)
    pool = BufferPool(coord.server("s0").fabric)
    plan = coord.plan(SQL, "/d")
    puller = MultiStreamPuller(coord, plan, pool=pool, lease_batches=3)
    it = puller.batches()
    next(it)
    next(it)
    it.close()     # abandon with undelivered lease batches in flight
    assert pool.outstanding == 0
    for server in coord.servers.values():
        assert not server.reader_map


# ------------------------------------------------- multi-stream behaviour


def test_multi_stream_beats_single_stream():
    """Acceptance: same total bytes, ≥4 streams, lower modeled transport
    time than one stream — per-stream clocks from the same stats path.
    Compares the modeled-only critical path (deterministic); the wall-clock
    variant (critical_path_s) is load-sensitive and belongs in benchmarks."""
    single = cluster_scan(make_cluster(1), SQL, "/d")
    multi = cluster_scan(make_cluster(4), SQL, "/d")
    assert multi.bytes == single.bytes
    assert multi.batches == single.batches
    assert multi.modeled_critical_path_s < single.modeled_critical_path_s


class FlakyServer(ThallusServer):
    """Raises on its N-th iterate call, once — a transient stream fault."""

    def __init__(self, engine, fabric=None, fail_on_call=2):
        super().__init__(engine, fabric)
        self.calls = 0
        self.fail_on_call = fail_on_call

    def iterate(self, uid, do_rdma, max_batches=None):
        self.calls += 1
        if self.calls == self.fail_on_call:
            raise ConnectionError("injected stream fault")
        return super().iterate(uid, do_rdma, max_batches)


def test_stream_failure_resumes_individually():
    coord = make_cluster(3, server_cls=FlakyServer)
    got = []
    stats = cluster_scan(coord, SQL, "/d",
                         sink=lambda i, b: got.append(
                             b.column("c0").values.copy()))
    # every stream hit its injected fault once and resumed where it died
    assert stats.resumes == 3
    np.testing.assert_array_equal(np.sort(np.concatenate(got)),
                                  _reference_rows())
    # the faulted leases leaked server-side; the coordinator sweeps them
    assert coord.reclaim_stale(older_than_s=0.0) == 3
    for server in coord.servers.values():
        assert not server.reader_map


def test_pull_fault_releases_pool_checkout():
    """Regression: a fault inside the RDMA pull (after the pool checkout)
    must hand the slabs back — fault-resume loops must not leak."""
    class FaultyFabric(Fabric):
        def __init__(self):
            super().__init__()
            self.faults_left = 1

        def rdma_pull(self, src, dst, registered=False):
            if self.faults_left:
                self.faults_left -= 1
                raise ConnectionError("injected pull fault")
            return super().rdma_pull(src, dst, registered=registered)

    table = make_numeric_table("t", ROWS, 4, batch_rows=4096)
    coord = ClusterCoordinator()
    coord.add_server("s0", ThallusServer(Engine(), FaultyFabric()))
    coord.add_server("s1", ThallusServer(Engine(), Fabric()))
    coord.place_shards("/d", table)
    pool = BufferPool()
    got = []
    stats = cluster_scan(coord, SQL, "/d", pool=pool,
                         sink=lambda i, b: got.append(
                             b.column("c0").values.copy()))
    assert stats.resumes == 1
    assert pool.outstanding == 0
    np.testing.assert_array_equal(np.sort(np.concatenate(got)),
                                  _reference_rows())


def test_stream_failure_exhausts_resumes():
    coord = make_cluster(1, server_cls=FlakyServer)
    coord.server("s0").fail_on_call = 0           # fail every call
    coord.servers["s0"].iterate = lambda *a, **k: (_ for _ in ()).throw(
        ConnectionError("hard down"))
    plan = coord.plan(SQL, "/d")
    puller = MultiStreamPuller(coord, plan, max_resumes=2)
    with pytest.raises(ConnectionError):
        puller.run()


# ------------------------------------------------------------- the loader


def _token_servers(n):
    return token_servers(n)


def test_loader_cluster_mode_parity():
    single = ThallusLoader(_token_servers(1), "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=8, transport="thallus")
    ref = list(single)
    cluster = ThallusLoader(_token_servers(3), "SELECT tokens FROM tok", "/d",
                            seq_len=32, batch_seqs=8, transport="cluster")
    out = list(cluster)
    assert len(out) == len(ref)
    # merged order is schedule-dependent; totals are not
    assert sum(int(c["tokens"].sum()) for c in out) == \
           sum(int(c["tokens"].sum()) for c in ref)
    assert cluster.stats.batches == 6    # 96 seqs / 16 per batch


def test_loader_cluster_honors_global_start_batch():
    """Regression: a bare start_batch (or a single-stream checkpoint with no
    stream_offsets) must skip already-consumed batches, not re-deliver them."""
    kwargs = dict(seq_len=32, batch_seqs=16, transport="cluster")
    full = list(ThallusLoader(_token_servers(2), "SELECT tokens FROM tok",
                              "/d", **kwargs))
    resumed_loader = ThallusLoader(_token_servers(2),
                                   "SELECT tokens FROM tok", "/d",
                                   start_batch=2, **kwargs)
    resumed = list(resumed_loader)
    assert resumed_loader.stats.batches == 4          # 6 total - 2 skipped
    # round-robin order is deterministic, so the tail matches exactly
    assert len(resumed) == len(full) - 2
    for got, want in zip(resumed, full[2:]):
        np.testing.assert_array_equal(got["tokens"], want["tokens"])


def test_loader_cluster_resume_roundtrip():
    loader = ThallusLoader(_token_servers(2), "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=16, transport="cluster")
    it = iter(loader)
    first = [next(it), next(it)]
    ckpt = loader.state_dict()
    assert sum(ckpt["stream_offsets"]) == loader.stats.batches

    resumed = ThallusLoader(_token_servers(2), "SELECT tokens FROM tok", "/d",
                            seq_len=32, batch_seqs=16, transport="cluster")
    resumed.load_state_dict(ckpt)
    rest = list(resumed)
    full = list(ThallusLoader(_token_servers(2), "SELECT tokens FROM tok",
                              "/d", seq_len=32, batch_seqs=16,
                              transport="cluster"))
    assert len(first) + len(rest) == len(full)
    assert sum(int(c["tokens"].sum()) for c in first + rest) == \
           sum(int(c["tokens"].sum()) for c in full)


# -------------------------------------------- stale placements + empty shards


def test_hosts_drops_stale_placement_entries():
    """Regression: a placement naming a server that left the cluster (any
    path that bypassed remove_server's repair) raised KeyError out of
    hosts() and stranded EVERY scan of the dataset. Stale entries are now
    dropped — and reported as ``placement.stale`` — and plan() fails with
    a typed PlacementError only when no host survives."""
    from repro.cluster import PlacementError
    from repro.obs import FlightRecorder

    coord = make_cluster(3, placement="replica")
    coord.recorder = FlightRecorder()
    del coord.servers["s1"]                       # leave WITHOUT repair
    hosts = coord.hosts("/d")
    assert sorted(hosts) == ["s0", "s2"]
    stale = coord.recorder.events(kinds=["placement.stale"])
    assert [e.server_id for e in stale] == ["s1"]
    plan = coord.plan(SQL, "/d", num_streams=2)   # survivors still plan
    assert {e.server_id for e in plan.endpoints} <= {"s0", "s2"}
    coord.servers.clear()
    with pytest.raises(PlacementError):
        coord.plan(SQL, "/d")


def test_empty_shards_plan_and_scan_exactly_once():
    """Regression: place_shards with more servers than batches leaves some
    shards empty; planning then died (min-stream check counted empty
    shards) or opened zero-batch streams. Empty shards are now filtered
    out of the plan and the scan still delivers every row exactly once."""
    table = make_numeric_table("t", 3 * 4096, 2, batch_rows=4096)  # 3 batches
    coord = ClusterCoordinator()
    for i in range(5):
        coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric()))
    coord.place_shards("/d", table)
    plan = coord.plan(SQL, "/d")
    assert plan.num_streams == 3                  # only non-empty shards
    got = []
    cluster_scan(coord, SQL, "/d", sink=lambda i, b: got.append(b))
    ref = reference_batches(SQL, table=table)
    assert sorted(b.column("c0").values.tobytes() for b in got) == \
        sorted(b.column("c0").values.tobytes() for b in ref)


def test_all_shards_empty_raises_typed_error():
    table = make_numeric_table("t", 4096, 2, batch_rows=4096)
    coord = ClusterCoordinator()
    for i in range(2):
        coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric()))
    coord.place_shards("/d", table)
    coord._placements["/d"].assignment = {"s0": (), "s1": ()}
    with pytest.raises(ValueError, match="every shard"):
        coord.plan(SQL, "/d")
