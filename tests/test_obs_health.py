"""repro.obs health/SLO/postmortem layer: the per-server health state
machine (immediate escalation, hysteretic recovery, quarantine mirrored
from the rate history), multi-window burn-rate alerting (fire, latch,
clear, sample floors), the flight-recorder ring + postmortem bundle, and
the coordinator notify funnel end to end through a wired gateway."""
import json

import pytest
from conftest import make_coordinator

from repro.cluster import ClusterCoordinator
from repro.obs import (DEGRADED, HEALTHY, QUARANTINED, SUSPECT, FlightRecorder,
                       HealthConfig, HealthMonitor, MetricsRegistry, SloAlert,
                       SloEngine, SloObjective, Tracer, record_health)
from repro.qos import (AdmissionConfig, ClientClass, DistributedConfig,
                       ScanGateway, ScanRequest, ShardedAdmission)
from repro.sched import AdaptiveScheduler, RateHistory, StealConfig

pytestmark = pytest.mark.obs

SQL = "SELECT c0, c1 FROM t"


# ----------------------------------------------------------- health machine


def test_health_escalates_immediately_recovers_hysteretically():
    mon = HealthMonitor()
    mon.observe_event("stream.fault", "s0", 1.0)
    fired = mon.heartbeat(1.0)
    assert mon.state("s0") == SUSPECT           # escalation: one beat
    assert [t.kind for t in fired] == ["escalate"]
    assert fired[0].is_escalation

    # recovery: recover_heartbeats (2) clean beats per ONE level down
    assert mon.heartbeat(2.0) == []
    assert mon.state("s0") == SUSPECT
    (down,) = mon.heartbeat(3.0)
    assert (down.frm, down.to) == (SUSPECT, DEGRADED)
    assert mon.heartbeat(4.0) == []
    (down,) = mon.heartbeat(5.0)
    assert (down.frm, down.to) == (DEGRADED, HEALTHY)
    assert mon.state("s0") == HEALTHY


def test_health_dirty_beat_resets_recovery_streak():
    mon = HealthMonitor()
    mon.observe_event("stream.fault", "s0", 1.0)
    mon.heartbeat(1.0)
    mon.heartbeat(2.0)                           # clean streak 1
    mon.observe_event("stream.fault", "s0", 2.5)
    mon.heartbeat(3.0)                           # dirty: streak resets
    mon.heartbeat(4.0)
    assert mon.state("s0") == SUSPECT            # one clean beat: no recovery
    mon.heartbeat(5.0)
    assert mon.state("s0") == DEGRADED


def test_health_fault_storm_quarantines_without_history():
    mon = HealthMonitor(HealthConfig(fault_quarantine=3))
    for _ in range(3):
        mon.observe_event("stream.fault", "s0", 1.0)
    mon.heartbeat(1.0)
    assert mon.state("s0") == QUARANTINED
    # the beat after the storm ends: straight to suspect, never healthy
    (down,) = mon.heartbeat(2.0)
    assert (down.frm, down.to) == (QUARANTINED, SUSPECT)


def test_health_degraded_verdicts_from_declines_and_rate():
    hist = RateHistory()
    hist.observe("slow", 40e-6)
    hist.observe("a", 10e-6)
    hist.observe("b", 10e-6)
    mon = HealthMonitor().bind(history=hist)
    mon.observe_event("steal.decline", "thief", 1.0)
    mon.heartbeat(1.0)
    assert mon.state("slow") == DEGRADED         # rate > 2x fleet median
    assert mon.state("thief") == DEGRADED        # steal decline in window
    assert mon.state("a") == mon.state("b") == HEALTHY


def test_health_quarantine_conformant_with_rate_history():
    """The acceptance criterion: in a fault-free run the monitor's
    quarantine verdicts are exactly ``RateHistory.quarantined``'s — both
    while the history holds the server and after the quarantine lifts,
    driven by a recorded flap observation trace."""
    hist = RateHistory(quarantine_rounds=3)
    mon = HealthMonitor().bind(history=hist)
    # the flap trace: fast -> slow -> fast (> flap_ratio both ways)
    trace = [("f", 10e-6), ("f", 30e-6), ("f", 10e-6), ("ok", 10e-6)]
    for sid, rate in trace:
        hist.observe(sid, rate)
    hist.tick()
    assert hist.quarantined("f") and not hist.quarantined("ok")

    beat = 0
    while hist.quarantined("f"):
        beat += 1
        mon.heartbeat(float(beat))
        for sid in ("f", "ok"):
            assert (mon.state(sid) == QUARANTINED) == hist.quarantined(sid)
        hist.tick()                              # a lease round passes
    # quarantine lifted: the next heartbeat must agree again (suspect, not
    # quarantined) and then hysteresis takes it the rest of the way down
    mon.heartbeat(float(beat + 1))
    assert mon.state("f") == SUSPECT
    for sid in ("f", "ok"):
        assert (mon.state(sid) == QUARANTINED) == hist.quarantined(sid)


def test_health_snapshot_and_registry_rollup():
    mon = HealthMonitor()
    mon.observe_event("stream.fault", "s1", 1.0)
    mon.heartbeat(1.0)
    snap = mon.snapshot()
    assert snap["heartbeats"] == 1
    assert snap["servers"]["s1"]["state"] == SUSPECT
    assert snap["servers"]["s1"]["faults"] == 1

    reg = MetricsRegistry()
    record_health(reg, mon)
    out = reg.snapshot()
    assert out["health.heartbeats"] == 1
    assert out["health.server.s1.level"] == 2.0   # suspect
    assert out["health.server.s1.faults"] == 1

    from repro.utils.report import health_table
    table = health_table(mon)
    assert "s1" in table and SUSPECT in table and "heartbeats=1" in table


# ------------------------------------------------------------ slo burn rate


def _snapshot(value):
    return {"m.us": value}


def _engine(goal=0.75, windows=((10.0, 1.0), (2.0, 1.0)), min_samples=3):
    return SloEngine([SloObjective("obj", "m.us", target=100.0, goal=goal,
                                   windows=windows, min_samples=min_samples)])


def test_slo_fires_latches_and_clears():
    eng = _engine()
    seen = []
    eng.subscribe(seen.append)
    assert eng.observe(1.0, _snapshot(200.0)) == []    # below min_samples
    assert eng.observe(2.0, _snapshot(200.0)) == []
    (alert,) = eng.observe(3.0, _snapshot(200.0))
    assert isinstance(alert, SloAlert) and alert.is_page
    assert alert.n_samples == 3 and alert.value == 200.0
    assert all(b == pytest.approx(4.0) for b in alert.burns)  # 1.0 / 0.25
    assert seen == [alert] and eng.firing("obj")

    assert eng.observe(4.0, _snapshot(200.0)) == []    # latched: no re-page
    assert len(eng.alerts) == 1

    for t in (5.0, 6.0, 7.0):                          # good samples drain
        eng.observe(t, _snapshot(50.0))                # the short window
    assert not eng.firing("obj") and eng.resolved == 1

    for t in (8.0, 9.0, 10.0):                         # re-breach: new alert
        eng.observe(t, _snapshot(200.0))
    assert len(eng.alerts) == 2


def test_slo_long_window_blocks_one_bad_sample():
    """One bad scan inside a clean long window must NOT page: the long
    window's burn stays under threshold even though the short one spikes."""
    eng = _engine()
    for t in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0):
        eng.observe(t, _snapshot(50.0))
    fired = eng.observe(8.0, _snapshot(500.0))
    assert fired == [] and not eng.firing("obj")


def test_slo_skips_missing_and_non_numeric_metrics():
    eng = _engine(min_samples=1)
    assert eng.observe(1.0, {}) == []
    assert eng.observe(2.0, {"m.us": None}) == []
    assert eng.observe(3.0, {"m.us": "n/a"}) == []
    assert eng.observe(4.0, {"m.us": True}) == []      # bools excluded
    (alert,) = eng.observe(5.0, _snapshot(200.0))
    assert alert.n_samples == 1                        # only the real sample


def test_slo_better_higher_objective():
    eng = SloEngine([SloObjective("done", "n", target=24.0, better="higher",
                                  goal=0.5, windows=((10.0, 1.0),),
                                  min_samples=1)])
    assert eng.observe(1.0, {"n": 24.0}) == []
    (alert,) = eng.observe(2.0, {"n": 7.0})
    assert alert.objective == "done"


# ---------------------------------------------------------- flight recorder


def test_recorder_ring_bounds_and_filters():
    rec = FlightRecorder(capacity=4)
    for i in range(6):
        rec.record("steal" if i % 2 else "qos.shed", now_s=float(i),
                   server_id=f"s{i}")
    assert len(rec) == 4 and rec.dropped == 2
    evs = rec.events()
    assert [e.seq for e in evs] == [2, 3, 4, 5]        # oldest first
    assert [e.kind for e in rec.events(kinds={"steal"})] == ["steal"] * 2
    assert len(rec.events(last_n=1)) == 1
    assert rec.counts() == {"qos.shed": 2, "steal": 2}
    assert "steal" in str(evs[1]) and evs[1].attrs == {}


def test_recorder_postmortem_bundle_and_dump(tmp_path):
    rec = FlightRecorder()
    rec.record("steal.decline", now_s=1.0, server_id="s4", victim="s2")
    mon = HealthMonitor(recorder=rec)
    mon.observe_event("stream.fault", "s2", 2.0)
    mon.heartbeat(2.0)
    reg = MetricsRegistry()
    reg.gauge("x.us", 3.0)
    tracer = Tracer()
    tracer.begin("scan").commit()
    alert = SloAlert(kind="burn_rate", objective="o", metric="x.us",
                     value=3.0, target=1.0, goal=0.75, burns=(4.0,),
                     windows=((1.0, 1.0),), now_s=2.0, n_samples=3)

    path = rec.dump(str(tmp_path / "pm" / "bundle.json"), trigger=alert,
                    registry=reg, health=mon, tracer=tracer)
    bundle = json.load(open(path))
    assert bundle["trigger"]["objective"] == "o"
    kinds = [e["kind"] for e in bundle["events"]]
    assert "steal.decline" in kinds and "health.escalate" in kinds
    assert bundle["event_counts"]["steal.decline"] == 1
    assert bundle["registry"]["x.us"] == 3.0
    assert bundle["health"]["servers"]["s2"]["state"] == SUSPECT
    assert bundle["health_transitions"]
    assert "traceEvents" in bundle["trace"]


# ------------------------------------------------- coordinator notify funnel


def test_coordinator_notify_fans_out_to_recorder_and_health():
    rec = FlightRecorder()
    mon = HealthMonitor()
    coord = ClusterCoordinator(recorder=rec, health=mon)
    coord.notify("stream.fault", server_id="s0", now_s=1.0, delivered=3)
    assert rec.events()[0].attrs == {"delivered": 3}
    assert mon.servers["s0"].window_faults == 1
    assert coord.heartbeat(1.0)[0].to == SUSPECT

    bare = ClusterCoordinator()                  # both sinks absent: no-ops
    bare.notify("stream.fault", server_id="s0", now_s=1.0)
    assert bare.heartbeat(1.0) == []

    from repro.cluster.streams import notify_coordinator
    notify_coordinator(object(), "steal")        # no .notify: tolerated
    notify_coordinator(None, "steal")


def test_gateway_degradation_pages_with_causal_events():
    """End to end: a straggling replica behind a wired gateway trips the
    burn-rate engine, and the causal steal events are in the recorder."""
    rec = FlightRecorder()
    hist = RateHistory(quarantine_rounds=64)
    mon = HealthMonitor(recorder=rec).bind(history=hist)
    eng = SloEngine()
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=8),
        [f"s{i}" for i in range(4)],
        dist=DistributedConfig(borrow_limit=0))
    admission.recorder = rec
    coord = make_coordinator(4, "replica", slow=1, slowdown=4.0,
                             admission=admission)
    coord.recorder = rec
    coord.health = mon
    mon.bind(admission=admission)
    gateway = ScanGateway(
        coord, classes=[ClientClass("batch", 1.0)],
        scheduler=AdaptiveScheduler(steal=StealConfig(), history=hist))

    alerts = []
    for hb in range(1, 5):
        # 2 of 4 replicas leased (the s1 straggler among them): s2/s3 idle
        req = gateway.submit(ScanRequest("c", "batch", SQL, "/d",
                                         arrival_s=gateway.clock_s,
                                         num_streams=2))
        gateway.run()
        result = gateway.results[req.request_id]
        cp_us = result.cluster.modeled_critical_path_s * 1e6
        coord.heartbeat(gateway.clock_s)
        if hb == 1:                  # calibrate a deliberately tight target
            eng.add(SloObjective("cp", "cp.us", target=0.9 * cp_us,
                                 goal=0.75, windows=((1e3, 1.0),),
                                 min_samples=2))
        alerts += eng.observe(gateway.clock_s, {"cp.us": cp_us})
    assert alerts and alerts[0].objective == "cp"
    assert rec.counts().get("steal", 0) >= 1     # the causal event survives
    # straggler marked unhealthy by rate vs fleet median at SOME heartbeat
    assert any(t.server_id == "s1" and t.is_escalation
               for t in mon.transitions)
