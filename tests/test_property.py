"""Hypothesis property tests for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Fabric, RpcTransport, ThallusTransport,
                        batch_from_pydict, pack, schema, unpack,
                        pack_validity, unpack_validity, expose_batch,
                        allocate_like, assemble_batch)
from repro.kernels.pack import pack_segments, unpack_segments
from repro.engine import Engine, make_numeric_table

# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

_ints = st.one_of(st.none(), st.integers(-2**40, 2**40))
_floats = st.one_of(st.none(), st.floats(allow_nan=False, allow_infinity=False,
                                         width=32))
_strs = st.one_of(st.none(), st.text(max_size=12))


@st.composite
def batches(draw):
    n = draw(st.integers(1, 40))
    data = {
        "i": draw(st.lists(_ints, min_size=n, max_size=n)),
        "f": draw(st.lists(_floats, min_size=n, max_size=n)),
        "s": draw(st.lists(_strs, min_size=n, max_size=n)),
    }
    sch = schema(("i", "int64"), ("f", "float32"), ("s", "utf8"))
    return batch_from_pydict(sch, data)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(batches())
def test_serialize_roundtrip_any_batch(batch):
    assert unpack(pack(batch)).to_pydict() == batch.to_pydict()


@settings(max_examples=40, deadline=None)
@given(batches())
def test_transports_agree_any_batch(batch):
    fabric = Fabric()
    rpc_out, _ = RpcTransport(fabric).send_batch(batch)
    th_out, th_stats = ThallusTransport(fabric).send_batch(batch)
    assert rpc_out.to_pydict() == th_out.to_pydict() == batch.to_pydict()
    assert th_stats.serialize_s == 0.0          # zero-copy invariant


@settings(max_examples=40, deadline=None)
@given(batches())
def test_bulk_expose_assemble_roundtrip(batch):
    remote = expose_batch(batch)
    local = allocate_like(remote.descs)
    for s, d in zip(remote.segments, local.segments):
        if s.nbytes:
            d.view(np.uint8).reshape(-1)[:] = s.view(np.uint8).reshape(-1)
    out = assemble_batch(batch.schema, batch.num_rows, local.segments)
    assert out.to_pydict() == batch.to_pydict()
    # conservation: RDMA'd bytes == batch payload bytes
    assert remote.total_bytes == batch.nbytes


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 300), min_size=1, max_size=8),
       st.integers(0, 2**32 - 1))
def test_pack_kernel_roundtrip_any_segments(lens, seed):
    rng = np.random.default_rng(seed)
    segs = [rng.integers(0, 256, n).astype(np.uint8) for n in lens]
    packed, out_lens = pack_segments(segs)
    outs = unpack_segments(packed, out_lens)
    for s, o in zip(segs, outs):
        np.testing.assert_array_equal(s, o)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 500), st.integers(0, 2**32 - 1))
def test_validity_roundtrip(n, seed):
    mask = np.random.default_rng(seed).integers(0, 2, n).astype(bool)
    assert (unpack_validity(pack_validity(mask), n) == mask).all()


@settings(max_examples=10, deadline=None)
@given(num_servers=st.integers(2, 4),
       placement=st.sampled_from(["shard", "replica"]),
       num_subscribers=st.integers(2, 4),
       slow_server=st.integers(0, 3),
       slowdown=st.floats(1.0, 8.0),
       steal_factor=st.floats(1.1, 3.0),
       batch_rows=st.sampled_from([256, 512, 1024]))
def test_multicast_subscribers_byte_identical(num_servers, placement,
                                              num_subscribers, slow_server,
                                              slowdown, steal_factor,
                                              batch_rows):
    """repro.sched invariant: however the scan is split (shard vs replica,
    any batch granularity), however lopsided the fleet, and wherever work
    stealing decides to cut (the slowdown and steal factor move the steal
    point), the shared-ticket multicast hands every subscriber output
    byte-identical to a solo scan."""
    from repro.core import FabricConfig, ThallusServer
    from repro.core.protocol import ThallusClient
    from repro.cluster import ClusterCoordinator
    from repro.qos import ScanGateway, ScanRequest
    from repro.sched import AdaptiveScheduler, StealConfig, TicketTable

    table = make_numeric_table("t", 4096, 2, batch_rows=batch_rows)
    sql = "SELECT c0, c1 FROM t"
    coord = ClusterCoordinator()
    for i in range(num_servers):
        cfg = FabricConfig()
        if i == slow_server % num_servers:
            cfg = FabricConfig(rpc_bw=cfg.rpc_bw / slowdown,
                               rdma_bw=cfg.rdma_bw / slowdown)
        coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric(cfg)))
    if placement == "shard":
        coord.place_shards("/d", table)
    else:
        coord.place_replicas("/d", table)
    gateway = ScanGateway(coord, scheduler=AdaptiveScheduler(
        steal=StealConfig(factor=steal_factor, min_batches=1),
        tickets=TicketTable()))
    reqs = [gateway.submit(ScanRequest(f"c{i}", "interactive", sql, "/d"))
            for i in range(num_subscribers)]
    gateway.run()

    eng = Engine()
    eng.register("/d", table)
    solo = ThallusClient(ThallusServer(eng, Fabric())).run_query(sql, "/d")
    solo_dicts = [b.to_pydict() for b in solo]
    shared = 0
    for req in reqs:
        result = gateway.result(req.request_id)
        shared += int(result.shared)
        assert [b.to_pydict() for b in result.batches] == solo_dicts
    assert shared == num_subscribers - 1     # exactly one fan-out ran


@settings(max_examples=15, deadline=None)
@given(st.floats(-2.0, 2.0), st.integers(1, 4))
def test_engine_filter_conservation(threshold, ncols):
    """rows(WHERE c0 > t) + rows(WHERE NOT c0 > t) == rows (null-free)."""
    eng = Engine()
    eng.register("/t", make_numeric_table("t", 2000, ncols, batch_rows=512))
    a = sum(b.num_rows for b in
            eng.execute(f"SELECT c0 FROM t WHERE c0 > {threshold}", "/t").read_all())
    b = sum(b.num_rows for b in
            eng.execute(f"SELECT c0 FROM t WHERE NOT c0 > {threshold}", "/t").read_all())
    assert a + b == 2000
