"""Hypothesis property tests for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

# the hypothesis suites run in their own CI job (pytest -m slow) so the
# tier-1 smoke job stays fast; `pytest -q` still runs everything
pytestmark = pytest.mark.slow

from repro.core import (Fabric, RpcTransport, ThallusTransport,
                        batch_from_pydict, pack, schema, unpack,
                        pack_validity, unpack_validity, expose_batch,
                        allocate_like, assemble_batch)
from repro.kernels.pack import pack_segments, unpack_segments
from repro.engine import Engine, make_numeric_table

# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

_ints = st.one_of(st.none(), st.integers(-2**40, 2**40))
_floats = st.one_of(st.none(), st.floats(allow_nan=False, allow_infinity=False,
                                         width=32))
_strs = st.one_of(st.none(), st.text(max_size=12))


@st.composite
def batches(draw):
    n = draw(st.integers(1, 40))
    data = {
        "i": draw(st.lists(_ints, min_size=n, max_size=n)),
        "f": draw(st.lists(_floats, min_size=n, max_size=n)),
        "s": draw(st.lists(_strs, min_size=n, max_size=n)),
    }
    sch = schema(("i", "int64"), ("f", "float32"), ("s", "utf8"))
    return batch_from_pydict(sch, data)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(batches())
def test_serialize_roundtrip_any_batch(batch):
    assert unpack(pack(batch)).to_pydict() == batch.to_pydict()


@settings(max_examples=40, deadline=None)
@given(batches())
def test_transports_agree_any_batch(batch):
    fabric = Fabric()
    rpc_out, _ = RpcTransport(fabric).send_batch(batch)
    th_out, th_stats = ThallusTransport(fabric).send_batch(batch)
    assert rpc_out.to_pydict() == th_out.to_pydict() == batch.to_pydict()
    assert th_stats.serialize_s == 0.0          # zero-copy invariant


@settings(max_examples=40, deadline=None)
@given(batches())
def test_bulk_expose_assemble_roundtrip(batch):
    remote = expose_batch(batch)
    local = allocate_like(remote.descs)
    for s, d in zip(remote.segments, local.segments):
        if s.nbytes:
            d.view(np.uint8).reshape(-1)[:] = s.view(np.uint8).reshape(-1)
    out = assemble_batch(batch.schema, batch.num_rows, local.segments)
    assert out.to_pydict() == batch.to_pydict()
    # conservation: RDMA'd bytes == batch payload bytes
    assert remote.total_bytes == batch.nbytes


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 300), min_size=1, max_size=8),
       st.integers(0, 2**32 - 1))
def test_pack_kernel_roundtrip_any_segments(lens, seed):
    rng = np.random.default_rng(seed)
    segs = [rng.integers(0, 256, n).astype(np.uint8) for n in lens]
    packed, out_lens = pack_segments(segs)
    outs = unpack_segments(packed, out_lens)
    for s, o in zip(segs, outs):
        np.testing.assert_array_equal(s, o)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 500), st.integers(0, 2**32 - 1))
def test_validity_roundtrip(n, seed):
    mask = np.random.default_rng(seed).integers(0, 2, n).astype(bool)
    assert (unpack_validity(pack_validity(mask), n) == mask).all()


@settings(max_examples=10, deadline=None)
@given(num_servers=st.integers(2, 4),
       placement=st.sampled_from(["shard", "replica"]),
       num_subscribers=st.integers(2, 4),
       slow_server=st.integers(0, 3),
       slowdown=st.floats(1.0, 8.0),
       steal_factor=st.floats(1.1, 3.0),
       batch_rows=st.sampled_from([256, 512, 1024]))
def test_multicast_subscribers_byte_identical(num_servers, placement,
                                              num_subscribers, slow_server,
                                              slowdown, steal_factor,
                                              batch_rows):
    """repro.sched invariant: however the scan is split (shard vs replica,
    any batch granularity), however lopsided the fleet, and wherever work
    stealing decides to cut (the slowdown and steal factor move the steal
    point), the shared-ticket multicast hands every subscriber output
    byte-identical to a solo scan."""
    from repro.core import FabricConfig, ThallusServer
    from repro.core.protocol import ThallusClient
    from repro.cluster import ClusterCoordinator
    from repro.qos import ScanGateway, ScanRequest
    from repro.sched import AdaptiveScheduler, StealConfig, TicketTable

    table = make_numeric_table("t", 4096, 2, batch_rows=batch_rows)
    sql = "SELECT c0, c1 FROM t"
    coord = ClusterCoordinator()
    for i in range(num_servers):
        cfg = FabricConfig()
        if i == slow_server % num_servers:
            cfg = FabricConfig(rpc_bw=cfg.rpc_bw / slowdown,
                               rdma_bw=cfg.rdma_bw / slowdown)
        coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric(cfg)))
    if placement == "shard":
        coord.place_shards("/d", table)
    else:
        coord.place_replicas("/d", table)
    gateway = ScanGateway(coord, scheduler=AdaptiveScheduler(
        steal=StealConfig(factor=steal_factor, min_batches=1),
        tickets=TicketTable()))
    reqs = [gateway.submit(ScanRequest(f"c{i}", "interactive", sql, "/d"))
            for i in range(num_subscribers)]
    gateway.run()

    eng = Engine()
    eng.register("/d", table)
    solo = ThallusClient(ThallusServer(eng, Fabric())).run_query(sql, "/d")
    solo_dicts = [b.to_pydict() for b in solo]
    shared = 0
    for req in reqs:
        result = gateway.result(req.request_id)
        shared += int(result.shared)
        assert [b.to_pydict() for b in result.batches] == solo_dicts
    assert shared == num_subscribers - 1     # exactly one fan-out ran


@st.composite
def admission_traces(draw):
    """Random interleavings of acquires, releases, leases and reconciles
    across 2-5 shards and a small client pool, at non-decreasing modeled
    times. Borrows are implicit: any acquire routed to a saturated shard
    exercises the borrow path."""
    num_shards = draw(st.integers(2, 5))
    num_clients = draw(st.integers(1, 3))
    quota = draw(st.integers(1, 6))
    cap = draw(st.one_of(st.none(), st.integers(2, 10)))
    rate = draw(st.floats(10.0, 1000.0))
    burst = draw(st.integers(num_shards, 4 * num_shards))
    ops, now_s = [], 0.0
    for _ in range(draw(st.integers(5, 60))):
        now_s += draw(st.floats(0.0, 20e-3))
        kind = draw(st.sampled_from(
            ["acquire", "acquire", "acquire", "release", "lease",
             "reconcile"]))
        client = f"c{draw(st.integers(0, num_clients - 1))}"
        server = f"s{draw(st.integers(0, num_shards - 1))}"
        ops.append((kind, client, server, now_s,
                    draw(st.integers(1, 3))))
    return num_shards, quota, cap, rate, burst, ops


@settings(max_examples=60, deadline=None)
@given(admission_traces())
def test_sharded_admission_invariants(trace):
    """repro.qos.distributed invariants under random interleavings of
    acquires, releases, borrows and reconciles across 2-5 shards:
    (a) concurrently granted streams never exceed the global quota (per
    client) or the global cap (cluster-wide), (b) lease tokens are conserved
    across rebalances — no shard pair creates or destroys tokens — and
    (c) every Backpressure carries a positive ``retry_after_s``."""
    from repro.qos import (AdmissionConfig, Backpressure, ShardedAdmission)

    num_shards, quota, cap, rate, burst, ops = trace
    sharded = ShardedAdmission(
        AdmissionConfig(max_streams_per_client=quota, max_streams_total=cap,
                        lease_rate_per_s=rate, lease_burst=burst),
        [f"s{i}" for i in range(num_shards)])
    held: dict[tuple[str, str], int] = {}
    for kind, client, server, now_s, n in ops:
        if kind == "acquire":
            try:
                sharded.acquire_stream(client, server_id=server)
                held[(client, server)] = held.get((client, server), 0) + 1
            except Backpressure as e:
                assert e.retry_after_s > 0                      # (c)
        elif kind == "release":
            if held.get((client, server), 0) > 0:
                held[(client, server)] -= 1
                sharded.release_stream(client, server_id=server,
                                       now_s=now_s)
        elif kind == "lease":
            assert sharded.lease_wait_s(now_s, n, server_id=server) >= 0.0
        else:
            report = sharded.reconcile(now_s)
            assert report.tokens_after == \
                pytest.approx(report.tokens_before)             # (b)
        for c in {c for c, _ in held}:
            assert sharded.active_streams(c) <= quota           # (a)
        if cap is not None:
            assert sharded.active_total() <= cap                # (a)
    # the ledger matches the model's bookkeeping exactly
    for c in {c for c, _ in held}:
        assert sharded.active_streams(c) == \
            sum(v for (cc, _), v in held.items() if cc == c)
    # and tokens never exceed the global burst, however they were shuffled
    last = max((op[3] for op in ops), default=0.0)
    total = sum(s.tokens_at(last) for s in sharded.shards.values())
    assert total <= burst + 1e-9


def _recording_history(**kwargs):
    """A RateHistory that also logs raw observations (``.seen``) so the
    EWMA bound invariant can be checked against exactly what the scheduler
    saw. (Defined as a factory so the repro.sched import stays lazy.)"""
    from repro.sched import RateHistory

    class Recording(RateHistory):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.seen = {}

        def observe(self, server_id, rate_s):
            if rate_s > 0:
                self.seen.setdefault(server_id, []).append(rate_s)
            super().observe(server_id, rate_s)

    return Recording(**kwargs)


_CHAOS_TABLE = make_numeric_table("chaos", 1 << 16, 2, batch_rows=1 << 12)
_CHAOS_SQL = "SELECT c0, c1 FROM chaos"                  # 16 batches


@st.composite
def steal_chaos(draw):
    """A random cluster under the steal scheduler: per-server rate schedules
    (steady, degrading, flapping), a sharded admission budget with borrowing
    off, foreign tenants squatting on random shards, and one mid-scan
    freed-slot release — the interleavings that drive steal, decline and
    re-steal decisions."""
    num_servers = draw(st.integers(2, 5))
    factor = st.sampled_from([1.0, 1.0, 2.0, 4.0, 8.0])
    schedules = [draw(st.lists(factor, min_size=1, max_size=5))
                 for _ in range(num_servers)]
    extra_cap = draw(st.integers(0, num_servers))
    squatters = draw(st.lists(st.integers(0, num_servers - 1), max_size=3))
    release_after = draw(st.integers(1, 14))
    knobs = dict(
        alpha=draw(st.floats(0.1, 1.0)),
        flap_ratio=draw(st.floats(1.5, 4.0)),
        quarantine_rounds=draw(st.integers(1, 12)),
        repeat_decay=draw(st.floats(0.5, 1.0)),
    )
    steal = dict(
        factor=draw(st.floats(1.2, 2.5)),
        min_batches=draw(st.integers(1, 3)),
        steal_headroom_min=draw(st.integers(1, 2)),
        resteal_margin=draw(st.floats(1.0, 2.0)),
    )
    return (num_servers, schedules, extra_cap, squatters, release_after,
            knobs, steal)


@settings(max_examples=15, deadline=None)
@given(steal_chaos())
def test_steal_chaos_invariants(chaos):
    """The scheduler chaos harness: under random per-server rate schedules
    and steal/decline/re-steal interleavings over 2-5 admission shards,
    (a) no shard ever admits past its local slice and the cluster never
    exceeds the global cap, (b) every batch index is delivered exactly once
    — byte-identical to the solo scan however the ranges migrated, (c) the
    RateHistory EWMA stays within the min/max of the rates it observed, and
    (d) re-steals never exceed steals (one re-steal per range)."""
    from repro.cluster import ClusterCoordinator
    from repro.core import FlappingFabric, ThallusServer
    from repro.qos import (AdmissionConfig, Backpressure, DistributedConfig,
                           ShardedAdmission)
    from repro.sched import StealConfig, StealingPuller

    (num_servers, schedules, extra_cap, squatters, release_after, knobs,
     steal) = chaos
    ids = [f"s{i}" for i in range(num_servers)]
    cap = num_servers + extra_cap
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=cap), ids,
        dist=DistributedConfig(borrow_limit=0))
    coord = ClusterCoordinator(admission=admission)
    for sid, schedule in zip(ids, schedules):
        coord.add_server(sid, ThallusServer(
            Engine(), FlappingFabric(schedule=schedule)))
    coord.place_replicas("/d", _CHAOS_TABLE)
    history = _recording_history(**knobs)
    puller = StealingPuller(coord,
                            coord.plan(_CHAOS_SQL, "/d",
                                       num_streams=num_servers),
                            steal=StealConfig(**steal), history=history,
                            client_id="chaos")
    held = []
    for shard_idx in squatters:                 # foreign tenants squat
        try:
            admission.acquire_stream("squatter", server_id=ids[shard_idx])
            held.append(ids[shard_idx])
        except Backpressure:
            pass
    got, delivered = {}, 0
    for idx, batch in puller.batches():
        got.setdefault(idx, []).append(batch)
        delivered += 1
        if delivered == release_after and held:  # a freed-slot event
            admission.release_stream("squatter", server_id=held.pop())
    stats = puller.stats()
    # (a) shard-local and global admission safety, even through declines
    for sid, shard in admission.shards.items():
        assert shard.stats.peak_active <= shard.config.max_streams_total
    assert admission.peak_total <= cap
    assert stats.declines >= 0 and all(
        getattr(e, "server_id", "") for e in stats.steal_events)
    # (b) exactly-once delivery in global scan order
    order = sorted(range(len(puller.pullers)),
                   key=lambda i: puller.pullers[i].endpoint.start_batch)
    flat = [b for i in order for b in got.get(i, [])]
    solo = Engine()
    solo.register("/d", _CHAOS_TABLE)
    ref = list(solo.execute(_CHAOS_SQL, "/d").read_all())
    assert len(flat) == len(ref) == 16
    for g, r in zip(flat, ref):
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)
        np.testing.assert_array_equal(g.column("c1").values,
                                      r.column("c1").values)
    # (c) the EWMA never leaves the envelope of observed rates
    for sid, rates in history.seen.items():
        ewma = history.rate_for(sid)
        assert min(rates) - 1e-12 <= ewma <= max(rates) + 1e-12
    # (d) one re-steal per range: re-steals can never outnumber steals
    assert stats.re_steals <= stats.steals
    # nothing leaked: the scan's own streams all closed
    assert admission.active_streams("chaos") == 0


@settings(max_examples=15, deadline=None)
@given(st.floats(-2.0, 2.0), st.integers(1, 4))
def test_engine_filter_conservation(threshold, ncols):
    """rows(WHERE c0 > t) + rows(WHERE NOT c0 > t) == rows (null-free)."""
    eng = Engine()
    eng.register("/t", make_numeric_table("t", 2000, ncols, batch_rows=512))
    a = sum(b.num_rows for b in
            eng.execute(f"SELECT c0 FROM t WHERE c0 > {threshold}", "/t").read_all())
    b = sum(b.num_rows for b in
            eng.execute(f"SELECT c0 FROM t WHERE NOT c0 > {threshold}", "/t").read_all())
    assert a + b == 2000
