"""Columnar layer: batches, bitmaps, slicing, zero-copy guarantees."""
import numpy as np
import pytest

from repro.core import (Column, Field, RecordBatch, batch_from_arrays,
                        batch_from_pydict, concat_batches, pack_validity,
                        schema, unpack_validity)


@pytest.fixture
def mixed_batch():
    sch = schema(("id", "int64"), ("x", "float32"), ("name", "utf8"),
                 ("flag", "bool"))
    return batch_from_pydict(sch, {
        "id": [1, 2, None, 4, 5],
        "x": [0.5, None, 2.5, 3.5, None],
        "name": ["a", "bb", None, "dddd", ""],
        "flag": [True, False, True, None, False],
    })


def test_roundtrip_pydict(mixed_batch):
    d = mixed_batch.to_pydict()
    assert d["id"] == [1, 2, None, 4, 5]
    assert d["name"] == ["a", "bb", None, "dddd", ""]
    assert mixed_batch.num_rows == 5
    assert mixed_batch.num_columns == 4


def test_validity_bitmap_roundtrip(rng):
    for n in (1, 7, 8, 9, 64, 1000):
        mask = rng.integers(0, 2, n).astype(bool)
        np.testing.assert_array_equal(unpack_validity(pack_validity(mask), n),
                                      mask)


def test_null_counts(mixed_batch):
    assert [c.null_count() for c in mixed_batch] == [1, 2, 1, 1]


def test_select_is_zero_copy(mixed_batch):
    proj = mixed_batch.select(["x", "id"])
    assert proj.schema.names == ("x", "id")
    assert proj.column("x").values is mixed_batch.column("x").values
    assert proj.column("id").values is mixed_batch.column("id").values


def test_slice_fixed_width_is_view(mixed_batch):
    sl = mixed_batch.slice(1, 3)
    assert sl.num_rows == 3
    assert sl.column("id").values.base is not None  # numpy view
    assert sl.to_pydict()["id"] == [2, None, 4]
    assert sl.to_pydict()["name"] == ["bb", None, "dddd"]


def test_take_varlen(mixed_batch):
    out = mixed_batch.take(np.array([4, 3, 0]))
    assert out.to_pydict()["name"] == ["", "dddd", "a"]
    assert out.to_pydict()["id"] == [5, 4, 1]


def test_concat(mixed_batch):
    both = concat_batches([mixed_batch, mixed_batch.slice(0, 2)])
    assert both.num_rows == 7
    assert both.to_pydict()["name"][-2:] == ["a", "bb"]


def test_ragged_rejected():
    f1, f2 = Field("a", "int32"), Field("b", "int32")
    with pytest.raises(ValueError, match="ragged"):
        RecordBatch(schema(("a", "int32"), ("b", "int32")), (
            Column(f1, np.zeros(3, np.int32)),
            Column(f2, np.zeros(4, np.int32))))


def test_batch_from_arrays_rejects_varlen():
    with pytest.raises(ValueError):
        batch_from_arrays(schema(("s", "utf8")), [np.zeros(3, np.uint8)])
