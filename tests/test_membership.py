"""Elastic membership + nemesis fault injection: live join/leave/evict,
in-flight lease migration, health-driven evict/re-admit, and the seeded
fault schedule's determinism guarantees."""
import numpy as np
import pytest
from conftest import make_coordinator, reference_batches

from repro.cluster import (ClusterCoordinator, FaultSpec, MembershipController,
                           MigrationError, Nemesis, cluster_scan,
                           seeded_schedule)
from repro.core import Fabric, FabricConfig, ServerCrashedError, ThallusServer
from repro.engine import Engine, make_numeric_table
from repro.obs import FlightRecorder, HealthMonitor
from repro.qos import (AdmissionConfig, ClientClass, ScanGateway, ScanRequest,
                       ShardedAdmission)

ROWS = 40_000
SQL = "SELECT c0, c1 FROM t"


def scan_signature(coord, sql=SQL, dataset="/d", **kw):
    """Byte signature of a full cluster scan, in arrival order — compare as
    a multiset (``sorted``): the exactly-once witness."""
    got = []
    cluster_scan(coord, sql, dataset,
                 sink=lambda i, b: got.append(b), **kw)
    return [tuple(c.values.tobytes() for c in b.columns) for b in got]


def ordered_signature(coord, sql=SQL, num_streams=None):
    """Byte signature through the gateway's reassembly — global dataset
    order, the stronger byte-identical-delivery witness."""
    gw = ScanGateway(coord, classes=[ClientClass("c", 1.0)])
    gw.submit(ScanRequest("t", "c", sql, "/d", num_streams=num_streams))
    (result,) = gw.run()
    return [tuple(c.values.tobytes() for c in b.columns)
            for b in result.batches]


def reference_signature(sql=SQL, rows=ROWS):
    return [tuple(c.values.tobytes() for c in b.columns)
            for b in reference_batches(sql, rows=rows)]


# ------------------------------------------------ live leave/join re-placement


def test_remove_server_redeals_shards_exactly_once():
    """A shard server leaving re-deals ONLY its orphaned batches: survivors
    keep everything they held (minimal movement), and a scan after the
    repair still delivers every row exactly once."""
    coord = make_coordinator(4)
    before = dict(coord._placements["/d"].assignment)
    orphans = set(before["s1"])
    coord.remove_server("s1")
    after = coord._placements["/d"].assignment
    assert "s1" not in after
    for sid in ("s0", "s2", "s3"):
        assert set(before[sid]) <= set(after[sid])   # survivors keep theirs
    moved = set().union(*(set(after[s]) - set(before[s])
                          for s in ("s0", "s2", "s3")))
    assert moved == orphans                          # only orphans moved
    assert sorted(scan_signature(coord)) == sorted(reference_signature())


def test_add_server_rebalance_minimal_movement():
    """A live join takes ⌊batches/n⌋ slices from the largest shards — and
    the re-placed cluster still scans exactly-once."""
    coord = make_coordinator(3)
    before = dict(coord._placements["/d"].assignment)
    total = sum(len(v) for v in before.values())
    coord.add_server("s3", ThallusServer(Engine(), Fabric()),
                     rebalance=True)
    after = coord._placements["/d"].assignment
    assert len(after["s3"]) == total // 4
    for sid in ("s0", "s1", "s2"):                   # donors keep a prefix
        assert set(after[sid]) <= set(before[sid])
    assert sorted(scan_signature(coord)) == sorted(reference_signature())


def test_scan_parity_after_irregular_redeal():
    """After a leave the shards are no longer a regular ``i::n`` deal; the
    reassembled result must still come back in dataset order (the
    ``global_batches``-sorted path, not the legacy interleave)."""
    coord = make_coordinator(4)
    coord.remove_server("s2")
    assert ordered_signature(coord) == reference_signature()


# ------------------------------------------------- in-flight lease migration


def test_midlease_failover_is_byte_identical():
    """A replica dies MID-LEASE (after shipping one more batch); the lease
    migrates to a surviving replica via init_scan(start_batch=delivered)
    and the scan's total delivery is byte-identical — no loss, no re-ship."""
    recorder = FlightRecorder()
    coord = make_coordinator(3, placement="replica")
    coord.recorder = recorder
    coord.server("s0").crash(after_batches=1)
    assert ordered_signature(coord, num_streams=3) == reference_signature()
    migrates = recorder.events(kinds=["stream.migrate"])
    assert migrates and migrates[0].server_id == "s0"
    assert migrates[0].attrs["delivered"] >= 1       # the shipped prefix


def test_open_time_failover_when_server_already_dead():
    """A stream planned onto an already-crashed replica opens directly on
    the failover target instead of failing the whole scan."""
    coord = make_coordinator(3, placement="replica")
    coord.server("s1").crash()
    assert ordered_signature(coord, num_streams=3) == reference_signature()


def test_failover_needs_a_replica_home():
    """Shard placements cannot fail over — disjoint rows have no second
    home — and a replica scan with NO survivor raises MigrationError."""
    coord = make_coordinator(2)
    plan = coord.plan(SQL, "/d")
    with pytest.raises(MigrationError):
        coord.failover_target(plan.endpoints[0])
    coord = make_coordinator(2, placement="replica")
    plan = coord.plan(SQL, "/d", num_streams=2)
    for sid in ("s0", "s1"):
        coord.server(sid).crash()
    with pytest.raises(MigrationError):
        coord.failover_target(plan.endpoints[0])


def test_failover_target_prefers_healthy_replicas():
    recorder = FlightRecorder()
    health = HealthMonitor(recorder=recorder)
    coord = make_coordinator(3, placement="replica")
    coord.recorder, coord.health = recorder, health
    plan = coord.plan(SQL, "/d", num_streams=3)
    coord.server("s0").crash()
    # s1 collects a fault storm -> worst-ranked among the candidates
    for _ in range(3):
        coord.notify("stream.fault", server_id="s1", now_s=1.0)
    coord.heartbeat(1.0)
    assert coord.failover_target(plan.endpoints[0]) == "s2"


# ----------------------------------------------- health-driven evict/re-admit


def make_monitored_cluster():
    recorder = FlightRecorder()
    health = HealthMonitor(recorder=recorder)
    coord = make_coordinator(3, placement="replica")
    coord.recorder, coord.health = recorder, health
    return coord, health, recorder


def test_membership_evicts_quarantined_and_readmits_recovered():
    coord, health, recorder = make_monitored_cluster()
    controller = MembershipController(coord, health)
    coord.server("s0").crash()
    for _ in range(3):                               # the fault storm
        coord.notify("stream.fault", server_id="s0", now_s=1.0)
    coord.heartbeat(1.0)
    fired = controller.heartbeat(1.0)
    assert [e.action for e in fired] == ["evict"]
    assert controller.evicted == ("s0",)
    assert "s0" not in coord.servers
    assert "s0" not in coord._placements["/d"].server_ids
    assert any(e.kind == "membership.evict" for e in recorder.events())

    # still crashed: hysteretic recovery alone must NOT re-admit
    now = 2.0
    for _ in range(16):
        if health.state("s0") == "degraded":
            break
        coord.heartbeat(now)
        assert not controller.heartbeat(now)
        now += 1.0
    assert health.state("s0") == "degraded", "recovery never stepped down"
    controller._evicted["s0"].restore()
    fired = controller.heartbeat(now)
    assert [e.action for e in fired] == ["readmit"]
    assert controller.evicted == ()
    assert "s0" in coord.servers
    assert "s0" in coord._placements["/d"].server_ids
    # the re-admitted replica serves again, byte-identical
    assert sorted(scan_signature(coord, num_streams=3)) == \
        sorted(reference_signature())


def test_readmitted_server_gets_replica_copy_registered():
    """Re-admission repairs the placement: the joiner's engine holds the
    dataset again even though eviction preceded any explicit register."""
    coord, health, _ = make_monitored_cluster()
    controller = MembershipController(coord, health)
    server = coord.server("s1")
    server.crash()
    for _ in range(3):
        coord.notify("stream.fault", server_id="s1", now_s=1.0)
    coord.heartbeat(1.0)
    controller.heartbeat(1.0)
    server.engine = Engine()                         # simulate a cold restart
    server.restore()
    now = 2.0
    for _ in range(16):
        if "s1" in coord.servers:
            break
        coord.heartbeat(now)
        controller.heartbeat(now)
        now += 1.0
    assert "s1" in coord.servers, "recovered server never re-admitted"
    assert "/d" in server.engine.catalog


# ----------------------------------------------------- nemesis determinism


def _nemesis_run(seed: int):
    """One fully-seeded chaos loop; returns its observable fingerprint."""
    recorder = FlightRecorder(capacity=1024)
    health = HealthMonitor(recorder=recorder)
    table = make_numeric_table("t", ROWS, 4, batch_rows=4096)
    coord = ClusterCoordinator(recorder=recorder, health=health)
    for i in range(4):
        coord.add_server(f"s{i}",
                         ThallusServer(Engine(), Fabric(FabricConfig())))
    coord.place_replicas("/d", table)
    schedule = seeded_schedule(seed, list(coord.servers), beats=10)
    nemesis = Nemesis(coord, schedule)
    controller = MembershipController(coord, health)
    delivered = []
    for beat in range(10):
        now = float(beat)
        nemesis.beat(beat, now)
        delivered.extend(scan_signature(coord, num_streams=2))
        coord.heartbeat(now)
        controller.heartbeat(now)
    return (tuple(nemesis.timeline), delivered, recorder.counts(),
            tuple(e.action for e in controller.events))


def test_nemesis_replays_identically():
    """Same (seed, FabricConfig, schedule) → identical fault timeline,
    delivered bytes, flight-recorder event counts and membership log."""
    assert _nemesis_run(3) == _nemesis_run(3)


def test_nemesis_delivery_survives_the_schedule():
    """Whatever the seeded schedule does, every beat's scan still delivers
    the full dataset byte-identically (exactly-once under chaos)."""
    timeline, delivered, counts, _ = _nemesis_run(3)
    assert timeline                                  # the schedule acted
    ref = sorted(reference_signature(sql=SQL))
    per_scan = len(ref)
    assert len(delivered) == 10 * per_scan
    for i in range(10):
        assert sorted(delivered[i * per_scan:(i + 1) * per_scan]) == ref
    assert counts.get("nemesis.inject", 0) >= 1


def test_seeded_schedule_is_pure():
    a = seeded_schedule(7, ["s0", "s1", "s2"], beats=12)
    assert a == seeded_schedule(7, ["s0", "s1", "s2"], beats=12)
    assert a != seeded_schedule(8, ["s0", "s1", "s2"], beats=12)
    for spec in a:
        assert 1 <= spec.start_beat < spec.stop_beat <= 12


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor", "s0", 1)
    with pytest.raises(ValueError, match="stop_beat"):
        FaultSpec("kill", "s0", 5, stop_beat=5)


def test_nemesis_conformance_without_faults():
    """An empty schedule + an attached membership controller must replay
    the plain cluster beat-for-beat: no events, no evictions, identical
    delivered bytes (the PR 8 baselines stay untouched)."""
    recorder = FlightRecorder()
    health = HealthMonitor(recorder=recorder)
    coord = make_coordinator(3, placement="replica")
    coord.recorder, coord.health = recorder, health
    nemesis = Nemesis(coord, ())
    controller = MembershipController(coord, health)
    plain = scan_signature(coord, num_streams=3)
    for beat in range(3):
        nemesis.beat(beat, float(beat))
        assert scan_signature(coord, num_streams=3) == plain
        coord.heartbeat(float(beat))
        controller.heartbeat(float(beat))
    assert nemesis.timeline == []
    assert controller.events == []
    assert recorder.counts().get("membership.evict", 0) == 0

# -------------------------------------------- nemesis fault-collision fixes


def test_overlapping_slow_faults_compound_and_heal_stepwise():
    """Two slow windows overlapping on one server COMPOUND (the bandwidth
    divisor is the product of the active factors) and heal stepwise: each
    window's close removes only its own factor, and the base config comes
    back untouched when the last one lifts."""
    coord = make_coordinator(3, placement="replica")
    base = coord.server("s1").fabric.config
    nem = Nemesis(coord, (
        FaultSpec("slow", "s1", 1, stop_beat=4, factor=2.0),
        FaultSpec("slow", "s1", 2, stop_beat=6, factor=4.0)))
    nem.beat(1, 1.0)
    cfg = coord.server("s1").fabric.config
    assert cfg.rdma_bw == pytest.approx(base.rdma_bw / 2.0)
    nem.beat(2, 2.0)                             # windows overlap: 2x * 4x
    cfg = coord.server("s1").fabric.config
    assert cfg.rdma_bw == pytest.approx(base.rdma_bw / 8.0)
    assert cfg.rpc_bw == pytest.approx(base.rpc_bw / 8.0)
    assert len(nem.active) == 2
    nem.beat(3, 3.0)                             # nothing scheduled
    nem.beat(4, 4.0)                             # first window heals
    cfg = coord.server("s1").fabric.config
    assert cfg.rdma_bw == pytest.approx(base.rdma_bw / 4.0)
    nem.beat(5, 5.0)
    nem.beat(6, 6.0)                             # last window heals
    cfg = coord.server("s1").fabric.config
    assert cfg.rdma_bw == base.rdma_bw and cfg.rpc_bw == base.rpc_bw
    assert nem.active == {}
    assert nem._saved_fabric == {}               # base config handed back
    assert sorted(scan_signature(coord, num_streams=3)) == \
        sorted(reference_signature())


def test_nemesis_targets_post_construction_joiner():
    """A server that joins AFTER the nemesis is built is fair game: targets
    resolve through the coordinator's live view, not just the snapshot."""
    coord = make_coordinator(2, placement="replica")
    nem = Nemesis(coord, (FaultSpec("kill", "s2", 1, stop_beat=2),))
    coord.add_server("s2", ThallusServer(Engine(), Fabric(FabricConfig())),
                     rebalance=True)
    nem.beat(1, 1.0)                             # no KeyError: live lookup
    assert coord.server("s2").crashed
    nem.beat(2, 2.0)
    assert not coord.server("s2").crashed
    assert nem.timeline == [(1, "inject", "kill", "s2"),
                            (2, "heal", "kill", "s2")]


def test_partition_without_shard_records_no_phantom_fault():
    """A partition aimed where no admission shard exists injects nothing —
    and therefore records nothing: no active entry, no timeline event, and
    the heal beat of the never-injected fault is a guarded no-op."""
    coord = make_coordinator(2, placement="replica")     # no admission at all
    nem = Nemesis(coord, (FaultSpec("partition", "s0", 1, stop_beat=3),))
    nem.beat(1, 1.0)
    assert nem.active == {}
    assert nem.timeline == []
    nem.beat(3, 3.0)                             # heal side guarded too
    assert nem.timeline == []


def test_partition_heal_survives_absorbed_shard():
    """A partitioned shard absorbed by an eviction mid-fault must not blow
    up the heal beat: the rejoin is skipped (the shard is gone) but the
    heal itself is still recorded against the real injection."""
    admission = ShardedAdmission(AdmissionConfig(max_streams_total=8),
                                 ["s0", "s1"])
    coord = make_coordinator(2, placement="replica", admission=admission)
    spec = FaultSpec("partition", "s0", 1, stop_beat=3)
    nem = Nemesis(coord, (spec,), admission=admission)
    nem.beat(1, 1.0)
    assert admission.partitioned("s0")
    assert nem.active == {spec: 1}
    admission.remove_shard("s0", now_s=2.0)      # evict absorbs the shard
    nem.beat(3, 3.0)                             # heal: no KeyError
    assert nem.active == {}
    assert (3, "heal", "partition", "s0") in nem.timeline


def test_seeded_schedule_windows_fit_the_run():
    """Every drawn window heals inside the run (stop_beat <= beats) even
    for small beat counts, and a run too short to fit min_duration raises
    instead of silently emitting unhealable faults."""
    for seed in range(16):
        for beats in (3, 4, 5):
            for spec in seeded_schedule(seed, ["s0", "s1", "s2"],
                                        beats=beats):
                assert 1 <= spec.start_beat < spec.stop_beat <= beats
    with pytest.raises(ValueError, match="cannot fit"):
        seeded_schedule(0, ["s0"], beats=2)      # min_duration=2 needs >= 3
