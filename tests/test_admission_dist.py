"""The repro.qos.distributed layer: shard-count=1 conformance against the
centralized AdmissionController (same grants, denials, retry hints and
throttle waits on a recorded op trace), N-shard global-budget safety,
the borrow protocol, modeled-time reconciliation (capacity return + token
conservation), partition/rejoin chaos, per-shard routing through the
coordinator and stream pullers, and the gateway's freed-slot re-planning
hook."""
import numpy as np
import pytest
from conftest import make_coordinator, reference_batches, token_servers

from repro.cluster import cluster_scan
from repro.data import ThallusLoader
from repro.qos import (AdmissionConfig, AdmissionController, Backpressure,
                       DistributedConfig, DistributedStats, ScanGateway,
                       ScanRequest, ShardedAdmission)

SQL = "SELECT c0, c1 FROM t"


class _PoolStub:
    """Just enough BufferPool surface for the memory-budget check."""

    class _Stats:
        bytes_resident = 0

    def __init__(self, max_bytes):
        self.max_bytes = max_bytes
        self.stats = self._Stats()


def replay(adm, ops, pool=None):
    """Drive a recorded op sequence; return the observable outcome log
    (grants, denials with retry hints, token-bucket waits)."""
    log = []
    for op in ops:
        if op[0] == "acquire":
            _, client, server = op
            try:
                adm.acquire_stream(client, server_id=server)
                log.append(("grant", client))
            except Backpressure as e:
                log.append(("deny", client, e.reason.split(" (")[0],
                            e.retry_after_s))
        elif op[0] == "release":
            _, client, server, now_s = op
            adm.release_stream(client, server_id=server, now_s=now_s)
        elif op[0] == "lease":
            _, now_s, n, server = op
            log.append(("wait",
                        round(adm.lease_wait_s(now_s, n, server_id=server),
                              12)))
        elif op[0] == "memory":
            pool.stats.bytes_resident = op[1]
    return log


#: The recorded trace: exercises per-client quota denial, the global cap,
#: the memory budget, bucket exhaustion, backwards-jumping stream clocks,
#: and a release that frees a slot for a later grant — at modeled times.
TRACE = [
    ("acquire", "a", "s0"), ("acquire", "a", "s0"),
    ("acquire", "a", "s0"),                    # -> quota deny (2)
    ("acquire", "b", "s0"),
    ("acquire", "c", "s0"),                    # -> global-cap deny (3)
    ("lease", 0.0, 2, "s0"), ("lease", 0.0, 1, "s0"),   # bucket runs dry
    ("lease", 1e-3, 1, "s0"),                  # partial refill
    ("lease", 0.5, 2, "s0"),                   # backwards/forward motion
    ("release", "a", "s0", 0.5),
    ("acquire", "c", "s0"),                    # freed slot -> grant
    ("release", "c", "s0", 0.55),              # headroom for the mem check
    ("memory", 1 << 20),
    ("acquire", "b", "s0"),                    # -> memory deny
    ("memory", 0),
    ("acquire", "b", "s0"),                    # budget recovered -> grant
    ("lease", 0.6, 4, None),                   # unrouted (gateway shape)
]


def _stats_fields(stats):
    return (stats.stream_grants, stats.stream_denials, stats.total_denials,
            stats.memory_denials, stats.lease_grants,
            pytest.approx(stats.throttle_wait_s), stats.peak_active)


# ------------------------------------------------------------- conformance


def test_one_shard_conformance_replays_identically():
    """The drop-in guarantee: a one-shard ShardedAdmission is grant-for-
    grant, denial-for-denial, wait-for-wait identical to the centralized
    controller on the recorded trace — including the stats it accumulates."""
    cfg = AdmissionConfig(max_streams_per_client=2, max_streams_total=3,
                          lease_rate_per_s=100.0, lease_burst=2,
                          retry_after_hint_s=0.125)
    pool_c, pool_s = _PoolStub(1 << 16), _PoolStub(1 << 16)
    central = AdmissionController(cfg, pool=pool_c)
    sharded = ShardedAdmission(cfg, ["s0"], pool=pool_s)
    log_central = replay(central, TRACE, pool_c)
    log_sharded = replay(sharded, TRACE, pool_s)
    assert log_sharded == log_central
    # every denial carried the configured retry hint
    assert all(e[3] == 0.125 for e in log_central if e[0] == "deny")
    assert _stats_fields(sharded.stats) == _stats_fields(central.stats)
    # the aggregate stays AdmissionStats-shaped (gateway compatibility)
    assert isinstance(sharded.stats, DistributedStats)
    assert sharded.active_streams("a") == central.active_streams("a")
    assert sharded.active_total() == central.active_total()


def test_one_shard_conformance_survives_periodic_reconciles():
    """Reconciling a one-shard deployment is a no-op for every observable:
    the periodic reconciler must not perturb drop-in equivalence."""
    cfg = AdmissionConfig(max_streams_per_client=1, lease_rate_per_s=50.0,
                          lease_burst=4)
    central = AdmissionController(cfg)
    sharded = ShardedAdmission(
        cfg, ["s0"], dist=DistributedConfig(reconcile_interval_s=1e-4))
    ops = [("lease", i * 1e-3, 1, "s0") for i in range(20)]
    ops += [("acquire", "a", "s0"), ("acquire", "a", "s0")]
    assert replay(sharded, ops) == replay(central, ops)
    assert sharded.stats.reconciles > 0      # the reconciler did fire


def test_nshard_storm_never_exceeds_global_budget():
    """A seeded acquire/release storm across 3 shards and 4 clients, with
    borrowing on: after every op, no client exceeds the global per-client
    quota and the cluster never exceeds the global cap."""
    quota, cap = 4, 9
    cfg = AdmissionConfig(max_streams_per_client=quota, max_streams_total=cap)
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2"])
    rng = np.random.default_rng(7)
    held = []                                  # (client, server) grants
    denials = 0
    for _ in range(400):
        client = f"c{rng.integers(4)}"
        server = f"s{rng.integers(3)}"
        if held and rng.random() < 0.4:
            c, s = held.pop(rng.integers(len(held)))
            sharded.release_stream(c, server_id=s)
        else:
            try:
                sharded.acquire_stream(client, server_id=server)
                held.append((client, server))
            except Backpressure as e:
                denials += 1
                assert e.retry_after_s > 0
        for c in {c for c, _ in held}:
            assert sharded.active_streams(c) <= quota
        assert sharded.active_total() <= cap
    assert denials > 0                         # the storm did hit limits
    assert sharded.stats.borrows > 0           # and borrowing did fire
    assert max(sharded.peak_streams(f"c{i}") for i in range(4)) <= quota
    assert sharded.peak_total <= cap


# ------------------------------------------------------------- borrowing


def test_borrow_takes_from_least_loaded_peer_and_is_bounded():
    cfg = AdmissionConfig(max_streams_per_client=8)      # 2 per shard
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2", "s3"],
                               dist=DistributedConfig(borrow_limit=2))
    sharded.acquire_stream("c", server_id="s1")          # load s1
    for _ in range(2):
        sharded.acquire_stream("c", server_id="s0")      # fill s0's base
    sharded.acquire_stream("c", server_id="s0")          # borrow #1
    # least-loaded peers are s2/s3 (slack 2); s1 (slack 1) must be spared
    assert sharded.shards["s1"].stats.lends == 0
    assert sharded.shards["s0"].stats.borrows == 1
    sharded.acquire_stream("c", server_id="s0")          # borrow #2 (limit)
    with pytest.raises(Backpressure):                    # bounded slack
        sharded.acquire_stream("c", server_id="s0")
    assert sharded.stats.borrows == 2
    # the global budget was never exceeded along the way
    assert sharded.active_streams("c") == 5 <= 8
    assert sharded.peak_streams("c") == 5


def test_denied_acquire_rolls_back_partial_borrow():
    """Regression: a borrow that clears the quota reason while the total
    cap still denies must be reversed — otherwise capacity strands at a
    shard that never used it until the next reconcile."""
    cfg = AdmissionConfig(max_streams_per_client=4, max_streams_total=8)
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2", "s3"])
    sharded.acquire_stream("x", server_id="s0")
    sharded.acquire_stream("y", server_id="s0")          # s0 total slice full
    for sid in ("s1", "s2", "s3"):                       # exhaust the cap
        sharded.acquire_stream("y", server_id=sid)
        sharded.acquire_stream("z", server_id=sid)
    assert sharded.active_total() == 8
    # x@s0 is quota-blocked (borrowable: peers have x-slack) AND
    # total-blocked (not borrowable: the cluster cap is exhausted)
    with pytest.raises(Backpressure):
        sharded.acquire_stream("x", server_id="s0")
    assert sharded.stats.borrows == 0                    # rolled back
    for sid in ("s1", "s2", "s3"):                       # nothing stranded
        assert sharded.shards[sid].client_slack("x") == 1
    sharded.release_stream("z", server_id="s1")
    sharded.acquire_stream("x", server_id="s1")          # local, no borrow
    assert sharded.stats.borrows == 0


def test_release_of_unheld_stream_fires_no_phantom_event():
    """Regression: releasing a stream nobody holds (double release, wrong
    client) must not decrement anything or emit a freed-slot event — a
    subscribed gateway would widen a fan-out onto a lane that never freed."""
    sharded = ShardedAdmission(AdmissionConfig(max_streams_per_client=4),
                               ["s0", "s1"])
    events = []
    sharded.subscribe_release(lambda *a: events.append(a))
    sharded.release_stream("ghost", server_id="s0", now_s=1.0)
    assert events == []
    sharded.acquire_stream("c", server_id="s0")
    sharded.release_stream("c", server_id="s0", now_s=2.0)
    sharded.release_stream("c", server_id="s0", now_s=3.0)   # double release
    assert events == [("s0", "c", 2.0)]
    assert sharded.active_total() == 0


def test_borrow_cannot_manufacture_capacity():
    """When every peer is saturated there is no slack to borrow — the
    cluster-wide quota binds exactly as the centralized one would."""
    cfg = AdmissionConfig(max_streams_per_client=4)      # 1 per shard
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2", "s3"])
    for sid in ("s0", "s1", "s2", "s3"):
        sharded.acquire_stream("c", server_id=sid)
    with pytest.raises(Backpressure) as exc:
        sharded.acquire_stream("c", server_id="s0")
    assert exc.value.retry_after_s > 0
    assert sharded.active_streams("c") == 4


# --------------------------------------------------------- reconciliation


def test_reconcile_returns_borrowed_capacity_to_lenders():
    cfg = AdmissionConfig(max_streams_per_client=8)      # 2 per shard
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2", "s3"])
    for _ in range(5):                                   # 2 base + 3 borrowed
        sharded.acquire_stream("c", server_id="s0")
    assert sharded.shards["s0"].client_slack("c") == 0
    # in-use borrowed capacity is pinned: reconcile must not strand streams
    report = sharded.reconcile(0.1)
    assert report.capacity_returned == 0
    for _ in range(5):
        sharded.release_stream("c", server_id="s0")
    report = sharded.reconcile(0.2)
    assert report.capacity_returned == 3                 # all debt settled
    for sid in ("s0", "s1", "s2", "s3"):                 # balanced again
        assert sharded.shards[sid].client_slack("c") == 2


def test_reconcile_rebalances_tokens_and_conserves_total(modeled_clock):
    cfg = AdmissionConfig(lease_rate_per_s=100.0, lease_burst=8)
    sharded = ShardedAdmission(
        cfg, ["s0", "s1"],
        dist=DistributedConfig(reconcile_interval_s=1e9))  # manual only
    assert sharded.lease_wait_s(modeled_clock.now_s, 4,
                                server_id="s0") == 0.0   # drain s0 (burst 4)
    assert sharded.shards["s0"].tokens_at(modeled_clock.now_s) == 0.0
    report = sharded.reconcile(modeled_clock.now_s)
    assert report.tokens_before == pytest.approx(4.0)
    assert report.tokens_after == pytest.approx(report.tokens_before)
    assert report.tokens_moved == pytest.approx(2.0)     # s1 -> s0: 2 tokens
    assert sharded.shards["s0"].tokens_at(modeled_clock.now_s) == \
        pytest.approx(2.0)
    assert sharded.shards["s1"].stats.tokens_out == pytest.approx(2.0)
    # refill during a later round is time-based, not shard-pair transfer:
    # conservation is measured post-refill
    modeled_clock.advance(10e-3)                         # +0.5 tokens/shard
    report = sharded.reconcile(modeled_clock.now_s)
    assert report.tokens_after == pytest.approx(report.tokens_before)
    assert sharded.stats.tokens_rebalanced > 0


def test_periodic_reconciler_piggybacks_on_lease_clock():
    cfg = AdmissionConfig(lease_rate_per_s=100.0, lease_burst=8)
    sharded = ShardedAdmission(
        cfg, ["s0", "s1"],
        dist=DistributedConfig(reconcile_interval_s=50e-3))
    sharded.lease_wait_s(10e-3, 1, server_id="s0")
    assert sharded.stats.reconciles == 0                 # interval not hit
    sharded.lease_wait_s(60e-3, 1, server_id="s0")
    assert sharded.stats.reconciles == 1                 # fired at 60ms
    sharded.lease_wait_s(70e-3, 1, server_id="s0")
    assert sharded.stats.reconciles == 1                 # re-armed at 60ms


# -------------------------------------------------------- partition chaos


def test_partitioned_shard_degrades_to_local_reserve():
    """A shard whose reconciler stopped firing can neither borrow nor lend:
    it admits up to its own capacity (no over-admission possible), while the
    healthy shards keep borrowing among themselves."""
    cfg = AdmissionConfig(max_streams_per_client=8)      # 2 per shard
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2", "s3"])
    sharded.partition("s0")
    sharded.acquire_stream("c", server_id="s0")
    sharded.acquire_stream("c", server_id="s0")
    with pytest.raises(Backpressure):                    # local reserve only
        sharded.acquire_stream("c", server_id="s0")
    assert sharded.stats.borrows == 0
    # healthy shards borrow from each other but never from the partitioned
    for _ in range(6):                                   # 2 base + 4 borrowed
        sharded.acquire_stream("c", server_id="s1")
    assert sharded.shards["s0"].stats.lends == 0
    with pytest.raises(Backpressure):                    # global quota bound
        sharded.acquire_stream("c", server_id="s1")
    assert sharded.active_streams("c") == 8              # == global quota
    assert sharded.peak_streams("c") == 8


def test_rejoin_converges_within_two_reconcile_rounds():
    cfg = AdmissionConfig(max_streams_per_client=8,
                          lease_rate_per_s=100.0, lease_burst=8)
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2", "s3"])
    sharded.partition("s3")
    for _ in range(6):                                   # borrows from s1/s2
        sharded.acquire_stream("c", server_id="s0")
    sharded.lease_wait_s(0.0, 2, server_id="s3")         # drain s3's bucket
    report = sharded.reconcile(0.1)                      # s3 excluded
    assert "s3" not in report.participants
    # the partitioned bucket refills on its own local rate, but no peer
    # shifted tokens into or out of it
    assert sharded.shards["s3"].stats.tokens_in == 0.0
    assert sharded.shards["s3"].stats.tokens_out == 0.0
    for _ in range(6):
        sharded.release_stream("c", server_id="s0")
    sharded.rejoin("s3")
    reports = [sharded.reconcile(0.2), sharded.reconcile(0.3)]
    assert all("s3" in r.participants for r in reports)
    for sid in ("s0", "s1", "s2", "s3"):                 # balanced capacity
        assert sharded.shards[sid].client_slack("c") == 2
    # and the rejoined bucket was leveled back up by its peers
    assert sharded.shards["s3"].tokens_at(0.3) > 0.0
    total = sum(s.tokens_at(0.3) for s in sharded.shards.values())
    assert total <= cfg.lease_burst + 1e-9               # nothing created


# ------------------------------------------------ dataplane + loader wiring


def test_coordinator_routes_admission_to_endpoint_shard():
    sharded = ShardedAdmission(AdmissionConfig(max_streams_per_client=8),
                               ["s0", "s1"])
    coord = make_coordinator(2, "shard", admission=sharded)
    stats = cluster_scan(coord, SQL, "/d", client_id="c")
    assert stats.batches == 10
    for sid in ("s0", "s1"):                 # one grant on each shard
        assert sharded.shards[sid].stats.stream_grants == 1
    assert sharded.active_total() == 0       # all leases released
    assert sharded.peak_total == 2


def test_puller_charges_endpoint_shard_bucket():
    sharded = ShardedAdmission(
        AdmissionConfig(lease_rate_per_s=10.0, lease_burst=2), ["s0", "s1"])
    coord = make_coordinator(2, "shard", admission=sharded)
    stats = cluster_scan(coord, SQL, "/d", client_id="c")
    assert stats.throttle_wait_s > 0         # buckets ran dry mid-scan
    for sid in ("s0", "s1"):                 # each stream hit ITS OWN bucket
        assert sharded.shards[sid].stats.throttle_wait_s > 0
    assert sharded.stats.throttle_wait_s == pytest.approx(
        stats.throttle_wait_s)


def test_loader_surfaces_backpressure_from_sharded_admission():
    sharded = ShardedAdmission(
        AdmissionConfig(max_streams_per_client=2, retry_after_hint_s=0.25),
        ["s0", "s1", "s2", "s3"])
    loader = ThallusLoader(token_servers(4), "SELECT tokens FROM tok", "/d",
                           seq_len=32, batch_seqs=8, transport="cluster",
                           admission=sharded, client_id="trainer")
    with pytest.raises(Backpressure) as exc:
        list(loader)                         # 4 replica streams > quota 2
    assert exc.value.retry_after_s == 0.25
    assert loader.stats.backpressures == 1
    assert sharded.active_total() == 0       # partial fan-out fully closed
    retry = ThallusLoader(token_servers(4), "SELECT tokens FROM tok", "/d",
                          seq_len=32, batch_seqs=8, transport="cluster",
                          admission=sharded, client_id="trainer",
                          num_streams=2)
    assert len(list(retry)) == 12            # narrowed under the quota
    assert sharded.active_total() == 0


# ---------------------------------------------------- gateway re-planning


def test_gateway_with_sharded_admission_end_to_end():
    sharded = ShardedAdmission(
        AdmissionConfig(max_streams_per_client=2, lease_rate_per_s=1e3,
                        lease_burst=4), ["s0", "s1", "s2", "s3"])
    gateway = ScanGateway(make_coordinator(4, "shard"), admission=sharded)
    req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    got = gateway.result(req.request_id).batches
    ref = reference_batches(SQL)
    assert len(got) == len(ref)
    for g, r in zip(got, ref):               # exact global scan order
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)
    # the per-shard snapshot landed on QosStats and renders
    assert gateway.stats.admission is not None
    assert len(gateway.stats.admission.shards) == 4
    assert "shards=4" in gateway.stats.summary()
    from repro.utils.report import admission_table
    table = admission_table(gateway.stats.admission)
    assert "s0" in table and "*cluster*" in table
    # centralized stats render through the same table (one *global* row)
    assert "*global*" in admission_table(AdmissionController().stats)


def test_replan_on_release_widens_capped_fanout():
    """ROADMAP "gateway re-planning on freed slots": an interactive fan-out
    capped by another client's held streams re-packs its remaining work the
    modeled instant that client's streams close — same bytes, smaller
    modeled makespan."""
    service = {}
    for replan in (False, True):
        sharded = ShardedAdmission(
            AdmissionConfig(max_streams_per_client=4, max_streams_total=4),
            ["s0", "s1", "s2", "s3"])
        # slowed fabric: modeled wire dominates measured alloc noise, so
        # the 2-lane vs 4-lane makespan ratio is deterministic
        gateway = ScanGateway(make_coordinator(4, "shard",
                                               slowdown_all=2000),
                              admission=sharded)
        # a batch loader outside the gateway holds half the global cap
        sharded.acquire_stream("batch-loader", server_id="s0")
        sharded.acquire_stream("batch-loader", server_id="s1")
        req = gateway.submit(ScanRequest("ui", "interactive", SQL, "/d"))
        if replan:
            # ...and closes its streams mid-scan on the modeled clock; the
            # sharded controller's freed-slot events reach the gateway's
            # replan_on_release hook (auto-subscribed)
            for sid in ("s0", "s1"):
                sharded.release_stream("batch-loader", server_id=sid,
                                       now_s=1e-7)
        gateway.run()
        result = gateway.result(req.request_id)
        ref = reference_batches(SQL)
        assert len(result.batches) == len(ref)
        service[replan] = result.service_s
    # freed slots widened 2 lanes back to 4: the makespan shrank
    assert service[True] < 0.7 * service[False]
    assert gateway.stats.replans == 2


def test_replan_event_beyond_window_not_consumed_by_earlier_request():
    """Regression: a release stamped past a fan-out's service window must
    not be consumed (or counted) by it — the event stays queued for a later
    request whose window actually covers that instant, and the earlier
    request's modeled service is unchanged (the freed slot is held back
    from its lane count, matching the still-held occupancy)."""
    service = {}
    for with_event in (False, True):
        sharded = ShardedAdmission(
            AdmissionConfig(max_streams_per_client=4, max_streams_total=4),
            ["s0", "s1", "s2", "s3"])
        gateway = ScanGateway(make_coordinator(4, "shard",
                                               slowdown_all=2000),
                              admission=sharded)
        sharded.acquire_stream("bg", server_id="s0")
        sharded.acquire_stream("bg", server_id="s1")
        if with_event:
            # released on the wall clock, but stamped far beyond any
            # window on the modeled clock: still held as far as this
            # request's service model is concerned
            sharded.release_stream("bg", server_id="s0", now_s=10.0)
        req = gateway.submit(ScanRequest("ui", "interactive", SQL, "/d"))
        gateway.run()
        service[with_event] = gateway.result(req.request_id).service_s
    assert service[True] == pytest.approx(service[False], rel=0.1)
    assert gateway.stats.replans == 0
    assert gateway._replan_events == [(10.0, 1)]     # pending, not dropped


def test_replan_events_before_grant_are_not_double_counted():
    """A slot freed *before* the request was granted is already visible in
    the controller's occupancy — the event must be pruned, not replayed as
    an extra mid-service lane."""
    sharded = ShardedAdmission(
        AdmissionConfig(max_streams_per_client=4, max_streams_total=4),
        ["s0", "s1", "s2", "s3"])
    gateway = ScanGateway(make_coordinator(4, "shard"), admission=sharded)
    sharded.acquire_stream("other", server_id="s0")
    sharded.release_stream("other", server_id="s0", now_s=0.0)  # t <= grant
    req = gateway.submit(ScanRequest("ui", "interactive", SQL, "/d"))
    gateway.run()
    assert gateway.stats.replans == 0
    assert gateway.result(req.request_id) is not None


# ------------------------------------------- elastic shard add/remove


def test_remove_shard_absorbs_capacity_and_tombstones_releases():
    """Evicting a quota shard re-splits the global cap across survivors and
    leaves a tombstone: a late release from an in-flight lease that was
    admitted on the dead shard settles against the tombstone instead of
    mis-crediting a survivor (the over-admission hazard)."""
    cfg = AdmissionConfig(max_streams_total=6)
    sharded = ShardedAdmission(cfg, ["s0", "s1", "s2"])
    sharded.acquire_stream("c", server_id="s2")      # in-flight on s2
    sharded.remove_shard("s2", now_s=1.0)
    assert sorted(sharded.shards) == ["s0", "s1"]
    assert sum(s.config.max_streams_total
               for s in sharded.shards.values()) == 6
    before = {sid: s.active_total() for sid, s in sharded.shards.items()}
    sharded.release_stream("c", server_id="s2")      # settles on the tombstone
    assert {sid: s.active_total()
            for sid, s in sharded.shards.items()} == before
    # the freed global headroom is real: survivors admit the full cap
    for i in range(6):
        sharded.acquire_stream(f"c{i}", server_id=["s0", "s1"][i % 2])
    with pytest.raises(Backpressure):
        sharded.acquire_stream("late", server_id="s0")


def test_remove_last_shard_refused():
    sharded = ShardedAdmission(AdmissionConfig(max_streams_total=4),
                               ["s0", "s1"])
    sharded.remove_shard("s0")
    with pytest.raises(ValueError, match="last"):
        sharded.remove_shard("s1")
    with pytest.raises(KeyError):
        sharded.remove_shard("s9")


def test_add_shard_resplits_and_conserves_tokens(modeled_clock):
    """A joiner gets a fresh quota shard carved out of the SAME global
    budget (caps re-split, not inflated) and the token pool is conserved
    through the leave/join cycle — the joiner's bucket clock starts at the
    join, so it cannot over-credit a backlog of phantom refill time."""
    cfg = AdmissionConfig(max_streams_total=6, lease_rate_per_s=100.0,
                          lease_burst=8)
    sharded = ShardedAdmission(
        cfg, ["s0", "s1"],
        dist=DistributedConfig(reconcile_interval_s=1e9))
    now = modeled_clock.now_s
    total_before = sum(s.tokens_at(now) for s in sharded.shards.values())
    sharded.remove_shard("s1", now_s=now)
    assert sum(s.tokens_at(now)
               for s in sharded.shards.values()) == pytest.approx(
                   min(total_before, 8.0))          # capped at s0's burst
    modeled_clock.advance(1.0)
    now = modeled_clock.now_s
    sharded.add_shard("s1", now_s=now)
    assert sorted(sharded.shards) == ["s0", "s1"]
    assert sum(s.config.max_streams_total
               for s in sharded.shards.values()) == 6
    total = sum(s.tokens_at(now) for s in sharded.shards.values())
    assert total <= 8.0 + 1e-9                      # never above the budget
    # phantom-refill guard: a joiner polled much later refills only from
    # its join time, never from t=0
    modeled_clock.advance(1e-3)
    s1 = sharded.shards["s1"]
    assert s1.tokens_at(modeled_clock.now_s) <= \
        float(s1.config.lease_burst) + 1e-9


def test_readd_existing_shard_refused():
    sharded = ShardedAdmission(AdmissionConfig(max_streams_total=4),
                               ["s0", "s1"])
    with pytest.raises(ValueError, match="already"):
        sharded.add_shard("s1")
