"""Transport layer: serialization, bulk handles, RPC-vs-Thallus parity, and
the zero-copy properties the paper's numbers rest on."""
import numpy as np
import pytest

from repro.core import (Fabric, FabricConfig, RpcTransport, ThallusTransport,
                        allocate_like, assemble_batch, batch_from_pydict,
                        expose_batch, pack, schema, serialized_size,
                        size_vectors, unpack)


@pytest.fixture
def batch(rng):
    sch = schema(("a", "int64"), ("b", "float64"), ("s", "utf8"))
    n = 500
    return batch_from_pydict(sch, {
        "a": [int(v) for v in rng.integers(0, 1000, n)],
        "b": [float(v) if i % 11 else None
              for i, v in enumerate(rng.standard_normal(n))],
        "s": [("x" * (i % 13)) if i % 7 else None for i in range(n)],
    })


def test_serialize_roundtrip(batch):
    wire = pack(batch)
    assert wire.nbytes == serialized_size(batch)
    out = unpack(wire)
    assert out.to_pydict() == batch.to_pydict()


def test_deserialize_is_zero_copy(batch):
    """Arrow semantics: unpacked columns are views into the wire buffer."""
    wire = pack(batch)
    out = unpack(wire, zero_copy=True)
    for col in out.columns:
        assert col.values.base is not None


def test_expose_is_zero_copy(batch):
    handle = expose_batch(batch)
    assert handle.num_segments == 3 * batch.num_columns
    # paper layout: 3i/3i+1/3i+2 = values/offsets/validity of column i
    for ci, col in enumerate(batch.columns):
        assert handle.segments[3 * ci] is col.values
        if col.offsets is not None:
            assert handle.segments[3 * ci + 1] is col.offsets
        if col.validity is not None:
            assert handle.segments[3 * ci + 2] is col.validity
    remote = handle.remote_view()
    assert remote.segments is None and remote.descs == handle.descs


def test_size_vectors_match_descs(batch):
    data, offs, nulls = size_vectors(batch)
    handle = expose_batch(batch)
    for ci in range(batch.num_columns):
        assert handle.descs[3 * ci].nbytes == data[ci]
        assert handle.descs[3 * ci + 1].nbytes == offs[ci]
        assert handle.descs[3 * ci + 2].nbytes == nulls[ci]


def test_allocate_like_and_assemble(batch):
    remote = expose_batch(batch)
    local = allocate_like(remote.descs)
    assert [s.nbytes for s in local.segments] == \
           [s.nbytes for s in remote.segments]
    for src, dst in zip(remote.segments, local.segments):
        if src.nbytes:
            dst.view(np.uint8).reshape(-1)[:] = src.view(np.uint8).reshape(-1)
    out = assemble_batch(batch.schema, batch.num_rows, local.segments)
    assert out.to_pydict() == batch.to_pydict()


def test_transport_parity(batch):
    fabric = Fabric()
    rpc_out, rpc_stats = RpcTransport(fabric).send_batch(batch)
    th_out, th_stats = ThallusTransport(fabric).send_batch(batch)
    assert rpc_out.to_pydict() == th_out.to_pydict() == batch.to_pydict()
    # the defining asymmetry: baseline pays serialization, Thallus does not
    assert rpc_stats.serialize_s > 0
    assert th_stats.serialize_s == 0.0
    assert th_stats.wire.num_segments == 3 * batch.num_columns


def test_thallus_faster_at_scale(rng):
    """Fig-2 direction: for large batches thallus wins; the model's constant
    per-segment costs erode the gain for tiny batches."""
    sch = schema(*[(f"c{i}", "float64") for i in range(8)])
    from repro.core import batch_from_arrays
    big = batch_from_arrays(sch, [rng.standard_normal(200_000) for _ in range(8)])
    fabric = Fabric()
    _, rpc = RpcTransport(fabric).send_batch(big)
    _, th = ThallusTransport(fabric).send_batch(big)
    assert th.total_s < rpc.total_s
    small = batch_from_arrays(sch, [rng.standard_normal(4) for _ in range(8)])
    _, rpc_s = RpcTransport(fabric).send_batch(small)
    _, th_s = ThallusTransport(fabric).send_batch(small)
    gain_big = rpc.total_s / th.total_s
    gain_small = rpc_s.total_s / th_s.total_s
    assert gain_big > gain_small  # the paper's diminishing-gain trend


def test_fabric_counters(batch):
    fabric = Fabric(FabricConfig())
    ThallusTransport(fabric).send_batch(batch)
    assert fabric.rdma_count == 1
    assert fabric.bytes_over_rdma == batch.nbytes
    assert fabric.bytes_over_rpc < 1024  # control plane only
