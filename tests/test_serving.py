"""Serving: batcher cohorts, greedy decode correctness, response batches."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode as decode_fn
from repro.models import init_params, prefill
from repro.serving import Batcher, Request, completions_to_batch


def _engine(arch="granite-3-2b"):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def prefill_fn(tokens):
        return prefill(cfg, params, {"tokens": tokens}, remat="none")

    def decode_step(cache, tokens, position):
        return decode_fn(cfg, params, cache, tokens, position)

    return cfg, params, prefill_fn, decode_step


def test_batcher_cohorts(rng):
    cfg, params, pf, dec = _engine()
    b = Batcher(pf, dec, batch_size=3)
    for i in range(7):
        plen = int(rng.integers(3, 9))
        b.submit(Request(i, rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                         max_new_tokens=4))
    done = b.run()
    assert sorted(c.request_id for c in done) == list(range(7))
    assert all(len(c.tokens) == 4 for c in done)
    assert all(0 <= t < cfg.padded_vocab for c in done for t in c.tokens)


def test_batcher_eos_stops_early(rng):
    cfg, params, pf, dec = _engine()
    # discover what the model emits first, then use it as EOS
    b0 = Batcher(pf, dec, batch_size=1)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    b0.submit(Request(0, prompt, max_new_tokens=3))
    first = b0.run()[0].tokens[0]
    b1 = Batcher(pf, dec, batch_size=1)
    b1.submit(Request(1, prompt, max_new_tokens=8, eos_id=int(first)))
    out = b1.run()[0]
    assert out.tokens[0] == first and len(out.tokens) == 1


def test_completions_to_batch():
    from repro.serving import Completion
    batch = completions_to_batch([Completion(3, [5, 6]), Completion(9, [7])])
    d = batch.to_pydict()
    assert d["request_id"] == [3, 3, 9]
    assert d["token"] == [5, 6, 7]
    assert d["position"] == [0, 1, 0]


def test_greedy_decode_matches_manual(rng):
    """Batcher output == manual prefill+argmax loop for a single request."""
    cfg, params, pf, dec = _engine()
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    # manual
    logits, cache = pf(jnp.asarray(prompt)[None])
    cache = jax.tree.map(
        lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, 3)) + ((0, 0),) * (x.ndim - 3))
        if x.ndim >= 4 and x.shape[2] == 6 else x, cache)
    toks = []
    nxt = int(jnp.argmax(logits[0, -1]))
    for step in range(3):
        toks.append(nxt)
        if step == 2:
            break
        logits, cache = dec(cache, jnp.asarray([[nxt]], jnp.int32),
                            jnp.int32(6 + step))
        nxt = int(jnp.argmax(logits[0, -1]))
    # batcher
    b = Batcher(pf, dec, batch_size=1)
    b.submit(Request(0, prompt, max_new_tokens=3))
    out = b.run()[0]
    assert out.tokens == toks
