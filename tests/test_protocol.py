"""Protocol state machine: init_scan / iterate / do_rdma / finalize,
multi-tenancy, resumability, lease reclaim."""
import numpy as np
import pytest

from repro.core import Fabric, RpcClient, ThallusClient, ThallusServer
from repro.engine import Engine, make_numeric_table


@pytest.fixture
def server():
    eng = Engine()
    eng.register("/d/t", make_numeric_table("t", 50_000, 6, batch_rows=8192))
    return ThallusServer(eng, Fabric())


def test_full_scan(server):
    client = ThallusClient(server)
    batches = client.run_query("SELECT c0, c1 FROM t", "/d/t")
    assert sum(b.num_rows for b in batches) == 50_000
    assert all(b.schema.names == ("c0", "c1") for b in batches)
    assert not server.reader_map  # finalized


def test_parity_with_rpc_client(server):
    a = ThallusClient(server).run_query("SELECT c2 FROM t WHERE c2 > 0", "/d/t")
    b = RpcClient(server).run_query("SELECT c2 FROM t WHERE c2 > 0", "/d/t")
    va = np.concatenate([x.column("c2").values for x in a])
    vb = np.concatenate([x.column("c2").values for x in b])
    np.testing.assert_allclose(va, vb)
    assert (va > 0).all()


def test_multi_tenant_readers(server):
    h1 = server.init_scan("SELECT c0 FROM t", "/d/t")
    h2 = server.init_scan("SELECT c1 FROM t", "/d/t")
    assert h1.uuid != h2.uuid
    assert len(server.reader_map) == 2
    server.finalize(h1.uuid)
    assert len(server.reader_map) == 1
    with pytest.raises(KeyError):
        server.finalize(h1.uuid)   # double-finalize rejected
    server.finalize(h2.uuid)


def test_resume_from_cursor(server):
    """A client that dies mid-scan resumes via start_batch without
    re-pulling earlier batches (fault tolerance)."""
    c1 = ThallusClient(server)
    handle = server.init_scan("SELECT c0 FROM t", "/d/t")
    c1._schema = handle.schema
    server.iterate(handle.uuid, c1.do_rdma, max_batches=3)
    pos = server.cursor_position(handle.uuid)
    assert pos == 3
    # crash: no finalize. new client resumes at the recorded cursor
    c2 = ThallusClient(server)
    rest = c2.run_query("SELECT c0 FROM t", "/d/t", start_batch=pos)
    total = sum(b.num_rows for b in c1.batches + rest)
    assert total == 50_000
    # leaked lease from the dead client is reclaimable
    assert server.reclaim_stale(older_than_s=0.0) == 1


def test_bounded_lease(server):
    client = ThallusClient(server)
    handle = server.init_scan("SELECT c0 FROM t", "/d/t")
    client._schema = handle.schema
    shipped = server.iterate(handle.uuid, client.do_rdma, max_batches=2)
    assert shipped == 2
    shipped = server.iterate(handle.uuid, client.do_rdma)
    assert shipped == 5  # 50k rows / 8192 per batch = 7 total
    server.finalize(handle.uuid)


def test_transport_stats_decompose(server):
    client = ThallusClient(server)
    client.run_query("SELECT c0, c1, c2, c3, c4, c5 FROM t", "/d/t")
    for st in client.stats:
        assert st.serialize_s == 0.0
        assert st.wire.bytes_moved > 0
        assert st.total_s > 0


def test_finalize_twice_raises(server):
    handle = server.init_scan("SELECT c0 FROM t", "/d/t")
    server.finalize(handle.uuid)
    with pytest.raises(KeyError):
        server.finalize(handle.uuid)


def test_iterate_after_finalize_raises(server):
    client = ThallusClient(server)
    handle = server.init_scan("SELECT c0 FROM t", "/d/t")
    client._schema = handle.schema
    server.finalize(handle.uuid)
    with pytest.raises(KeyError):
        server.iterate(handle.uuid, client.do_rdma)


def test_resume_past_end_of_stream(server):
    """init_scan(start_batch=k) beyond the last batch yields an immediately
    drained (but valid, finalizable) reader."""
    client = ThallusClient(server)
    batches = client.run_query("SELECT c0 FROM t", "/d/t", start_batch=999)
    assert batches == []
    assert not server.reader_map     # run_query finalized the empty lease


def test_rpc_client_resumes_from_cursor(server):
    """The baseline client takes start_batch through the same public API —
    no reaching into server internals (the thallus/rpc asymmetry is gone)."""
    full = RpcClient(server).run_query("SELECT c0 FROM t", "/d/t")
    tail = RpcClient(server).run_query("SELECT c0 FROM t", "/d/t",
                                       start_batch=3)
    assert sum(b.num_rows for b in tail) == \
           sum(b.num_rows for b in full[3:])
    np.testing.assert_array_equal(tail[0].column("c0").values,
                                  full[3].column("c0").values)


def test_reclaim_spares_active_scans(server):
    """Regression: a long-running scan that keeps iterating must NOT be
    evicted just because it was created long ago — staleness is judged by
    last_activity, refreshed on every iterate/next_batch."""
    import time as _time

    client = ThallusClient(server)
    active = server.init_scan("SELECT c0 FROM t", "/d/t")
    client._schema = active.schema
    abandoned = server.init_scan("SELECT c1 FROM t", "/d/t")
    _time.sleep(0.05)
    # the active lease pulls a batch (refreshing last_activity); the
    # abandoned one has been idle the whole time
    server.iterate(active.uuid, client.do_rdma, max_batches=1)
    assert server.reclaim_stale(older_than_s=0.04) == 1
    assert active.uuid in server.reader_map
    assert abandoned.uuid not in server.reader_map
    server.finalize(active.uuid)


def test_reclaim_stale_on_modeled_clock():
    """Regression: reclaim_stale judged staleness on the WALL clock even
    when the deployment runs on a modeled timeline, so a modeled sweep
    either leaked dead leases forever (modeled now ~0 << monotonic
    last_activity) or evicted every live lease at once. With a ``clock``
    hook (or an explicit ``now_s``) the whole lifecycle — stamp, touch,
    sweep — lives on one timeline."""
    t = [0.0]
    eng = Engine()
    eng.register("/d/t", make_numeric_table("t", 20_000, 2, batch_rows=4096))
    server = ThallusServer(eng, Fabric(), clock=lambda: t[0])
    client = ThallusClient(server)
    active = server.init_scan("SELECT c0 FROM t", "/d/t")
    client._schema = active.schema
    abandoned = server.init_scan("SELECT c1 FROM t", "/d/t")
    t[0] = 100.0
    server.iterate(active.uuid, client.do_rdma, max_batches=1)  # touch @100
    assert server.reclaim_stale(older_than_s=50.0) == 1
    assert active.uuid in server.reader_map
    assert abandoned.uuid not in server.reader_map
    # an explicit now_s pins the sweep even without a clock hook
    assert server.reclaim_stale(older_than_s=10.0, now_s=200.0) == 1
    assert not server.reader_map


def test_crash_kills_leases_and_restore_revives(server):
    """A crashed server drops its reader map and refuses every protocol
    verb with ServerCrashedError until restored."""
    from repro.core import ServerCrashedError

    handle = server.init_scan("SELECT c0 FROM t", "/d/t")
    server.crash()
    assert server.crashed and not server.reader_map
    with pytest.raises(ServerCrashedError):
        server.init_scan("SELECT c0 FROM t", "/d/t")
    with pytest.raises(ServerCrashedError):
        server.iterate(handle.uuid, lambda *a: None, max_batches=1)
    server.restore()
    batches = ThallusClient(server).run_query("SELECT c0 FROM t", "/d/t")
    assert sum(b.num_rows for b in batches) == 50_000


def test_crash_after_batches_dies_mid_iterate(server):
    """``crash(after_batches=n)`` ships n more batches then dies MID-LEASE:
    the client keeps the delivered prefix, the server is down, and the
    raised error reports how much of the lease shipped."""
    from repro.core import ServerCrashedError

    client = ThallusClient(server)
    handle = server.init_scan("SELECT c0 FROM t", "/d/t")
    client._schema = handle.schema
    server.crash(after_batches=2)
    assert not server.crashed                     # armed, not yet dead
    with pytest.raises(ServerCrashedError, match="after shipping 2"):
        server.iterate(handle.uuid, client.do_rdma, max_batches=7)
    assert server.crashed
    assert len(client.batches) == 2               # the delivered prefix
