"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp ref."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.pack import (layout_segments, pack_ref, pack_segments,
                                pack_tiles, stage_segments, unpack_segments,
                                packed_nbytes, tiles_for, TILE_BYTES)
from repro.kernels.take import (bitmap_expand_ref, expand_validity,
                                take_column, take_ref)

DTYPES = (np.float32, np.int32, np.int64, np.uint8, np.float16)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("sizes", [
    [1], [4096], [4096, 4096], [1, 5000, 17], [0, 100], [8192, 64, 3, 4097],
])
def test_pack_roundtrip_shapes_dtypes(rng, dtype, sizes):
    segs = [(rng.standard_normal(n) * 100).astype(dtype) for n in sizes]
    packed, lens = pack_segments(segs)
    assert packed.dtype == jnp.uint8
    assert packed.size == packed_nbytes(lens)
    outs = unpack_segments(packed, lens)
    for s, o in zip(segs, outs):
        np.testing.assert_array_equal(s.view(np.uint8).reshape(-1), o)


def test_pack_kernel_matches_ref(rng):
    segs = [rng.integers(0, 255, n).astype(np.uint8) for n in (100, 9000, 1)]
    staged, seg_lens = stage_segments(segs)
    seg_ids, tile_ids, _ = layout_segments([int(x) for x in seg_lens])
    got = pack_tiles(jnp.asarray(staged), jnp.asarray(seg_ids),
                     jnp.asarray(tile_ids))
    ref = pack_ref(jnp.asarray(staged), jnp.asarray(seg_ids),
                   jnp.asarray(tile_ids))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_tiles_for():
    assert tiles_for(0) == 1
    assert tiles_for(1) == 1
    assert tiles_for(TILE_BYTES) == 1
    assert tiles_for(TILE_BYTES + 1) == 2


@pytest.mark.parametrize("dtype", (np.float32, np.int32, np.float16))
@pytest.mark.parametrize("shape", [(64, 1), (130, 7), (512, 128), (300, 200)])
def test_take_matches_ref(rng, dtype, shape):
    vals = (rng.standard_normal(shape) * 10).astype(dtype)
    idx = rng.integers(0, shape[0], 97).astype(np.int32)
    got = np.asarray(take_column(vals, idx))
    ref = np.asarray(take_ref(jnp.asarray(vals), jnp.asarray(idx)))
    np.testing.assert_array_equal(got, ref)


def test_take_1d(rng):
    vals = rng.integers(-5, 5, 777).astype(np.int64)
    idx = rng.integers(0, 777, 33).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(take_column(vals, idx)), vals[idx])


@pytest.mark.parametrize("n", [1, 8, 100, 1024, 4096, 10000])
def test_bitmap_expand_matches_ref(rng, n):
    mask = rng.integers(0, 2, n).astype(bool)
    bm = np.packbits(mask, bitorder="little")
    got = np.asarray(expand_validity(bm, n))
    ref = np.asarray(bitmap_expand_ref(jnp.asarray(bm), n))
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got, mask)


# ---------------------------------------------------------------------------
# flash attention (the kernel behind the vmem_fused_attention accounting)
# ---------------------------------------------------------------------------

from repro.kernels.attention import attention_ref, flash_attention, flash_gqa


@pytest.mark.parametrize("shape", [(2, 128, 128, 64), (1, 256, 256, 32),
                                   (1, 128, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(rng, shape, causal):
    BH, Sq, Sk, hd = shape
    if causal and Sq != Sk:
        pytest.skip("causal requires square")
    q = jnp.asarray(rng.standard_normal((BH, Sq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, Sk, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, Sk, hd)), jnp.float32)
    got = np.asarray(flash_attention(q, k, v, causal=causal))
    ref = np.asarray(attention_ref(q, k, v, causal=causal))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_gqa_matches_model_attention(rng):
    """The kernel and the jnp path the models actually lower must agree —
    this is what licenses the fused-memory roofline accounting."""
    from repro.models.layers import chunked_attention
    B, S, H, KV, hd = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = np.asarray(flash_gqa(q, k, v, causal=True))
    b = np.asarray(chunked_attention(q, k, v, causal=True, q_positions=pos,
                                     k_positions=pos, kv_chunk=64))
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)
