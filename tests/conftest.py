import os

# Smoke tests and benches run on the single real CPU device; ONLY the
# dry-run entry point (repro.launch.dryrun) forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

from repro.cluster import ClusterCoordinator
from repro.core import Fabric, FabricConfig, ThallusClient, ThallusServer
from repro.engine import Engine, make_numeric_table


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ----------------------------------------------------- shared cluster setup
# The qos/sched/cluster suites all stand up the same fixture: a numeric
# table dealt across N ThallusServers (optionally with one slowed-down
# fabric), a single-server reference scan, and token shards for the loader.
# One definition here; the suites parameterize rows/batch sizes.

def make_coordinator(num_servers: int, placement: str = "shard",
                     table=None, rows: int = 40_000, ncols: int = 4,
                     batch_rows: int = 4096, dataset: str = "/d",
                     admission=None, slow: int | None = None,
                     slowdown: float = 4.0, slowdown_all: float = 1.0,
                     server_cls=ThallusServer) -> ClusterCoordinator:
    """A seeded N-server cluster: ``table`` (or a fresh numeric one) placed
    as shards or replicas, with server ``slow``'s fabric ``slowdown``×
    slower (the straggler fixture) and an optional admission controller.
    ``slowdown_all`` slows every fabric uniformly — tests asserting modeled
    makespan ratios use it so modeled wire time dwarfs measured noise."""
    if table is None:
        table = make_numeric_table("t", rows, ncols, batch_rows=batch_rows)
    coord = ClusterCoordinator(admission=admission)
    for i in range(num_servers):
        factor = slowdown_all * (slowdown if slow == i else 1.0)
        cfg = FabricConfig()
        if factor != 1.0:
            cfg = FabricConfig(rpc_bw=cfg.rpc_bw / factor,
                               rdma_bw=cfg.rdma_bw / factor)
        coord.add_server(f"s{i}", server_cls(Engine(), Fabric(cfg)))
    if placement == "shard":
        coord.place_shards(dataset, table)
    else:
        coord.place_replicas(dataset, table)
    return coord


def reference_batches(sql: str, table=None, rows: int = 40_000,
                      ncols: int = 4, batch_rows: int = 4096,
                      dataset: str = "/d"):
    """The single-server, single-stream scan every parity test compares
    against (same seeded table as :func:`make_coordinator`)."""
    if table is None:
        table = make_numeric_table("t", rows, ncols, batch_rows=batch_rows)
    eng = Engine()
    eng.register(dataset, table)
    return ThallusClient(ThallusServer(eng, Fabric())).run_query(sql, dataset)


def token_servers(n: int, num_seqs: int = 96, seq_len: int = 32,
                  vocab_size: int = 128, seqs_per_batch: int = 16,
                  dataset: str = "/d") -> list[ThallusServer]:
    """N replica servers over one token table — the loader suites' shape."""
    from repro.data import make_token_table
    table = make_token_table("tok", num_seqs=num_seqs, seq_len=seq_len,
                             vocab_size=vocab_size,
                             seqs_per_batch=seqs_per_batch)
    servers = []
    for _ in range(n):
        eng = Engine()
        eng.register(dataset, table)
        servers.append(ThallusServer(eng, Fabric()))
    return servers


# ------------------------------------------------- recorded straggler trace
# The canonical straggler fixture: the test_sched table shape (16 batches of
# 8192 rows, 2 selected columns) replicated on 4 servers with server 3's
# fabric 4x slow. STRAGGLER_TRACE is the steal-event log the PR 3
# static-factor StealingPuller produced on it (all values modeled, so the
# replay is exact); the conformance suite replays today's puller with
# history=None (and with every hysteresis knob neutralized) against it.

STRAGGLER_ROWS = 1 << 17
STRAGGLER_BATCH_ROWS = 1 << 13
STRAGGLER_SQL = "SELECT c0, c1 FROM t"

#: (victim, thief, start_batch, num_batches, epoch_s, victim_eta_s,
#:  median_eta_s) per event, modeled times rounded to 12 decimals.
STRAGGLER_TRACE = (
    ("s3", "s0", 14, 2, 9.8181333e-05, 0.000196362667, 6.5290667e-05),
)


def straggler_coordinator(table=None) -> ClusterCoordinator:
    """The coordinator STRAGGLER_TRACE was recorded against."""
    if table is None:
        table = make_numeric_table("t", STRAGGLER_ROWS, 4,
                                   batch_rows=STRAGGLER_BATCH_ROWS)
    return make_coordinator(4, "replica", table=table, slow=3, slowdown=4.0)


def steal_event_trace(stats) -> tuple:
    """A ClusterStats' steal events in STRAGGLER_TRACE's comparable shape
    (kind-tagged fields excluded: the conformance claim is that the static
    paths fire identically, and a decline/re-steal appearing at all would
    change the event count)."""
    return tuple((e.victim, e.thief, e.start_batch, e.num_batches,
                  round(e.epoch_s, 12), round(e.victim_eta_s, 12),
                  round(e.median_eta_s, 12)) for e in stats.steal_events)


class ModeledClock:
    """A tiny monotonic modeled clock for admission/reconcile tests: the
    qos layer runs on caller-supplied modeled times, so tests drive one
    explicitly instead of scattering float literals."""

    def __init__(self, start_s: float = 0.0):
        self.now_s = start_s

    def advance(self, dt_s: float) -> float:
        if dt_s < 0:
            raise ValueError("modeled time only moves forward")
        self.now_s += dt_s
        return self.now_s


@pytest.fixture
def modeled_clock():
    return ModeledClock()
