import os

# Smoke tests and benches run on the single real CPU device; ONLY the
# dry-run entry point (repro.launch.dryrun) forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
