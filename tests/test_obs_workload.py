"""repro.obs workload layer: seeded client populations, side workloads and
the stress driver — determinism (same seed => identical submit schedule and
registry snapshot), Jain fairness bounds, conformance of the population
machinery to the scripted bench shapes, rate-metric zero-window guards, and
causal shed/decline attribution through a wired gateway."""
import zlib

import numpy as np
import pytest
from conftest import make_coordinator

from repro.cluster import ClusterCoordinator
from repro.core import Fabric, FabricConfig, ThallusServer
from repro.engine import Engine, make_numeric_table
from repro.obs import (ClientPopulation, FlightRecorder, InteractiveSideLoad,
                       MetricsRegistry, PopulationSideWorkload, StressDriver,
                       jain_index, population_classes, record_workload)
from repro.qos import (AdmissionConfig, DistributedConfig, ScanGateway,
                       ShardedAdmission)
from repro.qos.metrics import ClassStats

pytestmark = pytest.mark.obs

LIGHT_SQL = "SELECT c0 FROM t"


# ------------------------------------------------------------ jain fairness


def test_jain_bounds_and_degenerate_inputs():
    # degenerate allocations are fair by definition
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0, 0.0]) == 1.0          # zero-throughput mix
    assert jain_index([42.0]) == 1.0                   # a single population
    # perfect equality
    assert jain_index([3.0, 3.0, 3.0, 3.0]) == pytest.approx(1.0)
    # one class hogging everything: the 1/n lower bound
    assert jain_index([10.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
    # monotone: skew strictly lowers the index
    assert jain_index([4.0, 1.0, 1.0, 1.0]) < jain_index([2.0, 1.0, 1.0, 1.0])
    # negative readings clamp instead of inflating the numerator
    assert jain_index([-1.0, 1.0]) == jain_index([0.0, 1.0])


# ----------------------------------------------------- zero-duration guards


def test_rate_properties_survive_zero_modeled_duration():
    """A class whose every request shed before any service ran has bytes
    and samples but zero modeled duration — every rate/percentile property
    must report 0.0, not divide by zero."""
    c = ClassStats("batch")
    c.bytes = 1 << 20
    c.service_s = 0.0
    assert c.throughput_bytes_per_s == 0.0
    assert c.throughput_over(0.0) == 0.0
    assert c.throughput_over(-1.0) == 0.0
    assert c.mean_grant_latency_s == 0.0               # no samples either
    assert c.p50_grant_latency_s == 0.0
    # and a real window still divides
    assert c.throughput_over(2.0) == pytest.approx((1 << 20) / 2.0)


# ----------------------------------------------------- populations: drawing


def test_population_draw_processes_and_windows():
    rng = np.random.default_rng(0)
    burst = ClientPopulation("b", arrival="burst", rate_per_beat=3.0)
    kws = burst.draw(rng, 1.0, 2.0)
    assert [k["arrival_s"] for k in kws] == [2.0, 2.0, 2.0]
    uniform = ClientPopulation("u", arrival="uniform", rate_per_beat=4.0)
    kws = uniform.draw(rng, 0.0, 1.0)
    assert [k["arrival_s"] for k in kws] == [0.25, 0.5, 0.75, 1.0]
    poisson = ClientPopulation("p", arrival="poisson", rate_per_beat=5.0,
                               cost_jitter=0.2)
    kws = poisson.draw(rng, 0.0, 1.0)
    assert all(0.0 <= k["arrival_s"] <= 1.0 for k in kws)
    assert kws == sorted(kws, key=lambda k: k["arrival_s"])
    assert all(k["cost_hint"] > 0 for k in kws)


def test_population_validation_and_activation():
    with pytest.raises(ValueError):
        ClientPopulation("x", arrival="weibull")
    with pytest.raises(ValueError):
        ClientPopulation("x", rate_per_beat=-1.0)
    p = ClientPopulation("x", start_beat=2, stop_beat=4)
    assert [p.active(b) for b in range(5)] == [False, False, True, True,
                                               False]


class _RecordingGateway:
    """Duck-typed gateway stub: captures submitted requests verbatim."""

    def __init__(self, clock_s: float = 0.0):
        self.clock_s = clock_s
        self.requests = []

    def submit(self, request):
        self.requests.append(request)
        return request


def test_same_seed_replays_identical_schedule():
    pop = ClientPopulation("storm", arrival="poisson", rate_per_beat=4.0,
                           cost_jitter=0.3, num_streams=2)
    schedules = []
    for _ in range(2):
        gw = _RecordingGateway()
        load = PopulationSideWorkload(pop, seed=9)
        for clock in (0.0, 1.0, 2.5, 4.0):
            gw.clock_s = clock
            load.submit(gw)
        schedules.append(load.schedule)
    assert schedules[0] == schedules[1]
    # a different seed draws a different storm
    gw = _RecordingGateway()
    other = PopulationSideWorkload(pop, seed=10)
    for clock in (0.0, 1.0, 2.5, 4.0):
        gw.clock_s = clock
        other.submit(gw)
    assert other.schedule != schedules[0]


def test_population_seed_streams_are_name_scoped():
    """Two same-seed populations with different names draw independent
    streams (the rng key folds in crc32(name))."""
    a = PopulationSideWorkload(
        ClientPopulation("a", arrival="poisson", rate_per_beat=4.0), seed=3)
    b = PopulationSideWorkload(
        ClientPopulation("b", arrival="poisson", rate_per_beat=4.0), seed=3)
    assert zlib.crc32(b"a") != zlib.crc32(b"b")
    gw_a, gw_b = _RecordingGateway(), _RecordingGateway()
    for clock in (1.0, 2.0, 3.0):
        gw_a.clock_s = gw_b.clock_s = clock
        a.submit(gw_a)
        b.submit(gw_b)
    offsets_a = [k["arrival_s"] for k in a.schedule]
    offsets_b = [k["arrival_s"] for k in b.schedule]
    assert offsets_a != offsets_b


# ----------------------------------------------- conformance to bench shapes


def test_single_population_degenerates_to_contention_mix():
    """One burst interactive population IS the scripted contention shape:
    ``transport_bench._submit_contention_mix`` submits 6 interactive
    lookups (client ``ui``, LIGHT_SQL, cost 1.0) at the current clock —
    the population machinery must reproduce that submit stream exactly."""
    gw = _RecordingGateway(clock_s=0.125)
    load = PopulationSideWorkload(ClientPopulation(
        "interactive", arrival="burst", rate_per_beat=6.0, sql=LIGHT_SQL,
        cost_hint=1.0, client_id="ui"), seed=0)
    load.submit(gw)
    assert len(gw.requests) == 6
    for r in gw.requests:
        assert (r.client_id, r.klass, r.sql, r.dataset) == (
            "ui", "interactive", LIGHT_SQL, "/d")
        assert r.cost_hint == 1.0
        assert r.deadline_s is None
        assert r.num_streams is None
        assert r.arrival_s == 0.125                    # burst: at the clock


def test_interactive_side_load_is_the_submit_side_load_shape():
    """``InteractiveSideLoad`` is the single implementation behind
    ``transport_bench.submit_side_load``: two light interactive lookups
    from client ``side`` stamped on the gateway's current clock."""
    gw = _RecordingGateway(clock_s=2.0)
    reqs = InteractiveSideLoad(LIGHT_SQL, "/d").submit(gw)
    assert len(reqs) == len(gw.requests) == 2
    for r in gw.requests:
        assert (r.client_id, r.klass, r.sql) == ("side", "interactive",
                                                 LIGHT_SQL)
        assert r.arrival_s == 2.0 and r.num_streams == 2


def test_side_workload_window_cursor_never_stamps_the_future():
    """Swapping in a fresh gateway (clock restarts at 0) must clamp the
    window: arrivals are never stamped after the submit instant."""
    pop = ClientPopulation("u", arrival="uniform", rate_per_beat=2.0)
    load = PopulationSideWorkload(pop, seed=0)
    gw = _RecordingGateway(clock_s=5.0)
    load.submit(gw)
    fresh = _RecordingGateway(clock_s=0.5)              # new modeled epoch
    load.submit(fresh)
    assert all(r.arrival_s <= 0.5 for r in fresh.requests)


# --------------------------------------------------- the driver, end to end


def _stress_cluster(populations, recorder):
    ids = ["s0", "s1", "s2"]
    table = make_numeric_table("t", 6 * 1024, 4, batch_rows=1024)
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=2 * len(ids)), ids,
        dist=DistributedConfig(borrow_limit=0))
    coord = ClusterCoordinator(admission=admission, recorder=recorder)
    for sid in ids:
        coord.add_server(sid, ThallusServer(Engine(), Fabric(FabricConfig())))
    coord.place_replicas("/d", table)
    return ScanGateway(coord, classes=population_classes(populations),
                       modeled_service=True)


def test_driver_same_seed_identical_registry_snapshot():
    def one_run():
        pops = [
            ClientPopulation("interactive", weight=4.0, arrival="uniform",
                             rate_per_beat=2.0, sql=LIGHT_SQL,
                             num_streams=2),
            ClientPopulation("storm", weight=1.0, arrival="poisson",
                             rate_per_beat=3.0, sql=LIGHT_SQL, cost_hint=4.0,
                             cost_jitter=0.3, num_streams=2),
        ]
        driver = StressDriver(_stress_cluster(pops, FlightRecorder()), pops,
                              seed=21)
        for _ in range(4):
            driver.beat()
        return ([lo.schedule for lo in driver.loads],
                driver.registry.snapshot())

    (sched_a, snap_a), (sched_b, snap_b) = one_run(), one_run()
    assert sched_a == sched_b
    assert snap_a == snap_b
    assert snap_a["workload.interactive.submitted"] == 8
    assert "workload.interactive.grant_latency.p99" in snap_a
    assert "workload.fairness.jain" in snap_a


def test_driver_attributes_sheds_and_squatter_declines():
    """Causal attribution end to end: an impossible deadline sheds the
    interactive class (``qos.shed``), and a squatter holding both of one
    replica-pair server's slots forces the other tenant's fan-outs to
    decline (``qos.backpressure``) — each charged to the right population
    via the flight-recorder window."""
    recorder = FlightRecorder()
    pops = [
        ClientPopulation("interactive", weight=4.0, arrival="uniform",
                         rate_per_beat=2.0, sql=LIGHT_SQL, num_streams=2,
                         deadline_s=1e-9),
        # replica placement with num_streams=2 lands on sorted-first
        # {s0, s1}; the squatter pins both s0 slots (per-server slice = 2)
        ClientPopulation("squatter", rate_per_beat=0.0,
                         squat_servers=("s0", "s0")),
    ]
    driver = StressDriver(_stress_cluster(pops, recorder), pops, seed=5)
    reports = [driver.beat() for _ in range(3)]
    # beat 0's window is empty (uniform arrivals land on the clock) —
    # later beats carry positive waits that bust the 1ns deadline
    assert driver.sheds["interactive"] >= 1
    assert driver.declines["interactive"] >= 1
    assert driver.sheds.get("squatter", 0) == 0
    assert sum(r.shed + r.declined for r in reports) >= 1
    kinds = {e.kind for e in recorder.events()}
    assert "qos.backpressure" in kinds
    snap = driver.registry.snapshot()
    assert snap["workload.interactive.declines"] == (
        driver.declines["interactive"])


def test_record_workload_zero_beats_is_all_zeros():
    """A driver queried before its first beat: zero window, no samples —
    the registry must come out finite and the fairness degenerate-fair."""
    pops = [ClientPopulation("interactive", sql=LIGHT_SQL, num_streams=2)]
    driver = StressDriver(_stress_cluster(pops, FlightRecorder()), pops)
    reg = MetricsRegistry()
    record_workload(reg, driver)
    snap = reg.snapshot()
    assert snap["workload.fairness.jain"] == 1.0
    assert snap["workload.fairness.latency_inflation"] == 1.0
    assert snap["workload.window.us"] == 0.0
