"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs; plus
serve-path (prefill+decode) coverage and SSM decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode, init_params, loss_fn, forward, prefill
from repro.training import OptimizerConfig, TrainConfig, init_train_state, make_train_step

KV_KEYS = ("k", "v", "self_k", "self_v")


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vlm.num_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encdec.encoder_seq, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits = forward(cfg, params, batch, remat="none")
    S_out = 16 + (cfg.vlm.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_out, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"
    loss = loss_fn(cfg, params, batch, remat="none")
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    # warmup_steps=0: full lr at step 0 so one step visibly moves params
    tcfg = TrainConfig(optimizer=OptimizerConfig(learning_rate=1e-3,
                                                 warmup_steps=0, decay_steps=10),
                       remat="none")
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(1))
    step = make_train_step(cfg, tcfg)
    batch = _batch(cfg, rng)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved, f"{arch}: update was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    logits_p, cache = prefill(cfg, params, batch, remat="none")
    assert np.isfinite(np.asarray(logits_p)).all(), arch

    def grow(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else None
        if name in KV_KEYS:
            return jnp.pad(x, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
        return x

    cache = jax.tree_util.tree_map_with_path(grow, cache)
    pos = S + (cfg.vlm.num_patches if cfg.family == "vlm" else 0)
    logits_d, new_cache = decode(cfg, params, cache,
                                 batch["tokens"][:, :1], jnp.int32(pos))
    assert logits_d.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits_d)).all(), arch
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-1.2b"])
def test_ssm_decode_matches_forward(arch, rng):
    """Strong consistency: prefill(S)+decode chain == full forward — the
    recurrent and chunked-dual forms of SSD must agree."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    S, extra = 24, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + extra)),
                       jnp.int32)
    full = forward(cfg, params, {"tokens": toks}, remat="none")
    _, cache = prefill(cfg, params, {"tokens": toks[:, :S]}, remat="none")
    if arch == "zamba2-1.2b":
        def grow(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else None
            if name in KV_KEYS:
                return jnp.pad(x, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
            return x
        cache = jax.tree_util.tree_map_with_path(grow, cache)
    logits = None
    for i in range(S, S + extra):
        logits, cache = decode(cfg, params, cache, toks[:, i : i + 1],
                               jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["granite-3-2b"])
def test_attention_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    S, extra = 12, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + extra)),
                       jnp.int32)
    full = forward(cfg, params, {"tokens": toks}, remat="none")
    _, cache = prefill(cfg, params, {"tokens": toks[:, :S]}, remat="none")
    cache = jax.tree.map(
        lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
        if x.ndim == 5 else x, cache)
    logits = None
    for i in range(S, S + extra):
        logits, cache = decode(cfg, params, cache, toks[:, i : i + 1],
                               jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_num_params_accounting():
    """MODEL_FLOPS honesty: analytic N within 2% of actual leaf count for a
    reduced dense config."""
    cfg = get_config("deepseek-coder-33b")
    n_full = cfg.num_params()
    assert 32e9 < n_full < 35e9        # ~33B
    moe = get_config("olmoe-1b-7b")
    assert moe.num_params(active_only=True) < moe.num_params() / 4
