"""Peer-to-peer shard migration & replica repair over the RDMA fast path:
donor selection off the segment directory, registered-pool pulls with slab
adoption, the stored-table durability fallback, background-class QoS
metering, and the failover story when a dead server was the sole holder."""
import random

import pytest
from conftest import make_coordinator, reference_batches

from repro.cluster import (BufferPool, MembershipController, MigrationError,
                           RepairConfig, ShardRepairer, cluster_scan)
from repro.core import Fabric, FabricConfig, ThallusServer
from repro.core.bulk import SegmentDesc
from repro.engine import Engine, make_numeric_table
from repro.obs import FlightRecorder, HealthMonitor
from repro.qos import AdmissionConfig, ShardedAdmission

ROWS = 40_000
SQL = "SELECT c0, c1 FROM t"


def fresh_server():
    return ThallusServer(Engine(), Fabric(FabricConfig()))


def scan_signature(coord, sql=SQL, dataset="/d", **kw):
    got = []
    cluster_scan(coord, sql, dataset, sink=lambda i, b: got.append(b), **kw)
    return sorted(tuple(c.values.tobytes() for c in b.columns) for b in got)


def reference_signature(sql=SQL, rows=ROWS):
    return sorted(tuple(c.values.tobytes() for c in b.columns)
                  for b in reference_batches(sql, rows=rows))


# ------------------------------------------------------------- peer pulls


def test_join_pulls_batches_peer_to_peer():
    """A live shard join moves the joiner's slice server→server over the
    registered pool path — zero table copies, donors attributed in the
    notify stream — and the repaired cluster scans byte-identical."""
    recorder = FlightRecorder()
    coord = make_coordinator(3)
    coord.recorder = recorder
    rep = ShardRepairer(coord)
    total = sum(len(v) for v in coord._placements["/d"].assignment.values())
    coord.add_server("s3", fresh_server(), rebalance=True)
    assert rep.stats.batches_pulled == total // 4
    assert rep.stats.table_copies == 0
    assert rep.stats.bytes_pulled > 0
    pulls = recorder.events(kinds=["repair.pull"])
    assert len(pulls) == total // 4
    donors = {e.attrs["donor"] for e in pulls}
    assert donors <= {"s0", "s1", "s2"} and donors
    assert scan_signature(coord) == reference_signature()


def test_peer_repair_matches_legacy_table_copy_bytes():
    """The peer path and the legacy coordinator-copy path are byte-for-byte
    interchangeable across the same join + leave sequence."""
    table = make_numeric_table("t", ROWS, 4, batch_rows=4096)
    peer = make_coordinator(3, table=table)
    legacy = make_coordinator(3, table=table)
    ShardRepairer(peer)
    for coord in (peer, legacy):
        coord.add_server("s3", fresh_server(), rebalance=True)
        coord.remove_server("s0")
    assert (peer._placements["/d"].assignment
            == legacy._placements["/d"].assignment)
    assert scan_signature(peer) == scan_signature(legacy) \
        == reference_signature()


def test_replica_join_pulls_full_copy_from_peers():
    """A replica join pre-warms the joiner entirely from live donors: every
    batch pulled, none copied, and the new replica serves byte-identical."""
    coord = make_coordinator(3, placement="replica")
    rep = ShardRepairer(coord)
    batches = len(coord._placements["/d"].table.batches)
    joiner = fresh_server()
    coord.add_server("s3", joiner, rebalance=True)
    assert rep.stats.batches_pulled == batches
    assert rep.stats.table_copies == 0
    assert "/d" in joiner.engine.catalog
    # scan pinned to the joiner alone: its pulled copy is the whole dataset
    coord.remove_server("s0")
    coord.remove_server("s1")
    coord.remove_server("s2")
    assert scan_signature(coord) == reference_signature()


def test_evicted_sole_holder_falls_back_to_stored_table():
    """A departed shard server's orphans have no live registered holder —
    the durability fallback streams them from the stored source table."""
    recorder = FlightRecorder()
    coord = make_coordinator(4)
    coord.recorder = recorder
    rep = ShardRepairer(coord)
    orphans = len(coord._placements["/d"].assignment["s1"])
    coord.remove_server("s1")
    assert rep.stats.table_copies == orphans
    assert rep.stats.batches_pulled == 0          # nothing to pull: disjoint
    assert rep.stats.bytes_copied > 0
    assert len(recorder.events(kinds=["repair.fallback"])) == orphans
    assert scan_signature(coord) == reference_signature()


def test_readmit_prewarm_rides_peer_path():
    """The membership re-admit pre-warm pulls the returning replica's copy
    peer-to-peer (a cold-restarted engine included) and reports the
    movement as ``repair.prewarm``."""
    recorder = FlightRecorder()
    health = HealthMonitor(recorder=recorder)
    coord = make_coordinator(3, placement="replica")
    coord.recorder, coord.health = recorder, health
    rep = ShardRepairer(coord)
    controller = MembershipController(coord, health)
    server = coord.server("s1")
    server.crash()
    for _ in range(3):
        coord.notify("stream.fault", server_id="s1", now_s=1.0)
    coord.heartbeat(1.0)
    controller.heartbeat(1.0)
    assert controller.evicted == ("s1",)
    server.engine = Engine()                      # cold restart
    server.restore()
    now = 2.0
    for _ in range(16):
        if "s1" in coord.servers:
            break
        coord.heartbeat(now)
        controller.heartbeat(now)
        now += 1.0
    assert "s1" in coord.servers
    assert "/d" in server.engine.catalog
    batches = len(coord._placements["/d"].table.batches)
    assert rep.stats.batches_pulled == batches    # the pre-warm, all peer
    prewarms = recorder.events(kinds=["repair.prewarm"])
    assert prewarms and prewarms[0].attrs["pulled"] == batches
    assert scan_signature(coord, num_streams=3) == reference_signature()


# ----------------------------------------------- sole-holder failover story


def test_failover_sole_holder_raises_then_fallback_restores_service():
    """Every replica of the dataset is dead: the in-flight lease surfaces a
    typed MigrationError — and the repair fallback then restores service
    from the stored source table on a fresh joiner."""
    coord = make_coordinator(2, placement="replica")
    rep = ShardRepairer(coord)
    plan = coord.plan(SQL, "/d", num_streams=2)
    for sid in ("s0", "s1"):
        coord.server(sid).crash()
    with pytest.raises(MigrationError):
        coord.failover_stream(plan.endpoints[0], 0)
    with pytest.raises(MigrationError):
        coord.failover_target(plan.endpoints[1])
    # the holders are gone for good: remove them, join a fresh server
    coord.remove_server("s0")
    coord.remove_server("s1")
    batches = len(coord._placements["/d"].table.batches)
    coord.add_server("s2", fresh_server(), rebalance=True)
    assert rep.stats.table_copies == batches      # no live donor anywhere
    assert rep.stats.batches_pulled == 0
    assert scan_signature(coord) == reference_signature()


# ------------------------------------------------- property: random splits


def test_random_membership_walk_stays_byte_identical():
    """Seeded random join/leave walks over shard placements: the peer path
    and the legacy table-copy path agree byte-for-byte at every step, and
    the repairer's segment directory always matches the live assignment."""
    table = make_numeric_table("t", ROWS, 4, batch_rows=4096)
    ref = reference_signature()
    for seed in (3, 11, 29):
        rng = random.Random(seed)
        peer = make_coordinator(3, table=table)
        legacy = make_coordinator(3, table=table)
        rep = ShardRepairer(peer)
        next_id, live = 3, 3
        for _ in range(6):
            if live > 2 and rng.random() < 0.5:
                victim = rng.choice(
                    sorted(peer._placements["/d"].assignment))
                for coord in (peer, legacy):
                    coord.remove_server(victim)
                live -= 1
            else:
                sid = f"s{next_id}"
                next_id += 1
                for coord in (peer, legacy):
                    coord.add_server(sid, fresh_server(), rebalance=True)
                live += 1
            assignment = peer._placements["/d"].assignment
            assert assignment == legacy._placements["/d"].assignment
            for sid, idxs in assignment.items():
                assert set(rep._held["/d"][sid]) == set(idxs)
            assert scan_signature(peer) == scan_signature(legacy) == ref


# ------------------------------------------------------------ QoS metering


def test_repair_yields_to_drained_donor_bucket():
    """With the donor's token bucket drained below the foreground reserve,
    repair backs off (modeled yields) and then absorbs its lease wait on
    the repair clock — foreground stream slots stay untouched."""
    admission = ShardedAdmission(
        AdmissionConfig(max_streams_total=8, lease_rate_per_s=100.0,
                        lease_burst=8), ["s0", "s1"])
    coord = make_coordinator(2, admission=admission)
    rep = ShardRepairer(coord, config=RepairConfig(backoff_s=1e-3))
    admission.lease_wait_s(0.0, 4, server_id="s0")   # drain s0's bucket
    coord.add_server("s2", fresh_server(), rebalance=True)
    assert rep.stats.batches_pulled > 0
    assert rep.stats.yields >= 1
    assert rep.stats.yield_s > 0.0
    assert rep.stats.throttle_wait_s > 0.0
    assert rep.stats.clock_s >= rep.stats.yield_s + rep.stats.throttle_wait_s
    assert admission.active_total() == 0             # no stream slots taken
    assert scan_signature(coord) == reference_signature()


def test_repair_open_bucket_never_waits():
    """Without a lease rate (open buckets) the background class runs at
    full speed: no yields, no waits."""
    admission = ShardedAdmission(AdmissionConfig(max_streams_total=8),
                                 ["s0", "s1"])
    coord = make_coordinator(2, admission=admission)
    rep = ShardRepairer(coord)
    coord.add_server("s2", fresh_server(), rebalance=True)
    assert rep.stats.batches_pulled > 0
    assert rep.stats.yields == 0
    assert rep.stats.throttle_wait_s == 0.0


# ------------------------------------------------------------- pool adopt


def test_pool_adopt_retains_slabs_permanently():
    fabric = Fabric(FabricConfig())
    pool = BufferPool(fabric)
    descs = (SegmentDesc(4096, "uint8", "values", 0),
             SegmentDesc(64, "int32", "offsets", 0))
    handle = pool.acquire(descs)
    pool.adopt(handle)
    assert pool.outstanding == 0                  # left the checkout ledger
    assert pool.free_bytes() == 0                 # but NOT back on free lists
    assert pool.stats.adopted == 2
    assert pool.stats.bytes_adopted == 4096 + 64
    assert pool.stats.bytes_resident == 4096 + 64  # still resident+registered
    assert fabric.registrations == 2
    with pytest.raises(KeyError):
        pool.release(handle)                      # adopted: no going back
    with pytest.raises(KeyError):
        pool.adopt(handle)


def test_pool_adopted_slabs_survive_budget_eviction():
    """Adopted slabs are shard storage: the LRU budget sweep may only evict
    free slabs, never adopted ones."""
    pool = BufferPool(max_bytes=8192)
    adopted = pool.acquire((SegmentDesc(4096, "uint8", "values", 0),))
    pool.adopt(adopted)
    kept = adopted.segments[0]
    kept[:] = 7
    # churn enough free slabs through the pool to force budget evictions
    for _ in range(4):
        h = pool.acquire((SegmentDesc(8192, "uint8", "values", 0),))
        pool.release(h)
    assert pool.stats.evictions >= 1
    assert (kept == 7).all()                      # adopted bytes untouched
    assert pool.stats.bytes_resident >= 4096
