"""Sharding rules + device transport + HLO analyzers (1-device runtime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import batch_from_arrays, schema
from repro.core.device_transport import batch_to_device, batch_to_device_packed
from repro.models import cache_pspecs, cache_spec, make_rules, param_shapes, param_specs
from repro.utils.hlo import collective_stats, shape_bytes
from repro.utils.hlo_cost import analyze


class FakeMesh:
    """Duck-typed stand-in for a (16,16) production mesh — rule/spec logic
    only consults shape/axis_names/size."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH16 = FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_rules_divisibility(arch):
    cfg = get_config(arch)
    rules = make_rules(cfg, MESH16)
    msize = 16
    if rules.get("heads"):
        assert cfg.eff_heads % msize == 0       # padded-head TP divisibility
    if rules.get("kv"):
        assert cfg.eff_kv % msize == 0
    if rules.get("head_dim"):
        assert cfg.resolved_head_dim % msize == 0
        assert cfg.eff_heads % msize != 0       # cascade only on fallback
    if rules.get("vocab"):
        assert cfg.padded_vocab % msize == 0
    # every arch must shard attention (directly or via padding) or be
    # attention-free
    assert cfg.attention_free or rules.get("heads") or rules.get("head_dim")
    # GQA grouping stays integral under padding
    if cfg.eff_kv:
        assert cfg.eff_heads % cfg.eff_kv == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_align(arch):
    """Every sharded dim must divide evenly — the compile-time guarantee."""
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    specs = param_specs(cfg, shapes, MESH16)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for sh, spec in zip(flat_shapes, flat_specs):
        for dim, axis in zip(sh.shape, tuple(spec) + (None,) * 9):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            n = 1
            for a in axes:
                n *= MESH16.shape[a]
            assert dim % n == 0, f"{arch}: dim {dim} not divisible by {n}"


@pytest.mark.parametrize("arch", ["deepseek-67b", "gemma-2b", "whisper-small"])
def test_cache_specs_align(arch):
    cfg = get_config(arch)
    cs = cache_spec(cfg, 128, 1024)
    specs = cache_pspecs(cfg, cs, MESH16)
    for sh, spec in zip(jax.tree.leaves(cs),
                        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, axis in zip(sh.shape, tuple(spec) + (None,) * 9):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            n = 1
            for a in axes:
                n *= MESH16.shape[a]
            assert dim % n == 0


def test_device_transport_parity(rng):
    """thallus path and packed path land identical column arrays."""
    sch = schema(("a", "float32"), ("b", "int32"))
    batch = batch_from_arrays(sch, [rng.standard_normal(256).astype(np.float32),
                                    rng.integers(0, 9, 256).astype(np.int32)])
    th = batch_to_device(batch)
    pk = batch_to_device_packed(batch)
    np.testing.assert_allclose(np.asarray(th["a"]), np.asarray(pk["a"]))
    np.testing.assert_array_equal(np.asarray(th["b"]), np.asarray(pk["b"]))


def test_shape_bytes():
    assert shape_bytes("bf16", "2,3") == 12
    assert shape_bytes("f32", "10") == 40
    assert shape_bytes("pred", "8") == 8


def test_hlo_cost_counts_loop_trips():
    """The whole point of the analyzer: a scanned dot counts x trip_count."""
    def step(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    w = jnp.zeros((5, 16, 16))
    x = jnp.zeros((4, 16))
    txt = jax.jit(step).lower(w, x).compile().as_text()
    cost = analyze(txt, 1)
    dot_flops = 2 * 4 * 16 * 16
    assert cost.flops >= 5 * dot_flops          # ×5 loop trips
    assert cost.flops < 20 * dot_flops


def test_collective_stats_parser():
    txt = """
  %all-gather.1 = bf16[16,4096]{1,0} all-gather(%p), replica_groups=[16,16]<=[256]
  %all-reduce.2 = f32[8,8]{1,0} all-reduce(%q), replica_groups={{0,1,2,3}}
"""
    stats = collective_stats(txt, 256)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1}
    ag = 15 / 16 * 16 * 4096 * 2
    ar = 2 * 3 / 4 * 64 * 4
    assert abs(stats.wire_bytes["all-gather"] - ag) < 1
    assert abs(stats.wire_bytes["all-reduce"] - ar) < 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_cells(arch):
    from repro.launch.dryrun_lib import input_specs
    cfg = get_config(arch)
    for shape in SHAPES.values():
        spec = input_specs(cfg, shape)
        assert "tokens" in spec
        if shape.kind == "decode":
            assert spec["tokens"].shape == (shape.global_batch, 1)
        elif cfg.family == "vlm":
            assert spec["tokens"].shape[1] == shape.seq_len - cfg.vlm.num_patches
        else:
            assert spec["tokens"].shape == (shape.global_batch, shape.seq_len)
