"""The repro.sched adaptive scheduler: work stealing (split at a lease
boundary, re-lease to the fastest idle replica, resume correctness), shared
tickets (coalescing, mid-flight join/cancel, multicast parity), and
lease-boundary preemption (park/resume round-trips restoring the admission
budget), plus their integration through the qos gateway, the loader, the
batcher, and the report tables."""
import numpy as np
import pytest
from conftest import make_coordinator, reference_batches

from repro.cluster import ClusterCoordinator, MultiStreamPuller
from repro.core import Fabric, ThallusServer
from repro.data import ThallusLoader, make_token_table
from repro.engine import Engine, make_numeric_table
from repro.qos import (AdmissionConfig, AdmissionController, ClientClass,
                       ScanGateway, ScanRequest, WeightedFairQueue)
from repro.sched import (AdaptiveScheduler, PreemptConfig, PreemptibleScan,
                         StealConfig, StealingPuller, TicketTable)

ROWS = 1 << 17
BATCH_ROWS = 1 << 13                     # -> 16 batches
SQL = "SELECT c0, c1 FROM t"
HEAVY_SQL = "SELECT c0, c1, c2, c3 FROM t"
TABLE = make_numeric_table("t", ROWS, 4, batch_rows=BATCH_ROWS)


def make_cluster(n, placement="shard", slow=None, slowdown=4.0,
                 admission=None):
    return make_coordinator(n, placement, table=TABLE, admission=admission,
                            slow=slow, slowdown=slowdown)


def _reference_batches(sql=SQL):
    return reference_batches(sql, table=TABLE)


def _assert_batches_equal(got, ref):
    assert len(got) == len(ref)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g.column("c0").values,
                                      r.column("c0").values)
        np.testing.assert_array_equal(g.column("c1").values,
                                      r.column("c1").values)


# ------------------------------------------------------------ work stealing


def test_steal_moves_straggler_tail_and_preserves_bytes():
    """The tentpole shape: one 4x-slow replica; stealing must fire, cut the
    modeled critical path, and deliver byte-identical global output."""
    coord = make_cluster(4, "replica", slow=3)
    base = MultiStreamPuller(coord, coord.plan(SQL, "/d"),
                             schedule="first_ready").run()

    coord = make_cluster(4, "replica", slow=3)
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig())
    got = {}
    stats = puller.run(lambda i, b: got.setdefault(i, []).append(b))
    assert stats.steals >= 1
    assert len(stats.streams) > 4            # thief streams appended
    assert stats.batches == base.batches and stats.bytes == base.bytes
    assert stats.modeled_critical_path_s < base.modeled_critical_path_s
    ev = stats.steal_events[0]
    assert ev.victim == "s3" and ev.thief != "s3"
    assert ev.num_batches >= 1 and ev.epoch_s > 0
    # stolen ranges stay disjoint+contiguous: sorting by start_batch
    # reproduces the solo scan exactly (steal-at-lease-boundary resume)
    order = sorted(range(len(puller.pullers)),
                   key=lambda i: puller.pullers[i].endpoint.start_batch)
    flat = [b for i in order for b in got.get(i, [])]
    _assert_batches_equal(flat, _reference_batches())


def test_steal_seeds_thief_start_epoch():
    """A stolen stream starts mid-scan: its start_s is the steal epoch, so
    the modeled critical path stays an honest makespan (never shorter than
    the epoch itself)."""
    coord = make_cluster(4, "replica", slow=3)
    puller = StealingPuller(coord, coord.plan(SQL, "/d"),
                            steal=StealConfig())
    stats = puller.run()
    assert stats.steals >= 1
    thieves = [s for s in stats.streams if s.start_s > 0]
    assert thieves
    assert stats.modeled_critical_path_s >= max(s.start_s for s in thieves)


def test_no_steal_on_shard_placement_or_balanced_fleet():
    # shard placement: nobody else holds the data — never steal
    coord = make_cluster(4, "shard", slow=3)
    stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                           steal=StealConfig()).run()
    assert stats.steals == 0
    # balanced replicas: nothing exceeds factor x median — never steal
    coord = make_cluster(4, "replica")
    stats = StealingPuller(coord, coord.plan(SQL, "/d"),
                           steal=StealConfig()).run()
    assert stats.steals == 0
    _assert_batches_equal(_reference_batches(), _reference_batches())


def test_steal_config_validation():
    with pytest.raises(ValueError):
        StealConfig(factor=0.5)
    with pytest.raises(ValueError):
        StealConfig(min_batches=0)


def test_gateway_reassembles_stolen_scan_in_order():
    """End to end through the gateway: stealing must not perturb global
    scan order (the reassembler sorts actual endpoint ranges, including
    stolen tails)."""
    coord = make_cluster(4, "replica", slow=3)
    gateway = ScanGateway(coord,
                          scheduler=AdaptiveScheduler(steal=StealConfig()))
    req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d"))
    gateway.run()
    result = gateway.result(req.request_id)
    assert result.cluster.steals >= 1
    assert gateway.stats.steals >= 1
    _assert_batches_equal(result.batches, _reference_batches())


# ----------------------------------------------------------- shared tickets


def test_ticket_table_lifecycle():
    table = TicketTable()
    key = table.key_for(SQL, "/d")
    table.subscribe(key, 1)
    table.subscribe(key, 2)
    table.subscribe(key, 2)                  # idempotent
    assert table.lookup(key).subscribers == [1, 2]
    assert table.redeem(key, 2) is None      # nothing published yet
    table.publish(key, 1, ["payload"], cluster=None)
    ticket = table.redeem(key, 2)
    assert ticket is not None and ticket.batches == ["payload"]
    assert ticket.primary_id == 1 and ticket.subscribers == []
    assert table.stats.hits == 1 and table.stats.misses == 1
    # cancel of the last subscriber of an UNexecuted ticket drops it
    key2 = table.key_for(SQL, "/e")
    table.subscribe(key2, 3)
    table.cancel(key2, 3)
    assert table.lookup(key2) is None
    assert table.stats.cancels == 1
    # begin_drain forgets published results (stale across drains)
    table.begin_drain()
    assert table.lookup(key) is None


def test_gateway_coalesces_identical_requests():
    """N identical queued queries -> one fan-out + N-1 multicast grants,
    all byte-identical, with per-subscriber class attribution."""
    gateway = ScanGateway(make_cluster(4, "shard"),
                          scheduler=AdaptiveScheduler(tickets=TicketTable()))
    reqs = [gateway.submit(ScanRequest(f"c{i}", "interactive", SQL, "/d"))
            for i in range(4)]
    other = gateway.submit(ScanRequest("x", "interactive", HEAVY_SQL, "/d"))
    gateway.run()
    assert len(gateway.stats.cluster) == 2   # SQL fan-out + HEAVY_SQL
    assert gateway.stats.ticket_hits == 3
    ref = _reference_batches()
    shared = []
    for r in reqs:
        result = gateway.result(r.request_id)
        _assert_batches_equal(result.batches, ref)
        shared.append(result.shared)
        assert result.service_s == 0.0 or not result.shared
    assert sorted(shared) == [False, True, True, True]
    assert gateway.result(other.request_id) is not None
    # multicast batches are copies, not views of the primary's result
    primary = next(gateway.result(r.request_id) for r in reqs
                   if not gateway.result(r.request_id).shared)
    hit = next(gateway.result(r.request_id) for r in reqs
               if gateway.result(r.request_id).shared)
    assert (hit.batches[0].column("c0").values is not
            primary.batches[0].column("c0").values)
    # attribution: hits count granted batches for their class
    cstats = gateway.stats.klass("interactive")
    assert cstats.granted == 5 and cstats.ticket_hits == 3
    assert cstats.batches == 5 * len(ref)


def test_ticket_subscriber_cancel_and_midflight_join():
    """A subscriber shed at dequeue cancels off the ticket without hurting
    the others; a request joining after the primary was queued (mid-flight)
    still coalesces."""
    gateway = ScanGateway(
        make_cluster(2, "shard"),
        classes=[ClientClass("interactive", 4.0), ClientClass("batch", 1.0)],
        scheduler=AdaptiveScheduler(tickets=TicketTable()),
        est_service_s_per_cost=1e-7)    # optimistic: submit lets doomed in
    heavy = gateway.submit(ScanRequest("h", "batch", HEAVY_SQL, "/d",
                                       cost_hint=8.0))
    # doomed joins the SQL ticket but its deadline passes the (optimistic)
    # submit estimate and expires while queued behind heavy -> cancel at
    # dequeue
    doomed = gateway.submit(ScanRequest("d", "batch", SQL, "/d",
                                        cost_hint=1.0, deadline_s=1e-5))
    primary = gateway.submit(ScanRequest("p", "interactive", SQL, "/d"))
    joiner = gateway.submit(ScanRequest("j", "interactive", SQL, "/d"))
    assert doomed is not None                # survived the submit estimate
    gateway.run()
    tickets = gateway.scheduler.tickets
    assert tickets.stats.cancels == 1
    assert gateway.stats.klass("batch").shed == 1
    assert gateway.stats.ticket_hits == 1    # joiner rode primary's ticket
    ref = _reference_batches()
    _assert_batches_equal(gateway.result(primary.request_id).batches, ref)
    _assert_batches_equal(gateway.result(joiner.request_id).batches, ref)
    assert gateway.result(heavy.request_id) is not None
    assert gateway.result(doomed.request_id) is None


def test_start_batch_offsets_resume_in_global_order():
    """ScanRequest.start_batch is the ticket key's third leg and the
    loader's resume cursor: replica plans push it down, shard plans trim."""
    ref = _reference_batches()
    for placement in ("shard", "replica"):
        gateway = ScanGateway(make_cluster(2, placement))
        req = gateway.submit(ScanRequest("c", "interactive", SQL, "/d",
                                         start_batch=5))
        gateway.run()
        _assert_batches_equal(gateway.result(req.request_id).batches,
                              ref[5:])
    # replica push-down skips the transport; shard trim cannot
    assert gateway.stats.cluster[0].batches == len(ref) - 5


# --------------------------------------------------------------- preemption


def test_preemptible_scan_round_trip_restores_admission_budget():
    """park releases every stream slot back to the admission budget;
    resume re-acquires them; the finished scan is byte-identical."""
    adm = AdmissionController(AdmissionConfig(max_streams_per_client=8))
    coord = make_cluster(2, "shard", admission=adm)
    plan = coord.plan(SQL, "/d")
    scan = PreemptibleScan(MultiStreamPuller(coord, plan, client_id="c"))
    assert adm.active_streams("c") == 2
    scan.run_round()
    scan.park()
    assert scan.parked and adm.active_streams("c") == 0   # budget restored
    with pytest.raises(RuntimeError):
        scan.run_round()                     # parked streams refuse to pull
    scan.resume()
    assert adm.active_streams("c") == 2      # slots re-acquired
    while not scan.done:
        scan.run_round()
    assert adm.active_streams("c") == 0      # drained leases released
    from repro.qos.gateway import reassemble
    _assert_batches_equal(reassemble(plan, scan.per_stream),
                          _reference_batches())
    assert scan.park_count == 1
    assert sum(s.parks for s in scan.stats().streams) == 2


def test_preempt_resume_backpressure_reparks_cleanly():
    adm = AdmissionController(AdmissionConfig(max_streams_per_client=2))
    coord = make_cluster(2, "shard", admission=adm)
    scan = PreemptibleScan(MultiStreamPuller(coord, coord.plan(SQL, "/d"),
                                             client_id="c"))
    scan.run_round()
    scan.park()
    adm.acquire_stream("c")                  # someone else took a slot
    from repro.qos import Backpressure
    with pytest.raises(Backpressure):
        scan.resume()
    assert scan.parked                       # nothing leaked half-open
    assert adm.active_streams("c") == 1      # only the foreign slot remains
    adm.release_stream("c")
    scan.resume()
    while not scan.done:
        scan.run_round()
    assert adm.active_streams("c") == 0


def test_gateway_preempts_batch_for_interactive_arrival():
    """The tentpole flow: a heavy batch scan starts alone; an interactive
    request arrives mid-service on the modeled clock; the batch parks at a
    lease boundary, the lookup runs, the batch resumes and completes
    byte-identically."""
    gateway = ScanGateway(make_cluster(4, "shard"),
                          scheduler=AdaptiveScheduler(preempt=PreemptConfig()))
    heavy = gateway.submit(ScanRequest("h", "batch", HEAVY_SQL, "/d",
                                       cost_hint=8.0))
    ui = gateway.submit(ScanRequest("ui", "interactive", SQL, "/d",
                                    arrival_s=1e-5))
    results = gateway.run()
    assert len(results) == 2
    hres = gateway.result(heavy.request_id)
    assert hres.preemptions >= 1
    assert gateway.stats.preemptions >= 1
    assert gateway.stats.klass("batch").preemptions >= 1
    _assert_batches_equal(hres.batches, _reference_batches(HEAVY_SQL))
    ures = gateway.result(ui.request_id)
    _assert_batches_equal(ures.batches, _reference_batches())
    # the lookup ran during the batch scan's parked window: it was granted
    # before the batch finished its (preempted) service
    assert ures.grant_latency_s < hres.service_s + hres.grant_latency_s


def test_plain_gateway_ignores_future_arrivals():
    """Regression: without a preemption-aware scheduler the gateway's plain
    pop ignores arrival times — popping a future-arrival request must not
    drag the clock forward and spuriously shed co-queued requests."""
    gateway = ScanGateway(make_cluster(2, "shard"))
    b = gateway.submit(ScanRequest("b", "batch", SQL, "/d", deadline_s=5.0))
    gateway.submit(ScanRequest("a", "interactive", SQL, "/d",
                               arrival_s=10.0))
    gateway.run()
    assert gateway.stats.shed == 0
    assert gateway.result(b.request_id) is not None


def test_preemptible_service_respects_stream_quota():
    """Regression: the preemptible path must bill the same quota-capped
    makespan as the one-shot path (streams serialize onto quota lanes)."""
    results = {}
    for scheduler in (None, AdaptiveScheduler(preempt=PreemptConfig())):
        adm = AdmissionController(AdmissionConfig(max_streams_per_client=2))
        gateway = ScanGateway(make_cluster(4, "shard"), admission=adm,
                              scheduler=scheduler)
        req = gateway.submit(ScanRequest("h", "batch", SQL, "/d"))
        gateway.run()
        results[scheduler is None] = gateway.result(req.request_id)
    plain, preemptible = results[True], results[False]
    # same 4 streams serialized onto 2 lanes: service within noise (the
    # clock components include measured alloc time, so compare loosely)
    assert preemptible.service_s >= 0.5 * plain.service_s


def test_loader_gateway_transport_evicts_consumed_results():
    coord = _token_cluster()
    gateway = ScanGateway(coord)
    loader = ThallusLoader([], "SELECT tokens FROM tok", "/tok",
                           seq_len=32, batch_seqs=8, transport="gateway",
                           gateway=gateway)
    assert len(list(loader)) == 8
    assert gateway.results == {}             # epoch result not retained


def test_wfq_arrival_aware_pop_and_preemptor_check():
    q = WeightedFairQueue([ClientClass("ui", 4.0), ClientClass("bg", 1.0)])

    class Item:
        def __init__(self, name, klass, arrival_s):
            self.name, self.klass, self.arrival_s = name, klass, arrival_s

    late_ui = Item("ui0", "ui", 5.0)
    early_bg = Item("bg0", "bg", 0.0)
    q.push(late_ui, "ui", cost=1.0)
    q.push(early_bg, "bg", cost=1.0)
    assert not q.has_preemptor("bg", now_s=1.0)   # ui hasn't arrived yet
    assert q.has_preemptor("bg", now_s=5.0)
    assert not q.has_preemptor("ui", now_s=9.0)   # nothing outweighs ui
    # arrival-aware pop: at t=1 only bg has arrived, despite ui's lower tag
    assert q.pop(1.0) is early_bg
    # nothing arrived: fall back to global min (caller advances its clock)
    assert q.pop(1.0) is late_ui
    # idle fallback serves the EARLIEST arrival, not the smallest tag —
    # jumping to a later arrival would idle past (and shed) the earlier one
    soon_bg = Item("bg1", "bg", 2.0)
    later_ui = Item("ui1", "ui", 9.0)        # lower tag (weight 4)...
    q.push(later_ui, "ui", cost=1.0)
    q.push(soon_bg, "bg", cost=1.0)
    assert q.pop(0.0) is soon_bg             # ...but bg1 arrives first


def test_preemption_composes_with_stealing_and_tickets():
    """All three mechanisms on at once (AdaptiveScheduler.default): a heavy
    batch scan is preempted by two identical interactive arrivals; the
    first lookup executes (its straggler is steal-eligible), the second
    rides its ticket, then the batch scan resumes and completes."""
    coord = make_cluster(4, "replica", slow=3)
    gateway = ScanGateway(coord, scheduler=AdaptiveScheduler.default())
    heavy = gateway.submit(ScanRequest("h", "batch", HEAVY_SQL, "/d",
                                       cost_hint=8.0))
    ui1 = gateway.submit(ScanRequest("u1", "interactive", SQL, "/d",
                                     arrival_s=1e-5))
    ui2 = gateway.submit(ScanRequest("u2", "interactive", SQL, "/d",
                                     arrival_s=1e-5))
    gateway.run()
    ref = _reference_batches()
    for req in (ui1, ui2):
        _assert_batches_equal(gateway.result(req.request_id).batches, ref)
    _assert_batches_equal(gateway.result(heavy.request_id).batches,
                          _reference_batches(HEAVY_SQL))
    assert gateway.stats.ticket_hits == 1    # ui2 rode ui1's ticket
    assert gateway.stats.preemptions >= 1    # heavy parked for the lookups
    assert gateway.result(heavy.request_id).preemptions >= 1
    assert gateway.stats.granted == 3


# ------------------------------------------------------- caller surfacing


def _token_cluster():
    table = make_token_table("tok", num_seqs=64, seq_len=32, vocab_size=128,
                             seqs_per_batch=16)
    coord = ClusterCoordinator()
    for i in range(2):
        eng = Engine()
        eng.register("/tok", table)
        coord.add_server(f"s{i}", ThallusServer(eng, Fabric()))
    coord.place_replicas("/tok", table)
    return coord


def test_loader_gateway_transport_surfaces_sharing():
    coord = _token_cluster()
    gateway = ScanGateway(coord,
                          scheduler=AdaptiveScheduler(tickets=TicketTable()))
    # another tenant already queued the identical scan; the loader's
    # request coalesces onto its ticket and is served by multicast
    gateway.submit(ScanRequest("tenant", "interactive",
                               "SELECT tokens FROM tok", "/tok"))
    loader = ThallusLoader([], "SELECT tokens FROM tok", "/tok",
                           seq_len=32, batch_seqs=8, transport="gateway",
                           gateway=gateway, client_id="trainer")
    chunks = list(loader)
    assert len(chunks) == 8                  # 64 seqs / 8 per chunk
    assert loader.stats.shared_scans == 1
    assert loader.stats.batches == 4
    # resume cursor is the global offset, usable as request.start_batch
    assert loader.state_dict()["batch_offset"] == 4
    solo = ThallusLoader([coord.server("s0")], "SELECT tokens FROM tok",
                         "/tok", seq_len=32, batch_seqs=8)
    for got, want in zip(chunks, solo):
        np.testing.assert_array_equal(got["tokens"], want["tokens"])


def test_loader_gateway_transport_surfaces_preemption():
    coord = _token_cluster()
    gateway = ScanGateway(coord,
                          scheduler=AdaptiveScheduler(preempt=PreemptConfig()))
    loader = ThallusLoader([], "SELECT tokens FROM tok", "/tok",
                           seq_len=32, batch_seqs=8, transport="gateway",
                           gateway=gateway, klass="batch")
    # an interactive lookup arrives while the loader's scan is in flight
    gateway.submit(ScanRequest("ui", "interactive",
                               "SELECT seq_id FROM tok", "/tok",
                               arrival_s=1e-6))
    chunks = list(loader)
    assert len(chunks) == 8
    assert loader.stats.preemptions >= 1


def test_batcher_ingest_scan_reports_sharing():
    import jax.numpy as jnp
    from repro.serving import Batcher

    coord = _token_cluster()
    gateway = ScanGateway(coord,
                          scheduler=AdaptiveScheduler(tickets=TicketTable()))

    def prefill(tokens):
        B, S = tokens.shape
        return jnp.ones((B, S, 64)), {"k": jnp.zeros((B, 1, S, 1))}

    def decode(cache, tokens, position):
        return jnp.ones((tokens.shape[0], 1, 64)), cache

    b1 = Batcher(prefill, decode, batch_size=16)
    b2 = Batcher(prefill, decode, batch_size=16)
    r1 = b1.submit_scan(gateway, "SELECT seq_id, tokens FROM tok", "/tok")
    r2 = b2.submit_scan(gateway, "SELECT seq_id, tokens FROM tok", "/tok")
    gateway.run()
    n1, shared1 = b1.ingest_scan(gateway, r1, seq_len=8)
    n2, shared2 = b2.ingest_scan(gateway, r2, seq_len=8)
    assert n1 == n2 and n1 > 0
    assert sorted([shared1, shared2]) == [False, True]
    assert gateway.stats.ticket_hits == 1


def test_sched_table_renders():
    from repro.utils.report import sched_table
    gateway = ScanGateway(make_cluster(2, "shard"),
                          scheduler=AdaptiveScheduler.default())
    for i in range(2):
        gateway.submit(ScanRequest(f"c{i}", "interactive", SQL, "/d"))
    gateway.run()
    out = sched_table(gateway.stats)
    assert "ticket hits" in out and "preemptions" in out
    assert "steals=" in out and "hit_rate=0.50" in out
