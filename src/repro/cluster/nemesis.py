"""Nemesis: a deterministic, scheduled fault injector for the cluster.

Modeled on YDB's nemesis tooling (a tracker of *active* faults driven by a
schedule, injected while a side workload keeps traffic flowing): each
:class:`FaultSpec` names one fault — ``kill`` (the server process dies and
its reader map with it), ``slow`` (its fabric loses bandwidth), or
``partition`` (its admission shard stops reconciling) — with the beat it
starts and, optionally, the beat it heals. :meth:`Nemesis.beat` is called
once per driver beat and injects/heals exactly what the schedule says, so
the same ``(seed, FabricConfig, schedule)`` replays the identical fault
timeline — the PR 8 byte-identical discipline extended to faults.

The nemesis is the *outside world*: it holds direct references to the
server objects captured at construction, so it can crash, heal, or slow a
server regardless of whether the membership controller currently has it
registered. Everything it does is reported through ``coordinator.notify``
(``nemesis.inject`` / ``nemesis.heal``) so the postmortem shows the fault
next to the recovery it caused.
"""
from __future__ import annotations

import dataclasses
import random

from .coordinator import ClusterCoordinator

KINDS = ("kill", "slow", "partition")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``stop_beat=None`` means the schedule never heals it (a permanent
    fault). ``factor`` applies to ``slow`` (bandwidth divisor);
    ``after_batches`` applies to ``kill`` (die only after shipping that
    many more batches — a mid-lease death, the case lease migration must
    survive; ``0`` dies immediately).
    """

    kind: str
    server_id: str
    start_beat: int
    stop_beat: int | None = None
    factor: float = 4.0
    after_batches: int = 0

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.stop_beat is not None and self.stop_beat <= self.start_beat:
            raise ValueError("stop_beat must follow start_beat")


class Nemesis:
    """Inject/heal the scheduled faults, track the active set."""

    def __init__(self, coordinator: ClusterCoordinator,
                 schedule: list[FaultSpec] | tuple[FaultSpec, ...],
                 admission=None) -> None:
        self.coordinator = coordinator
        self.schedule = tuple(schedule)
        self.admission = admission
        # the outside world's view of the fleet: survives evictions
        self._servers = dict(coordinator.servers)
        self._saved_fabric: dict[str, object] = {}
        self.active: dict[tuple[str, str], FaultSpec] = {}
        # (beat, action, kind, server_id) — the determinism witness
        self.timeline: list[tuple[int, str, str, str]] = []

    def beat(self, beat: int, now_s: float) -> list[FaultSpec]:
        """Apply the schedule for one beat; returns the specs acted on."""
        acted: list[FaultSpec] = []
        for spec in self.schedule:
            if spec.stop_beat is not None and spec.stop_beat == beat:
                self._heal(spec, beat, now_s)
                acted.append(spec)
        for spec in self.schedule:
            if spec.start_beat == beat:
                self._inject(spec, beat, now_s)
                acted.append(spec)
        return acted

    # ------------------------------------------------------------- inject
    def _inject(self, spec: FaultSpec, beat: int, now_s: float) -> None:
        server = self._servers[spec.server_id]
        if spec.kind == "kill":
            server.crash(after_batches=spec.after_batches)
        elif spec.kind == "slow":
            fabric = server.fabric
            if spec.server_id not in self._saved_fabric:
                self._saved_fabric[spec.server_id] = fabric.config
            base = self._saved_fabric[spec.server_id]
            fabric.config = dataclasses.replace(
                base, rdma_bw=base.rdma_bw / spec.factor,
                rpc_bw=base.rpc_bw / spec.factor)
        else:  # partition
            if (self.admission is not None
                    and spec.server_id in getattr(self.admission,
                                                  "shards", {})):
                self.admission.partition(spec.server_id)
        self.active[(spec.kind, spec.server_id)] = spec
        self.timeline.append((beat, "inject", spec.kind, spec.server_id))
        self.coordinator.notify("nemesis.inject", server_id=spec.server_id,
                                now_s=now_s, fault=spec.kind,
                                stop_beat=spec.stop_beat)

    # --------------------------------------------------------------- heal
    def _heal(self, spec: FaultSpec, beat: int, now_s: float) -> None:
        key = (spec.kind, spec.server_id)
        if key not in self.active:
            return
        server = self._servers[spec.server_id]
        if spec.kind == "kill":
            server.restore()
        elif spec.kind == "slow":
            saved = self._saved_fabric.pop(spec.server_id, None)
            if saved is not None:
                server.fabric.config = saved
        else:  # partition
            if self.admission is not None:
                rejoin = getattr(self.admission, "rejoin", None)
                if rejoin is not None:
                    rejoin(spec.server_id)
        del self.active[key]
        self.timeline.append((beat, "heal", spec.kind, spec.server_id))
        self.coordinator.notify("nemesis.heal", server_id=spec.server_id,
                                now_s=now_s, fault=spec.kind)


def seeded_schedule(seed: int, server_ids: list[str] | tuple[str, ...],
                    beats: int, faults: int = 3,
                    kinds: tuple[str, ...] = KINDS,
                    min_duration: int = 2,
                    max_duration: int = 4) -> tuple[FaultSpec, ...]:
    """A deterministic random schedule: ``faults`` specs drawn from
    ``seed``, each targeting one server for a bounded window inside
    ``[1, beats)``. Same arguments → same schedule, always."""
    rng = random.Random(seed)
    ids = sorted(server_ids)
    specs = []
    for _ in range(faults):
        kind = rng.choice(list(kinds))
        sid = rng.choice(ids)
        duration = rng.randint(min_duration, max_duration)
        start = rng.randint(1, max(1, beats - duration - 1))
        specs.append(FaultSpec(kind, sid, start, stop_beat=start + duration))
    return tuple(specs)
