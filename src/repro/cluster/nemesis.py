"""Nemesis: a deterministic, scheduled fault injector for the cluster.

Modeled on YDB's nemesis tooling (a tracker of *active* faults driven by a
schedule, injected while a side workload keeps traffic flowing): each
:class:`FaultSpec` names one fault — ``kill`` (the server process dies and
its reader map with it), ``slow`` (its fabric loses bandwidth), or
``partition`` (its admission shard stops reconciling) — with the beat it
starts and, optionally, the beat it heals. :meth:`Nemesis.beat` is called
once per driver beat and injects/heals exactly what the schedule says, so
the same ``(seed, FabricConfig, schedule)`` replays the identical fault
timeline — the PR 8 byte-identical discipline extended to faults.

The nemesis is the *outside world*: targets resolve through the
coordinator's live registry first (so servers that join after construction
are fair game) with a construction-time snapshot as fallback, so it can
crash, heal, or slow a server regardless of whether the membership
controller currently has it registered. Everything it does is reported through ``coordinator.notify``
(``nemesis.inject`` / ``nemesis.heal``) so the postmortem shows the fault
next to the recovery it caused.
"""
from __future__ import annotations

import dataclasses
import random

from .coordinator import ClusterCoordinator

KINDS = ("kill", "slow", "partition")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``stop_beat=None`` means the schedule never heals it (a permanent
    fault). ``factor`` applies to ``slow`` (bandwidth divisor);
    ``after_batches`` applies to ``kill`` (die only after shipping that
    many more batches — a mid-lease death, the case lease migration must
    survive; ``0`` dies immediately).
    """

    kind: str
    server_id: str
    start_beat: int
    stop_beat: int | None = None
    factor: float = 4.0
    after_batches: int = 0

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.stop_beat is not None and self.stop_beat <= self.start_beat:
            raise ValueError("stop_beat must follow start_beat")


class Nemesis:
    """Inject/heal the scheduled faults, track the active set."""

    def __init__(self, coordinator: ClusterCoordinator,
                 schedule: list[FaultSpec] | tuple[FaultSpec, ...],
                 admission=None) -> None:
        self.coordinator = coordinator
        self.schedule = tuple(schedule)
        self.admission = admission
        # the outside world's view of the fleet: a fallback for servers the
        # membership layer has evicted. The coordinator's live registry is
        # consulted first, so post-construction joiners are targetable too.
        self._servers = dict(coordinator.servers)
        self._saved_fabric: dict[str, object] = {}
        # overlapping slow faults COMPOUND: every active factor per server,
        # applied as a product over the saved base config
        self._slow_factors: dict[str, list[float]] = {}
        # live injections per (kind, server_id): kill/partition heal their
        # server-level effect only when the LAST overlapping window closes
        self._refcount: dict[tuple[str, str], int] = {}
        # active faults keyed by spec (not (kind, sid)), so two overlapping
        # windows on one server track — and heal — independently
        self.active: dict[FaultSpec, int] = {}
        # (beat, action, kind, server_id) — the determinism witness
        self.timeline: list[tuple[int, str, str, str]] = []

    def beat(self, beat: int, now_s: float) -> list[FaultSpec]:
        """Apply the schedule for one beat; returns the specs acted on."""
        acted: list[FaultSpec] = []
        for spec in self.schedule:
            if spec.stop_beat is not None and spec.stop_beat == beat:
                self._heal(spec, beat, now_s)
                acted.append(spec)
        for spec in self.schedule:
            if spec.start_beat == beat:
                self._inject(spec, beat, now_s)
                acted.append(spec)
        return acted

    def _server(self, server_id: str):
        """Resolve a target: the coordinator's live view first (so joiners
        added after construction are reachable), then the construction
        snapshot (so evicted servers stay crashable/healable)."""
        server = self.coordinator.servers.get(server_id)
        if server is not None:
            self._servers[server_id] = server     # keep the fallback fresh
            return server
        if server_id in self._servers:
            return self._servers[server_id]
        raise KeyError(f"nemesis has never seen server {server_id!r}")

    def _apply_slow(self, server, server_id: str) -> None:
        """(Re)apply the compounded product of every active slow factor."""
        base = self._saved_fabric[server_id]
        factor = 1.0
        for f in self._slow_factors[server_id]:
            factor *= f
        server.fabric.config = dataclasses.replace(
            base, rdma_bw=base.rdma_bw / factor,
            rpc_bw=base.rpc_bw / factor)

    # ------------------------------------------------------------- inject
    def _inject(self, spec: FaultSpec, beat: int, now_s: float) -> None:
        server = self._server(spec.server_id)
        sid = spec.server_id
        key = (spec.kind, sid)
        if spec.kind == "kill":
            server.crash(after_batches=spec.after_batches)
        elif spec.kind == "slow":
            if sid not in self._saved_fabric:
                self._saved_fabric[sid] = server.fabric.config
            self._slow_factors.setdefault(sid, []).append(spec.factor)
            self._apply_slow(server, sid)
        else:  # partition
            if (self.admission is None
                    or sid not in getattr(self.admission, "shards", {})):
                # the shard is absent (absorbed by an evict, or no sharded
                # controller at all): nothing was injected, so nothing is
                # recorded — no phantom faults in the active set/timeline
                return
            if self._refcount.get(key, 0) == 0:
                self.admission.partition(sid)
        self._refcount[key] = self._refcount.get(key, 0) + 1
        self.active[spec] = self.active.get(spec, 0) + 1
        self.timeline.append((beat, "inject", spec.kind, sid))
        self.coordinator.notify("nemesis.inject", server_id=sid,
                                now_s=now_s, fault=spec.kind,
                                stop_beat=spec.stop_beat)

    # --------------------------------------------------------------- heal
    def _heal(self, spec: FaultSpec, beat: int, now_s: float) -> None:
        if self.active.get(spec, 0) <= 0:
            return
        sid = spec.server_id
        key = (spec.kind, sid)
        server = self._server(sid)
        remaining = self._refcount.get(key, 1) - 1
        if spec.kind == "kill":
            if remaining <= 0:
                server.restore()
        elif spec.kind == "slow":
            factors = self._slow_factors.get(sid, [])
            try:
                factors.remove(spec.factor)
            except ValueError:
                pass
            if factors:
                self._apply_slow(server, sid)    # others still in force
            else:
                self._slow_factors.pop(sid, None)
                saved = self._saved_fabric.pop(sid, None)
                if saved is not None:
                    server.fabric.config = saved
        else:  # partition
            if remaining <= 0 and self.admission is not None:
                rejoin = getattr(self.admission, "rejoin", None)
                if (rejoin is not None
                        and sid in getattr(self.admission, "shards", {})):
                    rejoin(sid)
        self._refcount[key] = max(0, remaining)
        self.active[spec] -= 1
        if self.active[spec] <= 0:
            del self.active[spec]
        self.timeline.append((beat, "heal", spec.kind, sid))
        self.coordinator.notify("nemesis.heal", server_id=sid,
                                now_s=now_s, fault=spec.kind)


def seeded_schedule(seed: int, server_ids: list[str] | tuple[str, ...],
                    beats: int, faults: int = 3,
                    kinds: tuple[str, ...] = KINDS,
                    min_duration: int = 2,
                    max_duration: int = 4) -> tuple[FaultSpec, ...]:
    """A deterministic random schedule: ``faults`` specs drawn from
    ``seed``, each targeting one server for a bounded window whose
    ``stop_beat`` never exceeds ``beats`` — a fault the run cannot heal
    would silently become permanent. Same arguments → same schedule,
    always (the clamp keeps the draw sequence identical, so seeds that
    already fit produce the exact schedules they always did)."""
    if beats < min_duration + 1:
        raise ValueError(
            f"beats={beats} cannot fit a fault of min_duration="
            f"{min_duration} (faults start at beat 1)")
    rng = random.Random(seed)
    ids = sorted(server_ids)
    specs = []
    for _ in range(faults):
        kind = rng.choice(list(kinds))
        sid = rng.choice(ids)
        duration = rng.randint(min_duration, max_duration)
        start = rng.randint(1, max(1, beats - duration - 1))
        duration = min(duration, beats - start)   # clamp to the window
        specs.append(FaultSpec(kind, sid, start, stop_beat=start + duration))
    return tuple(specs)
