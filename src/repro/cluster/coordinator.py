"""Cluster coordinator: server/endpoint registry, dataset placement, lease
reclamation, and stream resume.

The coordinator is the control-plane brain the dataplane modules lean on:

* **registry** — server_id → :class:`ThallusServer`, plus a record of which
  datasets live where and how (``shard`` vs ``replica`` placement);
* **placement** — :meth:`place_shards` splits a table's batches round-robin
  across servers under one dataset path (disjoint shards);
  :meth:`place_replicas` registers a full copy everywhere;
* **planning** — :meth:`plan` delegates to :func:`repro.cluster.plan.plan_scan`
  with the recorded placement;
* **lease lifecycle** — :meth:`open_stream` / :meth:`resume_stream` /
  :meth:`close_stream` wrap ``init_scan``/``finalize``, and
  :meth:`reclaim_stale` sweeps every server's reader map (activity-based, so
  live streams survive the sweep);
* **admission** — an optional :class:`repro.qos.AdmissionController` (duck
  typed, so there is no cluster→qos import) gates every lease grant:
  ``open_stream`` acquires a per-client stream slot (raising
  ``qos.Backpressure`` at the quota or over the memory budget) and
  ``close_stream`` releases it. Admission checks are routed **per server**:
  the endpoint's ``server_id`` rides along on every acquire/release, so a
  :class:`repro.qos.ShardedAdmission` meters each lease against that
  server's own quota shard (a centralized controller simply ignores the
  routing hint). The qos ``ScanGateway`` meters at request granularity
  instead, so a gateway's coordinator runs without one;
* **observability funnel** — optional ``recorder`` (an
  ``obs.FlightRecorder``) and ``health`` (an ``obs.HealthMonitor``), both
  duck-typed. Every layer above and below reports its decisions through
  :meth:`notify` (steal/decline, park/resume, shed, stream fault, ...) so
  one attribute check on the coordinator fans the event out to the flight
  recorder ring and the health monitor's window counters; :meth:`heartbeat`
  advances the health state machine in modeled time. Plain deployments set
  neither and pay two ``None`` checks per event.
"""
from __future__ import annotations

import dataclasses

from ..core.protocol import ScanHandle, ThallusServer
from ..engine.table import Table
from .plan import Endpoint, ScanPlan, plan_scan


@dataclasses.dataclass
class _Placement:
    mode: str                      # "shard" | "replica"
    server_ids: tuple[str, ...]


class ClusterCoordinator:
    """Registry + lease lifecycle for a set of Thallus servers."""

    def __init__(self, admission=None, recorder=None, health=None) -> None:
        self.servers: dict[str, ThallusServer] = {}
        self.admission = admission
        self.recorder = recorder       # obs.FlightRecorder (duck-typed)
        self.health = health           # obs.HealthMonitor (duck-typed)
        self._placements: dict[str, _Placement] = {}

    # ------------------------------------------------- observability funnel
    def notify(self, kind: str, server_id: str = "", now_s: float = 0.0,
               **attrs) -> None:
        """Report one structured decision (``steal.decline``,
        ``stream.fault``, ``qos.shed``, ...) to the attached flight
        recorder and health monitor. A no-op when neither is attached."""
        if self.recorder is not None:
            self.recorder.record(kind, now_s=now_s, server_id=server_id,
                                 **attrs)
        if self.health is not None:
            observe = getattr(self.health, "observe_event", None)
            if observe is not None:
                observe(kind, server_id, now_s)

    def heartbeat(self, now_s: float) -> list:
        """Advance the attached health monitor one heartbeat on the modeled
        clock; returns the health transitions it produced ([] when no
        monitor is attached)."""
        if self.health is None:
            return []
        return self.health.heartbeat(now_s)

    # ------------------------------------------------------------ registry
    def add_server(self, server_id: str, server: ThallusServer) -> None:
        if server_id in self.servers:
            raise ValueError(f"server id {server_id!r} already registered")
        self.servers[server_id] = server

    def server(self, server_id: str) -> ThallusServer:
        if server_id not in self.servers:
            raise KeyError(f"unknown server {server_id!r}")
        return self.servers[server_id]

    def hosts(self, dataset: str) -> dict[str, ThallusServer]:
        """Which servers host ``dataset``. Uses the recorded placement when
        one exists, otherwise falls back to probing server catalogs."""
        placement = self._placements.get(dataset)
        if placement is not None:
            return {sid: self.servers[sid] for sid in placement.server_ids}
        found = {}
        for sid, server in self.servers.items():
            catalog = getattr(server.engine, "catalog", None)
            if catalog is not None and dataset in catalog:
                found[sid] = server
        return found

    def placement_mode(self, dataset: str) -> str:
        placement = self._placements.get(dataset)
        return placement.mode if placement is not None else "shard"

    # ----------------------------------------------------------- placement
    def place_shards(self, dataset: str, table: Table,
                     server_ids: list[str] | None = None) -> None:
        """Split ``table``'s batches round-robin into disjoint shards, one
        per server, all registered under the same dataset path."""
        ids = sorted(server_ids or self.servers)
        if not ids:
            raise ValueError("no servers to place shards on")
        for i, sid in enumerate(ids):
            shard = Table(table.name, table.schema,
                          batches=table.batches[i::len(ids)])
            self.server(sid).engine.register(dataset, shard)
        self._placements[dataset] = _Placement("shard", tuple(ids))

    def place_replicas(self, dataset: str, table: Table,
                       server_ids: list[str] | None = None) -> None:
        """Register a full copy of ``table`` on every server."""
        ids = sorted(server_ids or self.servers)
        if not ids:
            raise ValueError("no servers to place replicas on")
        for sid in ids:
            self.server(sid).engine.register(dataset, table)
        self._placements[dataset] = _Placement("replica", tuple(ids))

    # ------------------------------------------------------------ planning
    def plan(self, sql: str, dataset: str,
             num_streams: int | None = None,
             placement: str | None = None) -> ScanPlan:
        hosts = self.hosts(dataset)
        if not hosts:
            raise KeyError(f"no server hosts dataset {dataset!r}")
        mode = placement or self.placement_mode(dataset)
        return plan_scan(sql, dataset, hosts, placement=mode,
                         num_streams=num_streams)

    # ------------------------------------------------- stream lease lifecycle
    def open_stream(self, endpoint: Endpoint,
                    client_id: str = "default", trace=None,
                    now_s: float = 0.0) -> ScanHandle:
        """Open one stream lease; admission-gated when a controller is set
        (may raise ``qos.Backpressure`` with a retry-after hint). The check
        is routed to the endpoint server's quota shard when the controller
        is sharded (``server_id=`` is ignored by a centralized one).
        ``trace`` (an ``obs.StreamTrace``) gets a ``stream.open`` instant
        at ``now_s`` on the stream's local clock."""
        if self.admission is not None:
            self.admission.acquire_stream(client_id,
                                          server_id=endpoint.server_id)
        try:
            server = self.server(endpoint.server_id)
            handle = server.init_scan(endpoint.sql, endpoint.dataset,
                                      start_batch=endpoint.start_batch)
        except BaseException:
            if self.admission is not None:
                self.admission.release_stream(client_id,
                                              server_id=endpoint.server_id)
            raise
        if trace is not None:
            trace.instant("stream.open", now_s, cat="stream",
                          server=endpoint.server_id)
        return handle

    def admission_headroom(self, server_id: str,
                           client_id: str = "default") -> int | None:
        """Free admission capacity at ``server_id``'s quota shard for one
        more of ``client_id``'s streams, or ``None`` when unlimited/unknown.

        The steal scheduler's thief-side check: before re-leasing a stolen
        range onto a server, it asks whether that server's shard could admit
        the extra stream *locally* — a shard at its quota would stall the
        thief on admission (or force a borrow), trading a transport stall
        for an admission stall. Duck-typed like every admission touchpoint:
        controllers without a ``headroom`` query report ``None`` (no
        opinion), so plain deployments steal exactly as before."""
        if self.admission is None:
            return None
        headroom = getattr(self.admission, "headroom", None)
        if headroom is None:
            return None
        return headroom(server_id, client_id)

    def resume_stream(self, endpoint: Endpoint, delivered: int) -> ScanHandle:
        """Restart one failed stream where it died: a fresh ``init_scan``
        fast-forwarded past the batches the stream already delivered. The
        stream's admission slot stays held — a resume is the same logical
        stream, not a new grant."""
        server = self.server(endpoint.server_id)
        return server.init_scan(
            endpoint.sql, endpoint.dataset,
            start_batch=endpoint.start_batch + delivered)

    def reopen_stream(self, endpoint: Endpoint, delivered: int,
                      client_id: str = "default") -> ScanHandle:
        """Resume a *parked* stream (lease-boundary preemption, see
        :mod:`repro.sched.preempt`). Unlike :meth:`resume_stream`, parking
        released the admission slot back to the budget, so the re-open is a
        fresh admission-gated grant — it may raise ``qos.Backpressure``."""
        return self.open_stream(
            dataclasses.replace(
                endpoint, start_batch=endpoint.start_batch + delivered),
            client_id=client_id)

    def close_stream(self, endpoint: Endpoint, uid: str,
                     client_id: str = "default",
                     now_s: float | None = None, trace=None,
                     trace_now_s: float = 0.0) -> None:
        """Release the lease and its admission slot. ``now_s`` is an
        optional timestamp on the admission controller's modeled timeline,
        forwarded to its freed-slot callbacks; leave it ``None`` when the
        caller has no clock on that timeline (listeners then stamp their
        own — per-stream scan clocks do NOT qualify, they are relative).
        ``trace``/``trace_now_s`` record a ``stream.close`` instant on the
        stream's own (relative) clock — a different timeline on purpose."""
        if self.admission is not None:
            self.admission.release_stream(client_id,
                                          server_id=endpoint.server_id,
                                          now_s=now_s)
        server = self.server(endpoint.server_id)
        if uid in server.reader_map:   # may already be reclaimed/evicted
            server.finalize(uid)
        if trace is not None:
            trace.instant("stream.close", trace_now_s, cat="stream",
                          server=endpoint.server_id)

    def reclaim_stale(self, older_than_s: float) -> int:
        """Sweep abandoned leases across the whole cluster."""
        return sum(s.reclaim_stale(older_than_s)
                   for s in self.servers.values())
