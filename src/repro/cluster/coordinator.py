"""Cluster coordinator: server/endpoint registry, dataset placement, lease
reclamation, and stream resume.

The coordinator is the control-plane brain the dataplane modules lean on:

* **registry** — server_id → :class:`ThallusServer`, plus a record of which
  datasets live where and how (``shard`` vs ``replica`` placement);
* **placement** — :meth:`place_shards` splits a table's batches round-robin
  across servers under one dataset path (disjoint shards);
  :meth:`place_replicas` registers a full copy everywhere;
* **planning** — :meth:`plan` delegates to :func:`repro.cluster.plan.plan_scan`
  with the recorded placement;
* **lease lifecycle** — :meth:`open_stream` / :meth:`resume_stream` /
  :meth:`close_stream` wrap ``init_scan``/``finalize``, and
  :meth:`reclaim_stale` sweeps every server's reader map (activity-based, so
  live streams survive the sweep);
* **admission** — an optional :class:`repro.qos.AdmissionController` (duck
  typed, so there is no cluster→qos import) gates every lease grant:
  ``open_stream`` acquires a per-client stream slot (raising
  ``qos.Backpressure`` at the quota or over the memory budget) and
  ``close_stream`` releases it. Admission checks are routed **per server**:
  the endpoint's ``server_id`` rides along on every acquire/release, so a
  :class:`repro.qos.ShardedAdmission` meters each lease against that
  server's own quota shard (a centralized controller simply ignores the
  routing hint). The qos ``ScanGateway`` meters at request granularity
  instead, so a gateway's coordinator runs without one;
* **observability funnel** — optional ``recorder`` (an
  ``obs.FlightRecorder``) and ``health`` (an ``obs.HealthMonitor``), both
  duck-typed. Every layer above and below reports its decisions through
  :meth:`notify` (steal/decline, park/resume, shed, stream fault, ...) so
  one attribute check on the coordinator fans the event out to the flight
  recorder ring and the health monitor's window counters; :meth:`heartbeat`
  advances the health state machine in modeled time. Plain deployments set
  neither and pay two ``None`` checks per event.
"""
from __future__ import annotations

import dataclasses

from ..core.protocol import ScanHandle, ThallusServer
from ..engine.table import Table
from .plan import Endpoint, ScanPlan, plan_scan


class PlacementError(KeyError):
    """No registered server can serve the dataset — every host named by the
    recorded placement has left the cluster (or none was ever registered)."""


class MigrationError(RuntimeError):
    """A stream lease cannot fail over: no surviving replica hosts the
    dataset (shard placements hold disjoint data — a dead shard's rows have
    no second home until re-placement repairs the map)."""


# health states, worst-last — used only to *order* failover candidates, so
# the coordinator stays duck-typed on the monitor (no cluster→obs import)
_HEALTH_RANK = {"healthy": 0, "degraded": 1, "suspect": 2, "quarantined": 3}


@dataclasses.dataclass
class _Placement:
    mode: str                      # "shard" | "replica"
    server_ids: tuple[str, ...]
    table: Table | None = None     # source table, for membership re-placement
    # shard mode: server_id → dataset-global batch indices its shard holds
    assignment: dict[str, tuple[int, ...]] | None = None


class ClusterCoordinator:
    """Registry + lease lifecycle for a set of Thallus servers."""

    def __init__(self, admission=None, recorder=None, health=None) -> None:
        self.servers: dict[str, ThallusServer] = {}
        self.admission = admission
        self.recorder = recorder       # obs.FlightRecorder (duck-typed)
        self.health = health           # obs.HealthMonitor (duck-typed)
        # optional cluster.repair.ShardRepairer (duck-typed: observe /
        # forget / reshard / replicate). When attached, every re-placement
        # moves bytes peer-to-peer over the registered RDMA path instead of
        # re-registering slices of the coordinator's stored source table;
        # without one the legacy table-copy path below runs unchanged.
        self.repairer = None
        self._placements: dict[str, _Placement] = {}

    # ------------------------------------------------- observability funnel
    def notify(self, kind: str, server_id: str = "", now_s: float = 0.0,
               **attrs) -> None:
        """Report one structured decision (``steal.decline``,
        ``stream.fault``, ``qos.shed``, ...) to the attached flight
        recorder and health monitor. A no-op when neither is attached."""
        if self.recorder is not None:
            self.recorder.record(kind, now_s=now_s, server_id=server_id,
                                 **attrs)
        if self.health is not None:
            observe = getattr(self.health, "observe_event", None)
            if observe is not None:
                observe(kind, server_id, now_s)

    def heartbeat(self, now_s: float) -> list:
        """Advance the attached health monitor one heartbeat on the modeled
        clock; returns the health transitions it produced ([] when no
        monitor is attached)."""
        if self.health is None:
            return []
        return self.health.heartbeat(now_s)

    # ------------------------------------------------------------ registry
    def add_server(self, server_id: str, server: ThallusServer, *,
                   rebalance: bool = False, now_s: float = 0.0) -> None:
        """Register a server. With ``rebalance=True`` (a live *join*), every
        recorded placement is repaired to put the joiner to work: replica
        datasets get a full copy registered on it, shard datasets hand it a
        minimal-movement slice (only ``⌊batches/n⌋`` batches move, taken one
        at a time from the currently-largest shards)."""
        if server_id in self.servers:
            raise ValueError(f"server id {server_id!r} already registered")
        self.servers[server_id] = server
        if rebalance:
            for dataset, placement in self._placements.items():
                if server_id in placement.server_ids:
                    continue
                if placement.table is None:
                    continue   # legacy placement with no stored source table
                if placement.mode == "replica":
                    placement.server_ids = tuple(
                        sorted((*placement.server_ids, server_id)))
                    if self.repairer is not None:
                        self.repairer.replicate(dataset, placement, server_id,
                                                now_s=now_s)
                    else:
                        server.engine.register(dataset, placement.table)
                    self.notify("placement.repair", server_id=server_id,
                                now_s=now_s, dataset=dataset, mode="replica",
                                action="join")
                else:
                    self._join_shard(dataset, placement, server_id, now_s)

    def remove_server(self, server_id: str, *,
                      now_s: float = 0.0) -> ThallusServer:
        """Deregister a server (a live *leave*/eviction) and repair every
        placement naming it: replica placements just drop the host; shard
        placements re-deal the orphaned shard's batches across the smallest
        surviving shards (survivors keep everything they already hold —
        minimal movement). Returns the removed server so a membership
        controller can stash it for re-admission."""
        server = self.server(server_id)
        del self.servers[server_id]
        if self.repairer is not None:
            # the departed server's pinned memory is gone: purge it from the
            # donor directory BEFORE any re-deal tries to pull from it
            self.repairer.forget(server_id)
        for dataset, placement in self._placements.items():
            if server_id not in placement.server_ids:
                continue
            placement.server_ids = tuple(
                sid for sid in placement.server_ids if sid != server_id)
            if placement.mode == "shard" and placement.assignment is not None:
                orphans = placement.assignment.pop(server_id, ())
                self._redeal(dataset, placement, orphans, now_s=now_s)
                self.notify("placement.repair", server_id=server_id,
                            now_s=now_s, dataset=dataset, mode="shard",
                            action="leave", moved=len(orphans))
            else:
                self.notify("placement.repair", server_id=server_id,
                            now_s=now_s, dataset=dataset,
                            mode=placement.mode, action="leave")
        return server

    def _join_shard(self, dataset: str, placement: _Placement,
                    joiner: str, now_s: float) -> None:
        """Hand a joining server a minimal-movement shard slice."""
        assignment = placement.assignment
        if assignment is None:
            assignment = placement.assignment = {}
        total = sum(len(v) for v in assignment.values())
        want = total // (len(placement.server_ids) + 1)
        taken: list[int] = []
        donors: set[str] = set()
        for _ in range(want):
            # take one batch from the largest donor shard (deterministic
            # tie-break: largest size, then highest server_id) — its
            # highest global index, so donors keep their prefix
            donor = max(assignment,
                        key=lambda sid: (len(assignment[sid]), sid))
            *keep, moved = assignment[donor]
            assignment[donor] = tuple(keep)
            taken.append(moved)
            donors.add(donor)
        assignment[joiner] = tuple(sorted(taken))
        placement.server_ids = tuple(sorted((*placement.server_ids, joiner)))
        if self.repairer is not None:
            # the joiner pulls FIRST: the moved batches are still pinned on
            # their donors, so every one rides the peer RDMA path; the
            # donors then shrink to their kept prefix with zero movement
            self.repairer.reshard(dataset, placement, joiner, now_s=now_s)
            for donor in sorted(donors):
                self.repairer.reshard(dataset, placement, donor, now_s=now_s)
        else:
            for donor in sorted(donors):
                self._register_shard(dataset, placement, donor)
            self._register_shard(dataset, placement, joiner)
        self.notify("placement.repair", server_id=joiner, now_s=now_s,
                    dataset=dataset, mode="shard", action="join",
                    moved=len(taken))

    def _redeal(self, dataset: str, placement: _Placement,
                orphans: tuple[int, ...], now_s: float = 0.0) -> None:
        """Deal orphaned global batch indices to the smallest surviving
        shards (ties → lowest server_id), keeping each shard sorted."""
        assignment = placement.assignment
        if assignment is None or not placement.server_ids:
            return
        for idx in sorted(orphans):
            target = min(placement.server_ids,
                         key=lambda sid: (len(assignment.get(sid, ())), sid))
            assignment[target] = tuple(sorted((*assignment.get(target, ()),
                                               idx)))
        for sid in placement.server_ids:
            if self.repairer is not None:
                # survivors reuse what they hold; the orphaned indices have
                # no live holder left (shards are disjoint), so each lands
                # via the stored-table fallback — the durability story
                self.repairer.reshard(dataset, placement, sid, now_s=now_s)
            else:
                self._register_shard(dataset, placement, sid)

    def _register_shard(self, dataset: str, placement: _Placement,
                        server_id: str) -> None:
        table = placement.table
        if table is None or placement.assignment is None:
            return
        shard = Table(table.name, table.schema,
                      batches=[table.batches[j]
                               for j in placement.assignment.get(server_id,
                                                                 ())])
        self.server(server_id).engine.register(dataset, shard)

    def server(self, server_id: str) -> ThallusServer:
        if server_id not in self.servers:
            raise KeyError(f"unknown server {server_id!r}")
        return self.servers[server_id]

    def hosts(self, dataset: str) -> dict[str, ThallusServer]:
        """Which servers host ``dataset``. Uses the recorded placement when
        one exists, otherwise falls back to probing server catalogs.

        A placement may name servers that have since left the cluster
        (anything that bypassed :meth:`remove_server`'s repair); those are
        dropped from the returned map — and reported as ``placement.stale``
        — rather than raised, so one stale entry can't strand every scan of
        the dataset. :meth:`plan` raises :class:`PlacementError` only when
        *no* host survives."""
        placement = self._placements.get(dataset)
        if placement is not None:
            missing = [sid for sid in placement.server_ids
                       if sid not in self.servers]
            for sid in missing:
                self.notify("placement.stale", server_id=sid,
                            dataset=dataset)
            return {sid: self.servers[sid] for sid in placement.server_ids
                    if sid in self.servers}
        found = {}
        for sid, server in self.servers.items():
            catalog = getattr(server.engine, "catalog", None)
            if catalog is not None and dataset in catalog:
                found[sid] = server
        return found

    def placement_mode(self, dataset: str) -> str:
        placement = self._placements.get(dataset)
        return placement.mode if placement is not None else "shard"

    # ----------------------------------------------------------- placement
    def place_shards(self, dataset: str, table: Table,
                     server_ids: list[str] | None = None) -> None:
        """Split ``table``'s batches round-robin into disjoint shards, one
        per server, all registered under the same dataset path."""
        ids = sorted(server_ids or self.servers)
        if not ids:
            raise ValueError("no servers to place shards on")
        assignment = {sid: tuple(range(i, len(table.batches), len(ids)))
                      for i, sid in enumerate(ids)}
        for i, sid in enumerate(ids):
            shard = Table(table.name, table.schema,
                          batches=table.batches[i::len(ids)])
            self.server(sid).engine.register(dataset, shard)
        self._placements[dataset] = _Placement("shard", tuple(ids),
                                               table=table,
                                               assignment=assignment)
        if self.repairer is not None:
            self.repairer.observe(dataset, self._placements[dataset])

    def place_replicas(self, dataset: str, table: Table,
                       server_ids: list[str] | None = None) -> None:
        """Register a full copy of ``table`` on every server."""
        ids = sorted(server_ids or self.servers)
        if not ids:
            raise ValueError("no servers to place replicas on")
        for sid in ids:
            self.server(sid).engine.register(dataset, table)
        self._placements[dataset] = _Placement("replica", tuple(ids),
                                               table=table)
        if self.repairer is not None:
            self.repairer.observe(dataset, self._placements[dataset])

    # ------------------------------------------------------------ planning
    def plan(self, sql: str, dataset: str,
             num_streams: int | None = None,
             placement: str | None = None) -> ScanPlan:
        hosts = self.hosts(dataset)
        if not hosts:
            raise PlacementError(f"no server hosts dataset {dataset!r}")
        mode = placement or self.placement_mode(dataset)
        recorded = self._placements.get(dataset)
        assignment = (recorded.assignment
                      if recorded is not None and mode == "shard" else None)
        return plan_scan(sql, dataset, hosts, placement=mode,
                         num_streams=num_streams, assignment=assignment)

    # ------------------------------------------------- stream lease lifecycle
    def open_stream(self, endpoint: Endpoint,
                    client_id: str = "default", trace=None,
                    now_s: float = 0.0) -> ScanHandle:
        """Open one stream lease; admission-gated when a controller is set
        (may raise ``qos.Backpressure`` with a retry-after hint). The check
        is routed to the endpoint server's quota shard when the controller
        is sharded (``server_id=`` is ignored by a centralized one).
        ``trace`` (an ``obs.StreamTrace``) gets a ``stream.open`` instant
        at ``now_s`` on the stream's local clock."""
        if self.admission is not None:
            self.admission.acquire_stream(client_id,
                                          server_id=endpoint.server_id)
        try:
            server = self.server(endpoint.server_id)
            handle = server.init_scan(endpoint.sql, endpoint.dataset,
                                      start_batch=endpoint.start_batch)
        except BaseException:
            if self.admission is not None:
                self.admission.release_stream(client_id,
                                              server_id=endpoint.server_id)
            raise
        if trace is not None:
            trace.instant("stream.open", now_s, cat="stream",
                          server=endpoint.server_id)
        return handle

    def admission_headroom(self, server_id: str,
                           client_id: str = "default") -> int | None:
        """Free admission capacity at ``server_id``'s quota shard for one
        more of ``client_id``'s streams, or ``None`` when unlimited/unknown.

        The steal scheduler's thief-side check: before re-leasing a stolen
        range onto a server, it asks whether that server's shard could admit
        the extra stream *locally* — a shard at its quota would stall the
        thief on admission (or force a borrow), trading a transport stall
        for an admission stall. Duck-typed like every admission touchpoint:
        controllers without a ``headroom`` query report ``None`` (no
        opinion), so plain deployments steal exactly as before."""
        if self.admission is None:
            return None
        headroom = getattr(self.admission, "headroom", None)
        if headroom is None:
            return None
        return headroom(server_id, client_id)

    def resume_stream(self, endpoint: Endpoint, delivered: int) -> ScanHandle:
        """Restart one failed stream where it died: a fresh ``init_scan``
        fast-forwarded past the batches the stream already delivered. The
        stream's admission slot stays held — a resume is the same logical
        stream, not a new grant."""
        server = self.server(endpoint.server_id)
        return server.init_scan(
            endpoint.sql, endpoint.dataset,
            start_batch=endpoint.start_batch + delivered)

    def failover_target(self, endpoint: Endpoint) -> str:
        """Pick the surviving replica a dead server's stream migrates to.

        Only replica placements can fail over — a shard's rows have no
        second home. Candidates are the dataset's other registered,
        non-crashed hosts, ordered best-health-first (ties broken by sorted
        server_id so the choice is deterministic); raises
        :class:`MigrationError` when none survives."""
        placement = self._placements.get(endpoint.dataset)
        if placement is None or placement.mode != "replica":
            raise MigrationError(
                f"stream on {endpoint.server_id!r} cannot fail over: "
                f"dataset {endpoint.dataset!r} is not replica-placed")
        candidates = [
            sid for sid in placement.server_ids
            if sid != endpoint.server_id and sid in self.servers
            and not getattr(self.servers[sid], "crashed", False)]
        if not candidates:
            raise MigrationError(
                f"no surviving replica hosts dataset {endpoint.dataset!r} "
                f"(stream was on {endpoint.server_id!r})")
        if self.health is not None:
            state = getattr(self.health, "state", None)
            if state is not None:
                return min(candidates,
                           key=lambda sid: (_HEALTH_RANK.get(state(sid), 0),
                                            sid))
        return min(candidates)

    def failover_stream(self, endpoint: Endpoint, delivered: int,
                        client_id: str = "default", *,
                        slot_held: bool = True,
                        now_s: float = 0.0) -> tuple[Endpoint, ScanHandle]:
        """Migrate one stream lease off a dead/unregistered server.

        When the endpoint's server is still alive this is exactly
        :meth:`resume_stream` (same server, same slot). Otherwise the lease
        moves to :meth:`failover_target`'s pick: the dead shard's admission
        slot is released (when ``slot_held``), a fresh slot is acquired on
        the target's shard, and the scan resumes mid-flight via
        ``init_scan(start_batch=endpoint.start_batch + delivered)`` — the
        delivered prefix is never re-shipped. Returns the re-targeted
        endpoint (original ``start_batch``, so the caller's delivered-count
        bookkeeping stays valid) plus the new handle, and reports
        ``stream.migrate`` through the funnel."""
        server = self.servers.get(endpoint.server_id)
        if server is not None and not getattr(server, "crashed", False):
            return endpoint, self.resume_stream(endpoint, delivered)
        target = self.failover_target(endpoint)
        if self.admission is not None and slot_held:
            self.admission.release_stream(client_id,
                                          server_id=endpoint.server_id,
                                          now_s=now_s)
        new_endpoint = dataclasses.replace(endpoint, server_id=target)
        handle = self.open_stream(
            dataclasses.replace(new_endpoint,
                                start_batch=endpoint.start_batch + delivered),
            client_id=client_id, now_s=now_s)
        self.notify("stream.migrate", server_id=endpoint.server_id,
                    now_s=now_s, to=target, delivered=delivered,
                    client=client_id)
        return new_endpoint, handle

    def reopen_stream(self, endpoint: Endpoint, delivered: int,
                      client_id: str = "default") -> ScanHandle:
        """Resume a *parked* stream (lease-boundary preemption, see
        :mod:`repro.sched.preempt`). Unlike :meth:`resume_stream`, parking
        released the admission slot back to the budget, so the re-open is a
        fresh admission-gated grant — it may raise ``qos.Backpressure``."""
        return self.open_stream(
            dataclasses.replace(
                endpoint, start_batch=endpoint.start_batch + delivered),
            client_id=client_id)

    def close_stream(self, endpoint: Endpoint, uid: str,
                     client_id: str = "default",
                     now_s: float | None = None, trace=None,
                     trace_now_s: float = 0.0) -> None:
        """Release the lease and its admission slot. ``now_s`` is an
        optional timestamp on the admission controller's modeled timeline,
        forwarded to its freed-slot callbacks; leave it ``None`` when the
        caller has no clock on that timeline (listeners then stamp their
        own — per-stream scan clocks do NOT qualify, they are relative).
        ``trace``/``trace_now_s`` record a ``stream.close`` instant on the
        stream's own (relative) clock — a different timeline on purpose."""
        if self.admission is not None:
            self.admission.release_stream(client_id,
                                          server_id=endpoint.server_id,
                                          now_s=now_s)
        server = self.server(endpoint.server_id)
        if uid in server.reader_map:   # may already be reclaimed/evicted
            server.finalize(uid)
        if trace is not None:
            trace.instant("stream.close", trace_now_s, cat="stream",
                          server=endpoint.server_id)

    def reclaim_stale(self, older_than_s: float,
                      now_s: float | None = None) -> int:
        """Sweep abandoned leases across the whole cluster. ``now_s`` pins
        the sweep to the modeled timeline (see
        :meth:`ThallusServer.reclaim_stale`)."""
        return sum(s.reclaim_stale(older_than_s, now_s=now_s)
                   for s in self.servers.values())
