"""repro.cluster: the partitioned, pooled, multi-stream dataplane.

Turns the single client↔server scan of :mod:`repro.core.protocol` into a
cluster-scale transport: a FlightInfo-style planner (:mod:`.plan`), a
coordinator owning placement and lease lifecycle (:mod:`.coordinator`), a
registered buffer pool amortizing allocation + registration
(:mod:`.mempool`), and a multi-stream puller with bounded leases and
per-stream fault recovery (:mod:`.streams`).
"""
from __future__ import annotations

from typing import Callable

from ..core.recordbatch import RecordBatch
from .coordinator import (  # noqa: F401
    ClusterCoordinator, MigrationError, PlacementError,
)
from .membership import MembershipController, MembershipEvent  # noqa: F401
from .mempool import BufferPool, PoolStats, size_class  # noqa: F401
from .nemesis import FaultSpec, Nemesis, seeded_schedule  # noqa: F401
from .plan import Endpoint, ScanPlan, plan_scan, probe_batches  # noqa: F401
from .repair import RepairConfig, RepairStats, ShardRepairer  # noqa: F401
from .streams import (  # noqa: F401
    ClusterStats, MultiStreamPuller, StreamPuller, StreamStats,
)


def cluster_scan(coordinator: ClusterCoordinator, sql: str, dataset: str,
                 num_streams: int | None = None,
                 pool: BufferPool | None = None,
                 lease_batches: int = 1, schedule: str = "round_robin",
                 prefetch: bool = True, client_id: str = "default",
                 sink: Callable[[int, RecordBatch], None] | None = None,
                 ) -> ClusterStats:
    """One-call partitioned scan: plan → pull all streams → stats.

    With a ``pool``, batches are recycled after ``sink`` returns — the sink
    must copy anything it wants to keep (the streaming contract).
    """
    scan_plan = coordinator.plan(sql, dataset, num_streams=num_streams)
    puller = MultiStreamPuller(coordinator, scan_plan, pool=pool,
                               lease_batches=lease_batches, schedule=schedule,
                               prefetch=prefetch, client_id=client_id)
    return puller.run(sink)
