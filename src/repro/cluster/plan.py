"""Partitioned-scan planning: one query → many endpoints.

Arrow Flight amortizes per-stream setup costs by answering ``GetFlightInfo``
with a *list of endpoints*, each a (ticket, location) pair the client pulls
in parallel ("Benchmarking Apache Arrow Flight", arXiv:2204.03032). This
module is the Thallus analogue: :func:`plan_scan` turns ``(sql, dataset)``
plus a placement map into a deterministic :class:`ScanPlan` whose
:class:`Endpoint`\\ s are independent resumable scans (``init_scan`` args),
one per stream.

Two placements are planned:

* ``shard`` — each server holds a *disjoint shard* of the dataset under the
  same path. One endpoint per shard-holding server, full query, no overlap.
* ``replica`` — every server holds a full copy. The planner probes the
  result-batch count once (server-side planning RPC, the analogue of
  Flight's schema/stats in ``FlightInfo``) and splits the batch range into
  contiguous ``init_scan(start_batch=…) × max_batches`` slices.
"""
from __future__ import annotations

import dataclasses
import hashlib

from ..core.protocol import ThallusServer


@dataclasses.dataclass(frozen=True)
class Endpoint:
    """One stream of the partitioned scan — exactly the arguments a client
    needs to drive ``init_scan``/``iterate`` against one server.

    ``global_batches`` carries the *dataset-global* batch indices this
    stream's shard holds, in shard-local order. A fresh round-robin deal
    leaves it ``None`` (the classic ``i::n`` interleave reassembly applies);
    after a membership change re-deals orphaned batches, shards hold
    irregular index sets and reassembly must order by these global indices
    instead.
    """

    server_id: str
    sql: str
    dataset: str
    start_batch: int = 0
    max_batches: int | None = None   # None == drain to end-of-stream
    global_batches: tuple[int, ...] | None = None


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """The FlightInfo analogue: what a coordinator hands back for a query."""

    query_id: str
    sql: str
    dataset: str
    placement: str                   # "shard" | "replica"
    endpoints: tuple[Endpoint, ...]

    @property
    def num_streams(self) -> int:
        return len(self.endpoints)


def _query_id(sql: str, dataset: str, placement: str,
              server_ids: tuple[str, ...]) -> str:
    h = hashlib.sha1()
    for part in (sql, dataset, placement, *server_ids):
        h.update(part.encode())
        h.update(b"\0")
    return h.hexdigest()[:16]


def probe_batches(server: ThallusServer, sql: str, dataset: str) -> int:
    """Count result batches without shipping data — the planner's one
    server-side statistics pass (a planning RPC, charged to the fabric).
    Engines that expose ``estimate_batches`` answer from table statistics;
    otherwise the probe drains a planning-only reader (a real extra scan,
    the price of an exact count under filters)."""
    server.fabric.rpc(len(sql) + len(dataset) + 64)
    estimate = getattr(server.engine, "estimate_batches", None)
    if estimate is not None:
        n = estimate(sql, dataset)
        if n is not None:
            return n
    reader = server.engine.execute(sql, dataset)
    n = 0
    while reader.read_next() is not None:
        n += 1
    return n


def plan_scan(sql: str, dataset: str,
              servers: dict[str, ThallusServer],
              placement: str = "shard",
              num_streams: int | None = None,
              assignment: dict[str, tuple[int, ...]] | None = None) -> ScanPlan:
    """Deterministic partitioned-scan plan.

    ``servers`` maps server_id → server for every server hosting ``dataset``
    (the coordinator's placement lookup). Endpoints are emitted in sorted
    server_id order so the same inputs always produce the same plan.

    ``assignment`` (shard placement only) maps server_id → the dataset-global
    batch indices its shard holds. Servers whose shard is empty — the common
    case right after a member joins a small dataset, or when there are more
    servers than batches — get no endpoint: an empty shard owns no rows, so
    skipping it cannot drop data, and a stream pinned to it would only burn
    an admission slot to deliver nothing.
    """
    if not servers:
        raise ValueError(f"no servers host dataset {dataset!r}")
    ids = tuple(sorted(servers))
    if placement == "shard":
        if assignment is not None:
            ids = tuple(sid for sid in ids if assignment.get(sid))
            if not ids:
                raise ValueError(
                    f"every shard of dataset {dataset!r} is empty")
        if num_streams is not None and num_streams < len(ids):
            # every (non-empty) shard-holding server owns rows nobody else
            # has; fewer streams than shards would silently drop data
            raise ValueError(
                f"shard placement needs one stream per shard: {dataset!r} "
                f"lives on {len(ids)} servers, num_streams={num_streams}")
        endpoints = tuple(
            Endpoint(sid, sql, dataset,
                     global_batches=(tuple(assignment[sid])
                                     if assignment is not None else None))
            for sid in ids)
    elif placement == "replica":
        streams = num_streams or len(ids)
        total = probe_batches(servers[ids[0]], sql, dataset)
        streams = max(1, min(streams, total)) if total else 1
        base, extra = divmod(total, streams)
        endpoints, start = [], 0
        for i in range(streams):
            count = base + (1 if i < extra else 0)
            endpoints.append(Endpoint(ids[i % len(ids)], sql, dataset,
                                      start_batch=start, max_batches=count))
            start += count
        endpoints = tuple(endpoints)
    else:
        raise ValueError(f"unknown placement {placement!r} "
                         "(want 'shard' or 'replica')")
    return ScanPlan(_query_id(sql, dataset, placement, ids),
                    sql, dataset, placement, endpoints)
