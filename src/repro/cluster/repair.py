"""Peer-to-peer shard migration & replica repair over the RDMA fast path.

Until now every membership re-placement (join slice, leave re-deal, replica
copy, re-admit pre-warm) re-registered shard slices from the *coordinator's*
stored source table — a coordinator-held copy, not a network transfer, which
cannot exist at production scale. This module makes the cluster's registered
memory one shared repair substrate: the bytes a joiner needs already live,
pinned, in some peer's engine-registered shard, so the peer *donates* them
over the same registered-buffer RDMA path the client scan plane uses.

One repair of one batch is exactly the paper's transport, server→server:

* the **donor** (best-health live holder of the batch, picked off the
  repairer's segment directory) exposes its registered batch buffers as a
  read-only bulk — zero copies;
* the descriptor table crosses the control plane as one small RPC;
* the **target** checks pooled slabs out of its per-server registered
  :class:`~repro.cluster.mempool.BufferPool` and ``rdma_pull``s the segments
  with ``registered=True`` (both ends pinned: no per-segment registration);
* the pulled slabs are **adopted** — they leave the pool's checkout ledger
  and become the shard's long-lived storage — and the batch is assembled
  zero-copy and ``engine.register``ed under the dataset path.

Only when *no* live registered peer holds a batch (the dead server was its
sole holder) does the repairer fall back to the coordinator's stored source
table: the durability story. The fallback's cost is modeled honestly — the
batch streams over the RPC payload path and the target pins fresh segments —
so benchmarks can show what the peer path saves.

Repair traffic is a **background QoS class**: each pull first leases tokens
from the donor's admission shard (``lease_wait_s`` on the repair clock), and
while the donor's bucket sits below a small reserve the repairer *yields* —
backs off on its own modeled clock instead of draining tokens interactive
arrivals are about to claim. A rebalance storm therefore cannot starve
foreground scans; it waits for them.

Everything is reported through the obs spine: ``repair.pull`` /
``repair.fallback`` / ``repair.complete`` notify events, ``repair.*``
registry metrics (:func:`repro.obs.record_repair`), and optional trace spans
on the repair clock.
"""
from __future__ import annotations

import dataclasses

from ..core import bulk as bulk_mod
from ..core.recordbatch import RecordBatch
from ..engine.table import Table
from .coordinator import _HEALTH_RANK, ClusterCoordinator, _Placement
from .mempool import BufferPool


@dataclasses.dataclass
class RepairConfig:
    """Knobs for the background-class metering and the target-side pools."""

    tokens_per_batch: int = 1        # lease cost of one repaired batch
    reserve_tokens: float = 1.0      # donor-bucket floor kept for foreground
    backoff_s: float = 1.0e-3        # modeled yield while under the reserve
    max_yields_per_batch: int = 8    # bounded politeness: then pull anyway
    pool_max_bytes: int | None = None  # per-target pool budget (None = open)


@dataclasses.dataclass
class RepairStats:
    """Cumulative repair activity (``clock_s`` is a level, not a counter)."""

    repairs: int = 0                 # reshard/replicate operations completed
    batches_pulled: int = 0          # peer-to-peer RDMA pulls
    bytes_pulled: int = 0
    segments_pulled: int = 0
    batches_reused: int = 0          # already registered locally: zero movement
    table_copies: int = 0            # durability fallbacks to the source table
    bytes_copied: int = 0
    modeled_wire_s: float = 0.0      # peer path: descriptor RPC + RDMA wire
    modeled_copy_s: float = 0.0      # fallback path: RPC payload + fresh pins
    throttle_wait_s: float = 0.0     # admission lease waits (background class)
    yield_s: float = 0.0             # modeled backoff under the token reserve
    yields: int = 0
    clock_s: float = 0.0             # the repairer's modeled timeline

    def delta_since(self, baseline: "RepairStats") -> "RepairStats":
        """Activity since ``baseline`` (a ``replace()`` copy taken earlier);
        ``clock_s`` stays current, everything else is subtracted."""
        return RepairStats(
            repairs=self.repairs - baseline.repairs,
            batches_pulled=self.batches_pulled - baseline.batches_pulled,
            bytes_pulled=self.bytes_pulled - baseline.bytes_pulled,
            segments_pulled=self.segments_pulled - baseline.segments_pulled,
            batches_reused=self.batches_reused - baseline.batches_reused,
            table_copies=self.table_copies - baseline.table_copies,
            bytes_copied=self.bytes_copied - baseline.bytes_copied,
            modeled_wire_s=self.modeled_wire_s - baseline.modeled_wire_s,
            modeled_copy_s=self.modeled_copy_s - baseline.modeled_copy_s,
            throttle_wait_s=self.throttle_wait_s - baseline.throttle_wait_s,
            yield_s=self.yield_s - baseline.yield_s,
            yields=self.yields - baseline.yields,
            clock_s=self.clock_s)


class ShardRepairer:
    """Peer-to-peer re-placement engine, attached to a coordinator.

    Constructing one self-registers as ``coordinator.repairer`` (duck-typed:
    the coordinator only ever calls ``observe``/``forget``/``reshard``/
    ``replicate`` on it) and seeds the segment directory from the placements
    already recorded. From then on every re-placement site — ``_join_shard``,
    ``_redeal``, the ``add_server`` replica copy, and the membership
    controller's re-admit pre-warm riding ``add_server(rebalance=True)`` —
    routes its byte movement through here instead of the stored source table.
    """

    def __init__(self, coordinator: ClusterCoordinator,
                 config: RepairConfig | None = None,
                 client_id: str = "repair", tracer=None) -> None:
        self.coordinator = coordinator
        self.config = config or RepairConfig()
        self.client_id = client_id
        self.tracer = tracer           # obs.Tracer (duck-typed), optional
        self.stats = RepairStats()
        self.pools: dict[str, BufferPool] = {}   # target sid -> its pool
        # the segment directory: dataset -> server_id -> {global batch index
        # -> the batch object registered (pinned) on that server}. Donor
        # selection consults this, never the engines, so a dead server's
        # entries can be purged the moment it leaves.
        self._held: dict[str, dict[str, dict[int, RecordBatch]]] = {}
        coordinator.repairer = self
        for dataset, placement in coordinator._placements.items():
            self.observe(dataset, placement)

    # ------------------------------------------------------------ directory
    def observe(self, dataset: str, placement: _Placement) -> None:
        """Seed/refresh the directory from a freshly recorded placement:
        every named server holds its registered slice of the source table."""
        table = placement.table
        if table is None:
            return
        held = self._held.setdefault(dataset, {})
        if placement.mode == "replica":
            for sid in placement.server_ids:
                held[sid] = dict(enumerate(table.batches))
        else:
            for sid, idxs in (placement.assignment or {}).items():
                held[sid] = {i: table.batches[i] for i in idxs}

    def forget(self, server_id: str) -> None:
        """Drop a departed server from the directory — its pinned memory is
        gone, so it can never again be picked as a donor."""
        for held in self._held.values():
            held.pop(server_id, None)

    def holders(self, dataset: str, idx: int) -> tuple[str, ...]:
        """Which live, non-crashed servers hold batch ``idx`` registered."""
        held = self._held.get(dataset, {})
        live = []
        for sid, batches in held.items():
            if idx not in batches:
                continue
            server = self.coordinator.servers.get(sid)
            if server is None or getattr(server, "crashed", False):
                continue
            live.append(sid)
        return tuple(sorted(live))

    def _pick_donor(self, dataset: str, idx: int,
                    exclude: str) -> str | None:
        """Best-health live holder of ``idx`` (ties by sorted server_id)."""
        candidates = [sid for sid in self.holders(dataset, idx)
                      if sid != exclude]
        if not candidates:
            return None
        health = getattr(self.coordinator, "health", None)
        state = getattr(health, "state", None) if health is not None else None
        if state is not None:
            return min(candidates,
                       key=lambda sid: (_HEALTH_RANK.get(state(sid), 0), sid))
        return min(candidates)

    # ------------------------------------------------------------- repairs
    def reshard(self, dataset: str, placement: _Placement, server_id: str,
                *, now_s: float = 0.0) -> None:
        """Materialize ``server_id``'s assigned shard slice: reuse what it
        already holds, peer-pull what a live donor holds, fall back to the
        stored source table for sole-holder losses."""
        indices = tuple((placement.assignment or {}).get(server_id, ()))
        self._materialize(dataset, placement, server_id, indices, now_s,
                          action="reshard")

    def replicate(self, dataset: str, placement: _Placement, server_id: str,
                  *, now_s: float = 0.0) -> None:
        """Materialize a full replica on ``server_id`` (the join copy and
        the re-admit pre-warm), batch by batch from the best live donors."""
        table = placement.table
        if table is None:
            return
        self._materialize(dataset, placement, server_id,
                          tuple(range(len(table.batches))), now_s,
                          action="replicate")

    def _materialize(self, dataset: str, placement: _Placement,
                     server_id: str, indices: tuple[int, ...],
                     now_s: float, action: str) -> None:
        table = placement.table
        server = self.coordinator.servers.get(server_id)
        if table is None or server is None:
            return
        # the repair clock never runs behind the caller's modeled time
        self.stats.clock_s = max(self.stats.clock_s, now_s)
        trace = (self.tracer.begin(f"repair:{dataset}:{server_id}")
                 if self.tracer is not None else None)
        held = self._held.setdefault(dataset, {})
        mine = dict(held.get(server_id, {}))
        pulled = copied = reused = 0
        out: dict[int, RecordBatch] = {}
        for idx in indices:
            if idx in mine:
                out[idx] = mine[idx]       # already pinned here: zero movement
                reused += 1
                continue
            donor = self._pick_donor(dataset, idx, exclude=server_id)
            if donor is not None:
                out[idx] = self._peer_pull(dataset, server_id, donor, idx,
                                           trace)
                pulled += 1
            else:
                out[idx] = self._table_copy(dataset, server_id, table, idx,
                                            trace)
                copied += 1
        held[server_id] = out
        shard = Table(table.name, table.schema,
                      batches=[out[i] for i in indices])
        server.engine.register(dataset, shard)
        self.stats.repairs += 1
        self.stats.batches_reused += reused
        if trace is not None:
            trace.commit()
        self.coordinator.notify("repair.complete", server_id=server_id,
                                now_s=self.stats.clock_s, dataset=dataset,
                                action=action, pulled=pulled, copied=copied,
                                reused=reused)

    # ------------------------------------------------------------ data plane
    def _peer_pull(self, dataset: str, target_sid: str, donor_sid: str,
                   idx: int, trace) -> RecordBatch:
        """One batch over the registered fast path, donor → target."""
        donor = self.coordinator.servers[donor_sid]
        batch = self._held[dataset][donor_sid][idx]
        self._meter(donor_sid)
        # donor exposes its pinned shard buffers in place — zero copies
        remote = bulk_mod.expose_batch(batch, mode="read_only")
        # descriptor exchange: handle + the three size vectors
        rpc = donor.fabric.rpc(64 + 8 * 3 * len(batch.columns))
        pool = self._pool(target_sid)
        local = pool.acquire(remote.descs)
        try:
            wire = donor.fabric.rdma_pull(remote.segments, local.segments,
                                          registered=True)
        except BaseException:
            pool.release(local)
            raise
        out = bulk_mod.assemble_batch(batch.schema, batch.num_rows,
                                      local.segments)
        pool.adopt(local)      # the slabs ARE the shard's storage now
        wire_s = wire.modeled_wire_s + rpc.modeled_wire_s
        self.stats.batches_pulled += 1
        self.stats.bytes_pulled += wire.bytes_moved
        self.stats.segments_pulled += wire.num_segments
        self.stats.modeled_wire_s += wire_s
        if trace is not None:
            trace.span("repair.pull", self.stats.clock_s, wire_s,
                       cat="repair", donor=donor_sid, batch=idx)
        self.stats.clock_s += wire_s
        self.coordinator.notify("repair.pull", server_id=target_sid,
                                now_s=self.stats.clock_s, dataset=dataset,
                                donor=donor_sid, batch=idx,
                                nbytes=wire.bytes_moved)
        return out

    def _table_copy(self, dataset: str, target_sid: str, table: Table,
                    idx: int, trace) -> RecordBatch:
        """Durability fallback: no live peer holds the batch, so the
        coordinator streams its stored copy over the RPC payload path and
        the target pins fresh segments — the honest price of losing every
        registered holder."""
        batch = table.batches[idx]
        server = self.coordinator.servers[target_sid]
        wire = server.fabric.rpc(batch.nbytes)
        register_s = server.fabric.register(3 * len(batch.columns))
        cost = wire.modeled_wire_s + register_s
        self.stats.table_copies += 1
        self.stats.bytes_copied += batch.nbytes
        self.stats.modeled_copy_s += cost
        if trace is not None:
            trace.span("repair.copy", self.stats.clock_s, cost,
                       cat="repair", batch=idx)
        self.stats.clock_s += cost
        self.coordinator.notify("repair.fallback", server_id=target_sid,
                                now_s=self.stats.clock_s, dataset=dataset,
                                batch=idx, nbytes=int(batch.nbytes))
        return batch

    # ------------------------------------------------------------- metering
    def _meter(self, donor_sid: str) -> None:
        """Charge one pull to the donor's admission shard as background
        traffic: yield (modeled backoff) while the donor's token bucket sits
        below the foreground reserve, then lease the tokens and absorb the
        wait on the repair clock. Repair never consumes stream slots, so
        foreground admission quota is untouched."""
        admission = getattr(self.coordinator, "admission", None)
        if admission is None:
            return
        cfg = self.config
        shards = getattr(admission, "shards", None)
        if shards and donor_sid in shards:
            peek = shards[donor_sid].tokens_at
        else:
            peek = getattr(admission, "tokens_at", None)
        if peek is not None:
            for _ in range(cfg.max_yields_per_batch):
                if peek(self.stats.clock_s) >= (cfg.reserve_tokens
                                                + cfg.tokens_per_batch):
                    break
                self.stats.yields += 1
                self.stats.yield_s += cfg.backoff_s
                self.stats.clock_s += cfg.backoff_s
        wait = admission.lease_wait_s(self.stats.clock_s,
                                      cfg.tokens_per_batch,
                                      server_id=donor_sid)
        self.stats.throttle_wait_s += wait
        self.stats.clock_s += wait

    # --------------------------------------------------------------- pools
    def _pool(self, target_sid: str) -> BufferPool:
        """The target's registered pool: slab registrations are charged to
        the *target's* fabric once and amortized across every repair that
        lands there."""
        server = self.coordinator.servers[target_sid]
        pool = self.pools.get(target_sid)
        if pool is None or pool.fabric is not server.fabric:
            pool = BufferPool(server.fabric,
                              max_bytes=self.config.pool_max_bytes)
            self.pools[target_sid] = pool
        return pool
