"""Elastic membership: health verdicts drive server evict / re-admit.

The :class:`~repro.obs.health.HealthMonitor` (PR 7) already decides *how
sick* each server is; this module closes the loop by acting on the verdicts:

* a registered server whose health state reaches ``quarantined`` is
  **evicted** — :meth:`ClusterCoordinator.remove_server` repairs every
  placement naming it (replica drop / minimal-movement shard re-deal), the
  sharded admission controller absorbs its quota shard into the survivors,
  and the server object is stashed for later re-admission;
* a stashed server whose health has **recovered** (hysteretically stepped
  back down to ``degraded`` or better) and whose process is actually up
  (``not server.crashed``) is **re-admitted** —
  ``add_server(rebalance=True)`` puts it back to work and the admission
  layer spawns it a fresh quota shard.

Every transition funnels through ``coordinator.notify`` (``membership.evict``
/ ``membership.readmit``) so a nemesis postmortem can prove the causal chain
verdict → evict → migrate → re-admit beat by beat.

Like the rest of the cluster layer the controller is duck-typed on its
collaborators: ``health`` is anything with ``state(server_id) -> str``,
``admission`` anything with ``remove_shard``/``add_shard`` (a centralized
controller without them is simply left alone), so there is still no
cluster→qos or cluster→obs import.
"""
from __future__ import annotations

import dataclasses

from ..core.protocol import ThallusServer
from .coordinator import ClusterCoordinator

#: health states that keep a server in (or return it to) the serving set
SERVABLE_STATES = ("healthy", "degraded")
#: the health state that triggers eviction
EVICT_STATE = "quarantined"


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One membership transition, in modeled time."""

    action: str            # "evict" | "readmit"
    server_id: str
    now_s: float
    reason: str = ""


class MembershipController:
    """Heartbeat-driven evict/re-admit loop over health verdicts."""

    def __init__(self, coordinator: ClusterCoordinator, health,
                 admission=None) -> None:
        self.coordinator = coordinator
        self.health = health
        self.admission = admission
        self._evicted: dict[str, ThallusServer] = {}
        self.events: list[MembershipEvent] = []

    @property
    def evicted(self) -> tuple[str, ...]:
        """Servers currently out of the serving set, sorted."""
        return tuple(sorted(self._evicted))

    def heartbeat(self, now_s: float) -> list[MembershipEvent]:
        """One membership pass: evict newly-quarantined servers, re-admit
        recovered ones. Call *after* ``coordinator.heartbeat`` so this
        beat's health verdicts are already advanced. Returns the
        transitions made this beat."""
        fired: list[MembershipEvent] = []
        for sid in sorted(self.coordinator.servers):
            if self.health.state(sid) == EVICT_STATE:
                fired.append(self._evict(sid, now_s))
        for sid in sorted(self._evicted):
            server = self._evicted[sid]
            if getattr(server, "crashed", False):
                continue           # process still down: nothing to re-admit
            if self.health.state(sid) in SERVABLE_STATES:
                fired.append(self._readmit(sid, now_s))
        self.events.extend(fired)
        return fired

    def _evict(self, sid: str, now_s: float) -> MembershipEvent:
        server = self.coordinator.remove_server(sid, now_s=now_s)
        self._evicted[sid] = server
        if self.admission is not None:
            remove = getattr(self.admission, "remove_shard", None)
            if remove is not None and sid in getattr(self.admission,
                                                     "shards", {}):
                remove(sid, now_s=now_s)
        event = MembershipEvent("evict", sid, now_s,
                                reason=self.health.state(sid))
        self.coordinator.notify("membership.evict", server_id=sid,
                                now_s=now_s, reason=event.reason)
        return event

    def _readmit(self, sid: str, now_s: float) -> MembershipEvent:
        server = self._evicted.pop(sid)
        # the rebalance below IS the re-admit pre-warm: with a repairer
        # attached it pulls the joiner's slices/replicas peer-to-peer over
        # the registered RDMA path; attribute that movement to this event
        repairer = getattr(self.coordinator, "repairer", None)
        baseline = (dataclasses.replace(repairer.stats)
                    if repairer is not None else None)
        self.coordinator.add_server(sid, server, rebalance=True, now_s=now_s)
        if repairer is not None:
            warm = repairer.stats.delta_since(baseline)
            if warm.batches_pulled or warm.table_copies or warm.batches_reused:
                self.coordinator.notify(
                    "repair.prewarm", server_id=sid, now_s=now_s,
                    pulled=warm.batches_pulled, copied=warm.table_copies,
                    reused=warm.batches_reused, bytes=warm.bytes_pulled)
        if self.admission is not None:
            add = getattr(self.admission, "add_shard", None)
            if add is not None and sid not in getattr(self.admission,
                                                      "shards", {}):
                add(sid, now_s=now_s)
        event = MembershipEvent("readmit", sid, now_s,
                                reason=self.health.state(sid))
        self.coordinator.notify("membership.readmit", server_id=sid,
                                now_s=now_s, reason=event.reason)
        return event
