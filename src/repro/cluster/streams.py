"""Multi-stream puller: N concurrent resumable leases over one scan plan.

Each :class:`Endpoint` of a :class:`~repro.cluster.plan.ScanPlan` becomes a
:class:`StreamPuller` driving its own ``init_scan → iterate(lease) →
finalize`` loop. A :class:`MultiStreamPuller` interleaves the pullers with
bounded ``max_batches`` leases under one of two schedules:

* ``round_robin`` — deterministic rotation (the loader uses this so resume
  offsets are well-defined);
* ``first_ready`` — always lease from the stream whose modeled clock is
  furthest behind (first-ready-wins, the scheduling Arrow Flight clients use
  to keep parallel endpoints drained evenly).

Streams are independently fault-tolerant: an ``iterate`` that raises is
resumed through the coordinator (``init_scan(start_batch=delivered)``) up to
``max_resumes`` times, without disturbing the other streams.

Because the wire is modeled (no NIC here), concurrency is modeled too: each
stream accrues a **modeled clock** (its serial wire + measured client CPU
time), and :attr:`ClusterStats.critical_path_s` — the cluster's transport
duration — is the slowest stream's clock, while ``sum_total_s`` is the total
work. Both come from the same per-batch stats, so benchmark decompositions
for 1 stream and N streams share one code path.

Two flow-control behaviours ride on that clock:

* **async pipelining** (``prefetch=True``, the default): each stream keeps a
  one-deep prefetch slot, so the control/lease RPC for batch *k+1* is posted
  while the modeled RDMA pull of batch *k* is in flight. The hidden portion
  is recorded as ``prefetch_overlap_s`` and the stream clock only pays the
  remainder — turning prefetch off shows the full serial RPC cost in
  ``critical_path_s``.
* **backpressure reporting**: when the coordinator carries a
  ``qos.AdmissionController``, every lease grant asks its token bucket for a
  token; a throttled grant's modeled wait is charged to the stream clock and
  surfaced as ``throttle_wait_s`` — the signal the qos layer aggregates.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Iterator

from ..core import bulk as bulk_mod
from ..core.recordbatch import RecordBatch
from ..core.transport import TransportStats, rdma_pull_batch
from .coordinator import ClusterCoordinator
from .mempool import BufferPool, PoolStats
from .plan import Endpoint, ScanPlan


def notify_coordinator(coordinator, kind: str, **kw) -> None:
    """Forward one decision to ``coordinator.notify`` when it exists — the
    observability funnel is optional and coordinators are duck-typed in
    tests, so emission sites never assume the method."""
    notify = getattr(coordinator, "notify", None)
    if notify is not None:
        notify(kind, **kw)


@dataclasses.dataclass
class StreamStats:
    """Per-stream fabric-level counters + timing decomposition."""

    server_id: str = ""
    batches: int = 0
    bytes: int = 0
    segments: int = 0
    rdma_ops: int = 0
    control_rpcs: int = 0
    resumes: int = 0
    migrations: int = 0             # leases failed over to another replica
    alloc_s: float = 0.0            # measured: pool checkout or fresh alloc
    deserialize_s: float = 0.0      # measured: zero-copy assembly
    modeled_wire_s: float = 0.0
    modeled_register_s: float = 0.0  # per-pull registration actually charged
    control_rpc_s: float = 0.0      # modeled lease/control RPC time charged
    prefetch_overlap_s: float = 0.0  # control RPC hidden under prior pulls
    throttle_wait_s: float = 0.0    # admission token-bucket wait charged
    clock_s: float = 0.0            # this stream's serial transport time
    start_s: float = 0.0            # modeled epoch the stream began (a stolen
    #                                 stream starts mid-scan, not at t=0)
    parks: int = 0                  # lease-boundary preemptions survived


@dataclasses.dataclass
class ClusterStats:
    """Aggregate view over all streams of one partitioned scan."""

    query_id: str = ""
    placement: str = ""
    streams: list[StreamStats] = dataclasses.field(default_factory=list)
    pool: PoolStats | None = None
    # work-stealing audit trail (repro.sched.StealEvent instances; kept
    # duck-typed so cluster does not import sched)
    steal_events: list = dataclasses.field(default_factory=list)

    @property
    def steals(self) -> int:
        """Ranges moved to an idle replica. Events are kind-tagged
        (``steal`` / ``decline`` / ``re_steal``, see
        ``repro.sched.StealEvent``); an untagged event is a steal."""
        return sum(1 for e in self.steal_events
                   if getattr(e, "kind", "steal") == "steal")

    @property
    def declines(self) -> int:
        """Steals refused because the thief's admission shard was full."""
        return sum(1 for e in self.steal_events
                   if getattr(e, "kind", "steal") == "decline")

    @property
    def re_steals(self) -> int:
        """Tails reclaimed by their original victim from a degraded thief."""
        return sum(1 for e in self.steal_events
                   if getattr(e, "kind", "steal") == "re_steal")

    def steal_attribution(self) -> dict:
        """Per-shard decision counts: ``server_id -> {kind: count,
        "batches": moved}`` (``batches`` counts ranges that actually moved —
        steals and re-steals; declines moved nothing). Every event carries
        the shard it landed on (``StealEvent.server_id`` — the thief's
        shard for a steal, the refusing shard for a decline, the reclaiming
        shard for a re-steal); events recorded before the field existed are
        backfilled from their ``thief``, so old traces still attribute.
        ``utils/report.steal_table`` renders this."""
        out: dict = {}
        for e in self.steal_events:
            sid = getattr(e, "server_id", "") or getattr(e, "thief", "?")
            kind = getattr(e, "kind", "steal")
            per = out.setdefault(sid, {"batches": 0})
            per[kind] = per.get(kind, 0) + 1
            if kind != "decline":
                per["batches"] += e.num_batches
        return out

    @property
    def parks(self) -> int:
        return sum(s.parks for s in self.streams)

    @property
    def batches(self) -> int:
        return sum(s.batches for s in self.streams)

    @property
    def bytes(self) -> int:
        return sum(s.bytes for s in self.streams)

    @property
    def alloc_s(self) -> float:
        return sum(s.alloc_s for s in self.streams)

    @property
    def deserialize_s(self) -> float:
        return sum(s.deserialize_s for s in self.streams)

    @property
    def modeled_wire_s(self) -> float:
        return sum(s.modeled_wire_s for s in self.streams)

    @property
    def modeled_register_s(self) -> float:
        """Registration cost actually charged on pulls, plus the pool's
        one-time slab pinning (amortized across every batch it served)."""
        charged = sum(s.modeled_register_s for s in self.streams)
        if self.pool is not None:
            charged += self.pool.modeled_register_s
        return charged

    @property
    def control_rpc_s(self) -> float:
        return sum(s.control_rpc_s for s in self.streams)

    @property
    def prefetch_overlap_s(self) -> float:
        """Lease-RPC time hidden under RDMA pulls by the prefetch slot —
        the critical path shrinks by exactly the slowest stream's share."""
        return sum(s.prefetch_overlap_s for s in self.streams)

    @property
    def throttle_wait_s(self) -> float:
        return sum(s.throttle_wait_s for s in self.streams)

    @property
    def resumes(self) -> int:
        return sum(s.resumes for s in self.streams)

    @property
    def migrations(self) -> int:
        """Leases that failed over to a surviving replica mid-scan."""
        return sum(s.migrations for s in self.streams)

    @property
    def sum_total_s(self) -> float:
        """Total transport work across streams (serial equivalent)."""
        return sum(s.clock_s for s in self.streams)

    @property
    def critical_path_s(self) -> float:
        """Cluster transport duration: streams run concurrently, so the scan
        finishes when the slowest stream does. Includes each stream's
        measured client CPU time (alloc/assembly), so it is wall-clock-noisy;
        use :attr:`modeled_critical_path_s` for deterministic comparisons.
        A stream's finish time is its start epoch plus its own clock — a
        stolen stream begins mid-scan, so its ``start_s`` is nonzero."""
        return max((s.start_s + s.clock_s for s in self.streams), default=0.0)

    @property
    def modeled_critical_path_s(self) -> float:
        """Slowest stream by modeled wire time only — a pure function of
        bytes/segments/ops, reproducible under any machine load."""
        return max((s.start_s + s.modeled_wire_s for s in self.streams),
                   default=0.0)


class StreamPuller:
    """One endpoint's resumable lease-driven pull loop."""

    def __init__(self, coordinator: ClusterCoordinator, endpoint: Endpoint,
                 pool: BufferPool | None = None, max_resumes: int = 3,
                 prefetch: bool = True, client_id: str = "default",
                 trace=None):
        self.coordinator = coordinator
        self.endpoint = endpoint
        self.pool = pool
        self.max_resumes = max_resumes
        self.prefetch = prefetch
        self.client_id = client_id
        self.trace = trace              # obs.StreamTrace, local-clock spans
        self.stats = StreamStats(server_id=endpoint.server_id)
        self.delivered = 0
        self.drained = False
        self.parked = False
        self._prefetch_budget_s = 0.0   # prior pull's wire time still hideable
        try:
            self.server = coordinator.server(endpoint.server_id)
            self._handle = coordinator.open_stream(endpoint,
                                                   client_id=client_id,
                                                   trace=trace, now_s=0.0)
        except (KeyError, ConnectionError):
            # the plan named a server that left/crashed between planning and
            # open — migrate the stream before it ever starts. No admission
            # slot is held yet (open_stream released on failure), and
            # qos.Backpressure is not a connection fault, so it propagates.
            failover = getattr(coordinator, "failover_stream", None)
            if failover is None:
                raise
            self.endpoint, self._handle = failover(endpoint, 0, client_id,
                                                   slot_held=False)
            self.server = coordinator.server(self.endpoint.server_id)
            self.stats.server_id = self.endpoint.server_id
            self.stats.migrations += 1
        self._lease_out: list[tuple[RecordBatch, bulk_mod.BulkHandle | None]] = []

    # ----------------------------------------------------------- remaining
    @property
    def remaining(self) -> int | None:
        """Batches still owed by this stream's bounded range (``None`` for an
        unbounded drain-to-end endpoint)."""
        if self.endpoint.max_batches is None:
            return None
        return max(0, self.endpoint.max_batches - self.delivered)

    # ----------------------------------------------------------- split hook
    def split(self, keep_batches: int) -> tuple[int, int]:
        """Work-stealing split at a lease boundary: truncate this stream's
        bounded range to ``delivered + keep_batches`` and return the tail as
        a global ``(start_batch, num_batches)`` range for the thief to
        re-lease via ``init_scan(start_batch=…)``. Pure client-side
        bookkeeping — the victim's server reader simply stops being asked
        past the truncated range."""
        remaining = self.remaining
        if remaining is None:
            raise ValueError("cannot split an unbounded stream")
        if not 0 <= keep_batches < remaining:
            raise ValueError(
                f"keep_batches={keep_batches} outside [0, {remaining})")
        tail_start = (self.endpoint.start_batch + self.delivered
                      + keep_batches)
        tail_count = remaining - keep_batches
        self.endpoint = dataclasses.replace(
            self.endpoint, max_batches=self.delivered + keep_batches)
        return tail_start, tail_count

    # ----------------------------------------------------- park/unpark hooks
    def park(self) -> None:
        """Lease-boundary preemption: release the server lease (and the
        admission slot it holds) and checkpoint the resume offset. The
        stream stays logically alive — :meth:`unpark` re-opens it where it
        stopped. Call only between leases (never with a lease in flight)."""
        if self.drained or self.parked:
            return
        self.parked = True
        self.stats.parks += 1
        self._prefetch_budget_s = 0.0    # the pipeline is cold after a park
        if self.trace is not None:
            self.trace.instant("stream.park", self.stats.clock_s, cat="sched")
        notify_coordinator(self.coordinator, "stream.park",
                           server_id=self.endpoint.server_id,
                           now_s=self.stats.clock_s,
                           delivered=self.delivered)
        # no now_s: the stream clock is scan-relative, not on the admission
        # controller's timeline — release listeners stamp their own clocks
        self.coordinator.close_stream(self.endpoint, self._handle.uuid,
                                      client_id=self.client_id)
        self._handle = None

    def unpark(self) -> None:
        """Resume a parked stream: a fresh admission-gated lease fast-
        forwarded past everything already delivered (may raise
        ``qos.Backpressure`` — the slot was given back at park time)."""
        if self.drained or not self.parked:
            return
        self._handle = self.coordinator.reopen_stream(
            self.endpoint, self.delivered, client_id=self.client_id)
        self.parked = False
        if self.trace is not None:
            self.trace.instant("stream.unpark", self.stats.clock_s,
                               cat="sched")
        notify_coordinator(self.coordinator, "stream.unpark",
                           server_id=self.endpoint.server_id,
                           now_s=self.stats.clock_s,
                           delivered=self.delivered)

    # ------------------------------------------------------------- do_rdma
    def _do_rdma(self, num_rows: int, sizes, remote: bulk_mod.BulkHandle
                 ) -> TransportStats:
        # pin=True (no-pool path): fault pages in, the per-batch cost
        # registration pays and the pool amortizes
        batch, local, stats = rdma_pull_batch(
            self.server.fabric, self._handle.schema, num_rows, remote,
            pool=self.pool, pin=True)
        s = self.stats
        # the per-batch control message (descriptor RPC) the server charges
        # to the fabric; with the prefetch slot armed, the RPC for this batch
        # was posted while the previous batch's RDMA pull was in flight, so
        # only the un-hidden remainder lands on the stream clock
        cfg = self.server.fabric.config
        meta_bytes = 64 + 8 * sum(len(v) for v in sizes)
        rpc_s = cfg.rpc_rtt_s + meta_bytes / cfg.rpc_bw
        hidden = (min(rpc_s, self._prefetch_budget_s)
                  if self.prefetch and s.batches > 0 else 0.0)
        self._prefetch_budget_s = stats.wire.modeled_wire_s
        if self.trace is not None:
            # the spans partition this pull's clock advance exactly:
            # rpc_u + alloc + rdma + assemble == stats.total_s + rpc_u
            t0 = s.clock_s
            rpc_u = rpc_s - hidden
            wire_s = stats.wire.modeled_wire_s
            self.trace.span("lease.rpc", t0, rpc_u, cat="lease",
                            meta_bytes=meta_bytes)
            self.trace.span("alloc", t0 + rpc_u, stats.alloc_s, cat="alloc")
            self.trace.span("rdma.pull", t0 + rpc_u + stats.alloc_s, wire_s,
                            cat="rdma", bytes=stats.wire.bytes_moved,
                            segments=stats.wire.num_segments)
            self.trace.span("assemble", t0 + rpc_u + stats.alloc_s + wire_s,
                            stats.total_s - stats.alloc_s - wire_s,
                            cat="assemble")
            if hidden > 0.0:
                # off the critical path: the slice of this batch's control
                # RPC hidden under the previous pull, on its own lane
                self.trace.span("prefetch.overlap", t0 - hidden, hidden,
                                cat="prefetch", track_suffix=".prefetch")
        s.batches += 1
        s.bytes += stats.wire.bytes_moved
        s.segments += stats.wire.num_segments
        s.rdma_ops += 1
        s.control_rpcs += 1
        s.alloc_s += stats.alloc_s
        s.deserialize_s += stats.deserialize_s
        s.modeled_wire_s += stats.wire.modeled_wire_s
        s.modeled_register_s += stats.wire.modeled_register_s
        s.control_rpc_s += rpc_s - hidden
        s.prefetch_overlap_s += hidden
        s.clock_s += stats.total_s + (rpc_s - hidden)
        self._lease_out.append(
            (batch, local if self.pool is not None else None))
        return stats

    # --------------------------------------------------------------- lease
    def pull_lease(self, lease_batches: int
                   ) -> list[tuple[RecordBatch, bulk_mod.BulkHandle | None]]:
        """Pull up to ``lease_batches`` batches; empty list == drained.
        Returns (batch, pooled_handle) pairs — the caller owns releasing the
        handles back to the pool once the batch is consumed."""
        if self.drained:
            return []
        if self.parked:
            raise RuntimeError("stream is parked; unpark() before pulling")
        if self.endpoint.max_batches is not None:
            lease_batches = min(
                lease_batches, self.endpoint.max_batches - self.delivered)
            if lease_batches <= 0:
                self._finish()
                return []
        admission = self.coordinator.admission
        if admission is not None:
            # token-bucket lease metering: a throttled grant charges its
            # modeled wait to this stream's clock (backpressure signal).
            # Routed per server so a sharded controller meters this lease
            # against the endpoint's own bucket shard.
            wait = admission.lease_wait_s(self.stats.clock_s, 1,
                                          server_id=self.endpoint.server_id)
            if wait > 0.0 and self.trace is not None:
                self.trace.span("admission.throttle", self.stats.clock_s,
                                wait, cat="admission")
            self.stats.throttle_wait_s += wait
            self.stats.clock_s += wait
        self._lease_out = []
        for attempt in range(self.max_resumes + 1):
            try:
                self.server.iterate(
                    self._handle.uuid, self._do_rdma,
                    max_batches=lease_batches - len(self._lease_out))
                break
            except Exception:
                if attempt == self.max_resumes:
                    raise
                # resume just this stream where it died: batches that landed
                # before the fault stay delivered, the lease pulls the rest
                self.stats.resumes += 1
                delivered = self.delivered + len(self._lease_out)
                notify_coordinator(
                    self.coordinator, "stream.fault",
                    server_id=self.endpoint.server_id,
                    now_s=self.stats.clock_s,
                    delivered=delivered)
                failover = getattr(self.coordinator, "failover_stream", None)
                if failover is None:
                    self._handle = self.coordinator.resume_stream(
                        self.endpoint, delivered)
                    continue
                # same-server resume when the server is alive; otherwise the
                # lease fails over to a surviving replica mid-flight — the
                # delivered prefix stays delivered, only the tail re-targets
                old_sid = self.endpoint.server_id
                self.endpoint, self._handle = failover(
                    self.endpoint, delivered, self.client_id,
                    now_s=self.stats.clock_s)
                if self.endpoint.server_id != old_sid:
                    self.server = self.coordinator.server(
                        self.endpoint.server_id)
                    self.stats.server_id = self.endpoint.server_id
                    self.stats.migrations += 1
                    self._prefetch_budget_s = 0.0  # cold pipe on new server
        self.delivered += len(self._lease_out)
        if not self._lease_out:
            self._finish()
        return self._lease_out

    def _finish(self) -> None:
        if not self.drained:
            self.drained = True
            if self.parked:      # lease already released at park time
                self.parked = False
                return
            self.coordinator.close_stream(self.endpoint, self._handle.uuid,
                                          client_id=self.client_id,
                                          trace=self.trace,
                                          trace_now_s=self.stats.clock_s)


class MultiStreamPuller:
    """Drive every endpoint of a plan with bounded leases."""

    def __init__(self, coordinator: ClusterCoordinator, plan: ScanPlan,
                 pool: BufferPool | None = None, lease_batches: int = 1,
                 schedule: str = "round_robin", max_resumes: int = 3,
                 prefetch: bool = True, client_id: str = "default",
                 trace=None):
        if schedule not in ("round_robin", "first_ready"):
            raise ValueError(f"unknown schedule {schedule!r}")
        self.coordinator = coordinator
        self.plan = plan
        self.pool = pool
        # snapshot so stats() reports only THIS scan's pool activity even
        # when the pool is shared across many scans (gateway traffic)
        self._pool_baseline = (dataclasses.replace(pool.stats)
                               if pool is not None else None)
        self.lease_batches = lease_batches
        self.schedule = schedule
        self.trace = trace             # obs.TraceContext for the whole scan
        self.steal_events: list = []   # appended by repro.sched drivers
        self.pullers: list[StreamPuller] = []
        try:
            for i, ep in enumerate(plan.endpoints):
                self.pullers.append(
                    StreamPuller(coordinator, ep, pool=pool,
                                 max_resumes=max_resumes, prefetch=prefetch,
                                 client_id=client_id,
                                 trace=self._stream_trace(i, ep)))
        except BaseException:
            # an admission denial (or open failure) partway through the
            # fan-out must not leak the streams that did open
            self._abandon()
            raise

    def _stream_trace(self, idx: int, endpoint: Endpoint):
        """A per-stream child trace (own track + shift-group), or None
        when the scan is untraced."""
        if self.trace is None:
            return None
        return self.trace.stream(f"stream{idx}:{endpoint.server_id}")

    # ----------------------------------------------------------- iteration
    def batches(self) -> Iterator[tuple[int, RecordBatch]]:
        """Yield ``(stream_index, batch)`` in schedule order.

        With a pool, a yielded batch's buffers are recycled when iteration
        resumes — consume or copy it before advancing (streaming contract)."""
        pending: bulk_mod.BulkHandle | None = None
        try:
            for idx, batch, handle in self._drive():
                if pending is not None:
                    self.pool.release(pending)
                pending = handle
                yield idx, batch
        finally:
            if pending is not None:
                self.pool.release(pending)

    def run(self, sink: Callable[[int, RecordBatch], None] | None = None
            ) -> ClusterStats:
        """Drain every stream; optionally hand each batch to ``sink``."""
        for idx, batch, handle in self._drive():
            try:
                if sink is not None:
                    sink(idx, batch)
            finally:
                if handle is not None:
                    self.pool.release(handle)
        return self.stats()

    def _drive(self) -> Iterator[tuple[int, RecordBatch,
                                       bulk_mod.BulkHandle | None]]:
        try:
            if self.schedule == "round_robin":
                active = list(range(len(self.pullers)))
                while active:
                    still = []
                    for idx in active:
                        yield from self._lease(idx)
                        if not self.pullers[idx].drained:
                            still.append(idx)
                    active = still
            else:  # first_ready: lease from the stream furthest behind
                heap = [(0.0, idx) for idx in range(len(self.pullers))]
                heapq.heapify(heap)
                while heap:
                    _, idx = heapq.heappop(heap)
                    yield from self._lease(idx)
                    if not self.pullers[idx].drained:
                        heapq.heappush(
                            heap, (self.pullers[idx].stats.clock_s, idx))
        finally:
            self._abandon()    # no-op on a fully drained run

    def _lease(self, idx: int) -> Iterator[tuple[int, RecordBatch,
                                                 bulk_mod.BulkHandle | None]]:
        # pull_lease returns the puller's live _lease_out list; popping as we
        # yield means anything still in it was never handed to the consumer
        out = self.pullers[idx].pull_lease(self.lease_batches)
        while out:
            batch, handle = out.pop(0)
            yield idx, batch, handle

    def _abandon(self) -> None:
        """Consumer walked away mid-scan: release pooled handles for batches
        it never saw and finalize every still-open lease, so abandoned scans
        don't leak slabs or reader-map entries."""
        for puller in self.pullers:
            for _, handle in puller._lease_out:
                if handle is not None:
                    self.pool.release(handle)
            puller._lease_out = []
            puller._finish()

    # -------------------------------------------------------------- stats
    def stats(self) -> ClusterStats:
        return ClusterStats(
            query_id=self.plan.query_id, placement=self.plan.placement,
            streams=[p.stats for p in self.pullers],
            pool=(self.pool.stats.delta_since(self._pool_baseline)
                  if self.pool is not None else None),
            steal_events=list(self.steal_events))
