"""Registered buffer pool: pre-pinned size-class slabs + a registration cache.

The paper's own cost decomposition makes two per-batch constants the enemy of
small result sets: the client-side buffer allocation (``alloc_s``, measured)
and the per-segment registration (``seg_register_s``, modeled) charged on
every RDMA pull. Real RDMA systems amortize both the same way ("High-Speed
Query Processing over High-Speed Networks", arXiv:1502.07169): allocate and
register buffers *once*, then recycle them. This module does exactly that:

* slabs are uint8 arrays rounded up to power-of-two **size classes**, created
  (and faulted in — registration pins pages) on first miss;
* ``acquire(descs)`` checks out one slab per segment and returns a
  write-only :class:`~repro.core.bulk.BulkHandle` whose segments are dtype
  views into the slabs, flagged ``registered=True``;
* ``release(handle)`` returns the slabs to their free lists, so the next
  ``acquire`` with a similar layout is a list-pop, not a malloc;
* each slab's registration is charged to the fabric **once** (via
  :meth:`Fabric.register`); pulls into pooled buffers then take the
  ``registered=True`` fast path of :meth:`Fabric.rdma_pull` and skip the
  per-segment term entirely;
* resident slab bytes are bounded by an optional global **memory budget**
  (``max_bytes``): when creating a slab pushes the pool over budget, the
  least-recently-released free slabs are evicted — dropped *and
  unregistered* (:meth:`Fabric.unregister`), since a pinned-but-idle slab is
  exactly the registered memory an admission controller must reclaim. Slabs
  checked out to in-flight pulls are never evicted, so the budget is a
  high-water mark the pool converges back under as handles are released.
"""
from __future__ import annotations

import dataclasses
import time
import uuid as _uuid
from typing import Sequence

import numpy as np

from ..core.bulk import BulkHandle, SegmentDesc
from ..core.fabric import Fabric

_MIN_CLASS = 64  # bytes; keeps tiny validity/offset segments from fragmenting


def size_class(nbytes: int) -> int:
    """Round up to the pool's power-of-two size class."""
    if nbytes <= _MIN_CLASS:
        return _MIN_CLASS
    return 1 << (int(nbytes) - 1).bit_length()


@dataclasses.dataclass
class PoolStats:
    hits: int = 0                   # checkouts served from a free list
    misses: int = 0                 # checkouts that had to create a slab
    slabs_created: int = 0
    bytes_pooled: int = 0           # total slab bytes ever created
    bytes_resident: int = 0         # live slab bytes (free + checked out)
    evictions: int = 0              # slabs dropped + unregistered
    bytes_evicted: int = 0
    adopted: int = 0                # slabs promoted to long-lived storage
    bytes_adopted: int = 0
    registered_segments: int = 0    # slabs currently pinned with the fabric
    modeled_register_s: float = 0.0  # one-time pinning cost (amortized)
    acquire_s: float = 0.0          # measured wall time inside acquire()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def delta_since(self, baseline: "PoolStats") -> "PoolStats":
        """This pool's activity since ``baseline`` (a ``replace()`` copy
        taken earlier). Counters are subtracted; the two *levels* —
        ``bytes_resident`` and ``registered_segments`` — stay current.
        A scan over a shared pool attributes exactly its own slab creation
        (and registration cost) this way, instead of re-reporting the
        pool's whole cumulative history per scan."""
        return PoolStats(
            hits=self.hits - baseline.hits,
            misses=self.misses - baseline.misses,
            slabs_created=self.slabs_created - baseline.slabs_created,
            bytes_pooled=self.bytes_pooled - baseline.bytes_pooled,
            bytes_resident=self.bytes_resident,
            evictions=self.evictions - baseline.evictions,
            bytes_evicted=self.bytes_evicted - baseline.bytes_evicted,
            adopted=self.adopted - baseline.adopted,
            bytes_adopted=self.bytes_adopted - baseline.bytes_adopted,
            registered_segments=self.registered_segments,
            modeled_register_s=(self.modeled_register_s
                                - baseline.modeled_register_s),
            acquire_s=self.acquire_s - baseline.acquire_s)


class BufferPool:
    """Size-class pool of pre-registered client buffers.

    ``fabric`` is optional: without one the pool still recycles memory, it
    just has nothing to charge registrations to (unit tests use this).
    """

    def __init__(self, fabric: Fabric | None = None,
                 max_free_per_class: int = 64,
                 max_bytes: int | None = None):
        self.fabric = fabric
        self.max_free_per_class = max_free_per_class
        self.max_bytes = max_bytes
        self.stats = PoolStats()
        self._free: dict[int, list[np.ndarray]] = {}
        self._checked_out: dict[str, list[np.ndarray]] = {}
        self._lru_seq = 0
        self._release_seq: dict[int, int] = {}   # id(slab) -> release order
        # optional obs.FlightRecorder (duck-typed): evictions land in the
        # postmortem ring when one is attached
        self.recorder = None

    # ----------------------------------------------------------- checkout
    def _slab(self, cls: int) -> np.ndarray:
        free = self._free.get(cls)
        if free:
            self.stats.hits += 1
            slab = free.pop()
            self._release_seq.pop(id(slab), None)
            return slab
        self.stats.misses += 1
        self.stats.slabs_created += 1
        self.stats.bytes_pooled += cls
        self.stats.bytes_resident += cls
        slab = np.zeros(cls, dtype=np.uint8)   # zeros == fault pages in (pin)
        if self.fabric is not None:
            self.stats.modeled_register_s += self.fabric.register(1)
        self.stats.registered_segments += 1
        self._evict_over_budget()
        return slab

    def acquire(self, descs: Sequence[SegmentDesc]) -> BulkHandle:
        """Pool-backed ``allocate_like``: same layout, recycled memory."""
        t0 = time.perf_counter()
        slabs = [self._slab(size_class(d.nbytes)) for d in descs]
        segs = tuple(s[:d.nbytes].view(d.dtype)
                     for s, d in zip(slabs, descs))
        handle = BulkHandle(str(_uuid.uuid4()), tuple(descs), "write_only",
                            segments=segs, registered=True)
        self._checked_out[handle.handle_id] = slabs
        self.stats.acquire_s += time.perf_counter() - t0
        return handle

    # ------------------------------------------------------------ release
    def release(self, handle: BulkHandle) -> None:
        """Return a checked-out handle's slabs to the free lists. The
        handle's segments (and any batch assembled from them) must not be
        read afterwards — the memory will be recycled."""
        slabs = self._checked_out.pop(handle.handle_id, None)
        if slabs is None:
            raise KeyError(f"handle {handle.handle_id!r} not checked out")
        for slab in slabs:
            free = self._free.setdefault(slab.nbytes, [])
            if len(free) < self.max_free_per_class:
                self._lru_seq += 1
                self._release_seq[id(slab)] = self._lru_seq
                free.append(slab)
            else:
                self._drop(slab)     # class list full: evict outright
        self._evict_over_budget()

    def adopt(self, handle: BulkHandle) -> None:
        """Promote a checked-out handle's slabs to long-lived storage: they
        leave the checkout ledger *without* returning to the free lists, so
        the batch assembled from them stays valid forever (a repaired
        shard's resident memory). The slabs stay registered and keep
        counting toward ``bytes_resident``, but — like checkouts — they are
        never evicted: only ``_drop``-able free slabs are budget fodder."""
        slabs = self._checked_out.pop(handle.handle_id, None)
        if slabs is None:
            raise KeyError(f"handle {handle.handle_id!r} not checked out")
        self.stats.adopted += len(slabs)
        self.stats.bytes_adopted += sum(s.nbytes for s in slabs)

    # ------------------------------------------------------------ eviction
    def _drop(self, slab: np.ndarray) -> None:
        """Unpin one slab and forget it (memory goes back to the OS)."""
        self._release_seq.pop(id(slab), None)
        self.stats.evictions += 1
        self.stats.bytes_evicted += slab.nbytes
        self.stats.bytes_resident -= slab.nbytes
        self.stats.registered_segments -= 1
        if self.fabric is not None:
            self.fabric.unregister(1)
        if self.recorder is not None:
            self.recorder.record("pool.eviction", nbytes=int(slab.nbytes),
                                 resident=int(self.stats.bytes_resident))

    def _evict_over_budget(self) -> None:
        """LRU eviction: while resident bytes exceed the budget, drop the
        least-recently-released free slab (any size class). Checked-out
        slabs are untouchable, so an over-budget pool with everything in
        flight converges back under budget as handles are released."""
        if self.max_bytes is None:
            return
        while self.stats.bytes_resident > self.max_bytes:
            victim: tuple[int, int, int] | None = None   # (seq, cls, index)
            for cls, lst in self._free.items():
                for i, slab in enumerate(lst):
                    seq = self._release_seq.get(id(slab), 0)
                    if victim is None or seq < victim[0]:
                        victim = (seq, cls, i)
            if victim is None:
                return     # nothing free to evict right now
            _, cls, i = victim
            self._drop(self._free[cls].pop(i))

    # ---------------------------------------------------------- inspection
    @property
    def outstanding(self) -> int:
        return len(self._checked_out)

    def free_bytes(self) -> int:
        return sum(s.nbytes for lst in self._free.values() for s in lst)
