"""Registered buffer pool: pre-pinned size-class slabs + a registration cache.

The paper's own cost decomposition makes two per-batch constants the enemy of
small result sets: the client-side buffer allocation (``alloc_s``, measured)
and the per-segment registration (``seg_register_s``, modeled) charged on
every RDMA pull. Real RDMA systems amortize both the same way ("High-Speed
Query Processing over High-Speed Networks", arXiv:1502.07169): allocate and
register buffers *once*, then recycle them. This module does exactly that:

* slabs are uint8 arrays rounded up to power-of-two **size classes**, created
  (and faulted in — registration pins pages) on first miss;
* ``acquire(descs)`` checks out one slab per segment and returns a
  write-only :class:`~repro.core.bulk.BulkHandle` whose segments are dtype
  views into the slabs, flagged ``registered=True``;
* ``release(handle)`` returns the slabs to their free lists, so the next
  ``acquire`` with a similar layout is a list-pop, not a malloc;
* each slab's registration is charged to the fabric **once** (via
  :meth:`Fabric.register`); pulls into pooled buffers then take the
  ``registered=True`` fast path of :meth:`Fabric.rdma_pull` and skip the
  per-segment term entirely.
"""
from __future__ import annotations

import dataclasses
import time
import uuid as _uuid
from typing import Sequence

import numpy as np

from ..core.bulk import BulkHandle, SegmentDesc
from ..core.fabric import Fabric

_MIN_CLASS = 64  # bytes; keeps tiny validity/offset segments from fragmenting


def size_class(nbytes: int) -> int:
    """Round up to the pool's power-of-two size class."""
    if nbytes <= _MIN_CLASS:
        return _MIN_CLASS
    return 1 << (int(nbytes) - 1).bit_length()


@dataclasses.dataclass
class PoolStats:
    hits: int = 0                   # checkouts served from a free list
    misses: int = 0                 # checkouts that had to create a slab
    slabs_created: int = 0
    bytes_pooled: int = 0           # total slab bytes ever created
    registered_segments: int = 0    # slabs pinned with the fabric
    modeled_register_s: float = 0.0  # one-time pinning cost (amortized)
    acquire_s: float = 0.0          # measured wall time inside acquire()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class BufferPool:
    """Size-class pool of pre-registered client buffers.

    ``fabric`` is optional: without one the pool still recycles memory, it
    just has nothing to charge registrations to (unit tests use this).
    """

    def __init__(self, fabric: Fabric | None = None,
                 max_free_per_class: int = 64):
        self.fabric = fabric
        self.max_free_per_class = max_free_per_class
        self.stats = PoolStats()
        self._free: dict[int, list[np.ndarray]] = {}
        self._checked_out: dict[str, list[np.ndarray]] = {}

    # ----------------------------------------------------------- checkout
    def _slab(self, cls: int) -> np.ndarray:
        free = self._free.get(cls)
        if free:
            self.stats.hits += 1
            return free.pop()
        self.stats.misses += 1
        self.stats.slabs_created += 1
        self.stats.bytes_pooled += cls
        slab = np.zeros(cls, dtype=np.uint8)   # zeros == fault pages in (pin)
        if self.fabric is not None:
            self.stats.modeled_register_s += self.fabric.register(1)
        self.stats.registered_segments += 1
        return slab

    def acquire(self, descs: Sequence[SegmentDesc]) -> BulkHandle:
        """Pool-backed ``allocate_like``: same layout, recycled memory."""
        t0 = time.perf_counter()
        slabs = [self._slab(size_class(d.nbytes)) for d in descs]
        segs = tuple(s[:d.nbytes].view(d.dtype)
                     for s, d in zip(slabs, descs))
        handle = BulkHandle(str(_uuid.uuid4()), tuple(descs), "write_only",
                            segments=segs, registered=True)
        self._checked_out[handle.handle_id] = slabs
        self.stats.acquire_s += time.perf_counter() - t0
        return handle

    # ------------------------------------------------------------ release
    def release(self, handle: BulkHandle) -> None:
        """Return a checked-out handle's slabs to the free lists. The
        handle's segments (and any batch assembled from them) must not be
        read afterwards — the memory will be recycled."""
        slabs = self._checked_out.pop(handle.handle_id, None)
        if slabs is None:
            raise KeyError(f"handle {handle.handle_id!r} not checked out")
        for slab in slabs:
            free = self._free.setdefault(slab.nbytes, [])
            if len(free) < self.max_free_per_class:
                free.append(slab)

    # ---------------------------------------------------------- inspection
    @property
    def outstanding(self) -> int:
        return len(self._checked_out)

    def free_bytes(self) -> int:
        return sum(s.nbytes for lst in self._free.values() for s in lst)
