"""A deliberately small SQL front-end.

Grammar (case-insensitive keywords)::

    query   := SELECT select_list FROM ident [WHERE expr] [LIMIT int]
    select  := '*' | item (',' item)*
    item    := ident | agg '(' (ident|'*') ')'
    agg     := SUM | MIN | MAX | COUNT | AVG
    expr    := or_expr
    or      := and (OR and)*
    and     := unary (AND unary)*
    unary   := NOT unary | cmp
    cmp     := add (op add)? | add IS [NOT] NULL
    add     := mul (('+'|'-') mul)*
    mul     := atom (('*'|'/'|'%') atom)*
    atom    := number | string | ident | '(' expr ')'

Enough for every query shape in the paper's evaluation (column-selectivity
SELECTs, filtered scans, simple aggregates) without dragging in a parser dep.
"""
from __future__ import annotations

import dataclasses
import re

from .expressions import BinOp, Col, Expr, IsNull, Lit, Not

_TOKEN = re.compile(r"""
    \s*(?:
      (?P<num>(?:\d+\.\d*|\.\d+|\d+)(?:[eE][+-]?\d+)?)
    | (?P<str>'(?:[^']|'')*')
    | (?P<ident>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<op><=|>=|!=|<>|==|[-+*/%(),=<>])
    | (?P<star>\*)
    )""", re.VERBOSE)

_KEYWORDS = {"select", "from", "where", "limit", "and", "or", "not", "is",
             "null", "sum", "min", "max", "count", "avg"}
_AGGS = {"sum", "min", "max", "count", "avg"}


@dataclasses.dataclass
class SelectItem:
    column: str | None          # None for count(*)
    agg: str | None = None      # None for plain column

    @property
    def output_name(self) -> str:
        if self.agg is None:
            return self.column
        return f"{self.agg}({self.column or '*'})"


@dataclasses.dataclass
class Query:
    select: list[SelectItem] | None   # None == SELECT *
    table: str
    where: Expr | None = None
    limit: int | None = None

    @property
    def is_aggregate(self) -> bool:
        return bool(self.select) and any(s.agg for s in self.select)


class _Tokens:
    def __init__(self, sql: str):
        self.toks: list[tuple[str, str]] = []
        pos = 0
        while pos < len(sql):
            m = _TOKEN.match(sql, pos)
            if not m or m.end() == pos:
                if sql[pos:].strip():
                    raise ValueError(f"bad token at: {sql[pos:pos+20]!r}")
                break
            pos = m.end()
            for kind in ("num", "str", "ident", "op", "star"):
                v = m.group(kind)
                if v is not None:
                    if kind == "ident" and v.lower() in _KEYWORDS:
                        self.toks.append(("kw", v.lower()))
                    else:
                        self.toks.append((kind, v))
                    break
        self.i = 0

    def peek(self) -> tuple[str, str] | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> tuple[str, str]:
        t = self.peek()
        if t is None:
            raise ValueError("unexpected end of query")
        self.i += 1
        return t

    def accept(self, kind: str, value: str | None = None) -> bool:
        t = self.peek()
        if t and t[0] == kind and (value is None or t[1] == value):
            self.i += 1
            return True
        return False

    def expect(self, kind: str, value: str | None = None) -> str:
        t = self.next()
        if t[0] != kind or (value is not None and t[1] != value):
            raise ValueError(f"expected {value or kind}, got {t}")
        return t[1]


def parse(sql: str) -> Query:
    tk = _Tokens(sql)
    tk.expect("kw", "select")
    select: list[SelectItem] | None
    if tk.accept("op", "*") or tk.accept("star", "*"):
        select = None
    else:
        select = [_select_item(tk)]
        while tk.accept("op", ","):
            select.append(_select_item(tk))
    tk.expect("kw", "from")
    table = tk.expect("ident")
    where = None
    limit = None
    if tk.accept("kw", "where"):
        where = _expr(tk)
    if tk.accept("kw", "limit"):
        limit = int(tk.expect("num"))
    if tk.peek() is not None:
        raise ValueError(f"trailing tokens: {tk.peek()}")
    return Query(select, table, where, limit)


def _select_item(tk: _Tokens) -> SelectItem:
    t = tk.next()
    if t[0] == "kw" and t[1] in _AGGS:
        tk.expect("op", "(")
        if tk.accept("op", "*") or tk.accept("star", "*"):
            col = None
        else:
            col = tk.expect("ident")
        tk.expect("op", ")")
        return SelectItem(col, t[1])
    if t[0] == "ident":
        return SelectItem(t[1])
    raise ValueError(f"bad select item: {t}")


def _expr(tk: _Tokens) -> Expr:
    return _or(tk)


def _or(tk: _Tokens) -> Expr:
    left = _and(tk)
    while tk.accept("kw", "or"):
        left = BinOp("or", left, _and(tk))
    return left


def _and(tk: _Tokens) -> Expr:
    left = _unary(tk)
    while tk.accept("kw", "and"):
        left = BinOp("and", left, _unary(tk))
    return left


def _unary(tk: _Tokens) -> Expr:
    if tk.accept("kw", "not"):
        return Not(_unary(tk))
    return _cmp(tk)


def _cmp(tk: _Tokens) -> Expr:
    left = _add(tk)
    t = tk.peek()
    if t and t[0] == "kw" and t[1] == "is":
        tk.next()
        negate = tk.accept("kw", "not")
        tk.expect("kw", "null")
        return IsNull(left, negate=negate)
    if t and t[0] == "op" and t[1] in ("=", "==", "!=", "<>", "<", "<=", ">", ">="):
        tk.next()
        return BinOp(t[1], left, _add(tk))
    return left


def _add(tk: _Tokens) -> Expr:
    left = _mul(tk)
    while True:
        t = tk.peek()
        if t and t[0] == "op" and t[1] in ("+", "-"):
            tk.next()
            left = BinOp(t[1], left, _mul(tk))
        else:
            return left


def _mul(tk: _Tokens) -> Expr:
    left = _atom(tk)
    while True:
        t = tk.peek()
        if t and t[0] == "op" and t[1] in ("*", "/", "%"):
            tk.next()
            left = BinOp(t[1], left, _atom(tk))
        else:
            return left


def _atom(tk: _Tokens) -> Expr:
    t = tk.next()
    if t[0] == "op" and t[1] == "-":          # unary minus
        return BinOp("-", Lit(0), _atom(tk))
    if t[0] == "num":
        text = t[1]
        return Lit(float(text) if ("." in text or "e" in text.lower())
                   else int(text))
    if t[0] == "str":
        return Lit(t[1][1:-1].replace("''", "'"))
    if t[0] == "ident":
        return Col(t[1])
    if t[0] == "op" and t[1] == "(":
        e = _expr(tk)
        tk.expect("op", ")")
        return e
    raise ValueError(f"bad expression atom: {t}")
