"""Vectorized expression AST evaluated batch-at-a-time over RecordBatches.

Supports column refs, literals, arithmetic, comparisons, boolean logic, and
NULL-aware three-valued semantics where it matters for filters (a NULL
comparison never passes a WHERE clause, like SQL).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from ..core.recordbatch import RecordBatch


class Expr:
    def evaluate(self, batch: RecordBatch) -> tuple[np.ndarray, np.ndarray]:
        """Returns (values, valid_mask)."""
        raise NotImplementedError

    def columns(self) -> set[str]:
        raise NotImplementedError


@dataclasses.dataclass
class Col(Expr):
    name: str

    def evaluate(self, batch: RecordBatch):
        col = batch.column(self.name)
        if col.field.varlen:
            # materialize strings as object array for comparisons
            vals = np.array(
                [v if v is not None else "" for v in col.to_pylist()], dtype=object)
        else:
            vals = col.values
        return vals, col.valid_mask()

    def columns(self) -> set[str]:
        return {self.name}


@dataclasses.dataclass
class Lit(Expr):
    value: Any

    def evaluate(self, batch: RecordBatch):
        n = batch.num_rows
        if isinstance(self.value, str):
            vals = np.array([self.value] * n, dtype=object)
        else:
            vals = np.full(n, self.value)
        return vals, np.ones(n, dtype=np.bool_)

    def columns(self) -> set[str]:
        return set()


_ARITH = {
    "+": np.add, "-": np.subtract, "*": np.multiply, "/": np.divide,
    "%": np.mod,
}
_CMP = {
    "=": np.equal, "==": np.equal, "!=": np.not_equal, "<>": np.not_equal,
    "<": np.less, "<=": np.less_equal, ">": np.greater, ">=": np.greater_equal,
}


@dataclasses.dataclass
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    def evaluate(self, batch: RecordBatch):
        lv, lm = self.left.evaluate(batch)
        rv, rm = self.right.evaluate(batch)
        valid = lm & rm
        if self.op in _ARITH:
            with np.errstate(divide="ignore", invalid="ignore"):
                return _ARITH[self.op](lv, rv), valid
        if self.op in _CMP:
            return _CMP[self.op](lv, rv), valid
        if self.op == "and":
            return (lv.astype(bool) & rv.astype(bool)), valid
        if self.op == "or":
            # SQL OR: true OR null -> true
            out = lv.astype(bool) | rv.astype(bool)
            valid = valid | (lm & lv.astype(bool)) | (rm & rv.astype(bool))
            return out, valid
        raise ValueError(f"unknown op {self.op!r}")

    def columns(self) -> set[str]:
        return self.left.columns() | self.right.columns()


@dataclasses.dataclass
class Not(Expr):
    inner: Expr

    def evaluate(self, batch: RecordBatch):
        v, m = self.inner.evaluate(batch)
        return ~v.astype(bool), m

    def columns(self) -> set[str]:
        return self.inner.columns()


@dataclasses.dataclass
class IsNull(Expr):
    inner: Expr
    negate: bool = False

    def evaluate(self, batch: RecordBatch):
        _, m = self.inner.evaluate(batch)
        out = m if self.negate else ~m
        return out, np.ones(len(m), dtype=np.bool_)

    def columns(self) -> set[str]:
        return self.inner.columns()


def filter_mask(expr: Expr, batch: RecordBatch) -> np.ndarray:
    """SQL WHERE semantics: row passes iff predicate is TRUE and not NULL."""
    vals, valid = expr.evaluate(batch)
    return vals.astype(bool) & valid
