"""Columnar query engine (DuckDB stand-in for the Thallus server)."""
from .table import Catalog, Table, make_mixed_table, make_numeric_table  # noqa: F401
from .executor import Engine, QueryReader  # noqa: F401
from .sql import Query, parse  # noqa: F401
from .expressions import BinOp, Col, Expr, IsNull, Lit, Not, filter_mask  # noqa: F401
