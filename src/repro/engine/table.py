"""In-memory columnar tables + a dataset catalog (the DuckDB stand-in's
storage layer). A :class:`Table` is a list of same-schema record batches; a
:class:`Catalog` maps "dataset paths" to tables, mirroring the paper's
``init_scan(sql, dataset_path)`` signature.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from ..core.recordbatch import RecordBatch, batch_from_arrays, concat_batches
from ..core.schema import Schema, schema as make_schema


@dataclasses.dataclass
class Table:
    name: str
    schema: Schema
    batches: list[RecordBatch] = dataclasses.field(default_factory=list)

    @property
    def num_rows(self) -> int:
        return sum(b.num_rows for b in self.batches)

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self.batches)

    def append(self, batch: RecordBatch) -> None:
        if batch.schema != self.schema:
            raise ValueError(f"schema mismatch appending to {self.name!r}")
        self.batches.append(batch)

    def scan(self) -> Iterator[RecordBatch]:
        yield from self.batches

    def to_batch(self) -> RecordBatch:
        return concat_batches(self.batches)


class Catalog:
    """dataset path -> table. One per server process."""

    def __init__(self) -> None:
        self._tables: dict[str, Table] = {}

    def register(self, path: str, table: Table) -> None:
        self._tables[path] = table

    def get(self, path: str) -> Table:
        if path not in self._tables:
            raise KeyError(f"no dataset registered at {path!r}")
        return self._tables[path]

    def __contains__(self, path: str) -> bool:
        return path in self._tables

    def paths(self) -> list[str]:
        return sorted(self._tables)


# ---------------------------------------------------------------------------
# synthetic datasets for benchmarks (paper: column-selectivity experiments)
# ---------------------------------------------------------------------------


def make_numeric_table(name: str, num_rows: int, num_cols: int,
                       batch_rows: int = 1 << 16, seed: int = 0,
                       dtype: str = "float64") -> Table:
    """A wide numeric table, the shape used for column-selectivity sweeps:
    ``SELECT c0, ..., ck FROM t`` with k swept to change result-set size."""
    rng = np.random.default_rng(seed)
    sch = make_schema(*[(f"c{i}", dtype) for i in range(num_cols)])
    table = Table(name, sch)
    left = num_rows
    while left > 0:
        n = min(batch_rows, left)
        arrays = [rng.standard_normal(n).astype(dtype) for _ in range(num_cols)]
        table.append(batch_from_arrays(sch, arrays))
        left -= n
    return table


def make_mixed_table(name: str, num_rows: int, batch_rows: int = 1 << 14,
                     seed: int = 0) -> Table:
    """id/int + floats + strings + nulls — exercises all three buffer kinds."""
    from ..core.recordbatch import batch_from_pydict

    rng = np.random.default_rng(seed)
    sch = make_schema(("id", "int64"), ("val", "float64"),
                      ("flag", "bool"), ("tag", "utf8"))
    table = Table(name, sch)
    tags = ["alpha", "beta", "gamma", "delta", None]
    row = 0
    while row < num_rows:
        n = min(batch_rows, num_rows - row)
        data = {
            "id": list(range(row, row + n)),
            "val": [float(v) if i % 17 else None
                    for i, v in enumerate(rng.standard_normal(n))],
            "flag": [bool(v) for v in rng.integers(0, 2, n)],
            "tag": [tags[i % len(tags)] for i in range(n)],
        }
        table.append(batch_from_pydict(sch, data))
        row += n
    return table
