"""Vectorized batch-at-a-time executor + streaming reader.

``Engine.execute(sql, dataset)`` returns a :class:`QueryReader` implementing
the ``RecordBatchReader`` protocol the Thallus server iterates — the same
streaming-cursor shape the paper builds over DuckDB's chunked results, with
the DuckDB→Arrow conversion replaced by engine-native Arrow batches (our
"C Data Interface" handoff is numpy views — zero-copy by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from ..core.recordbatch import (Column, RecordBatch, batch_from_arrays,
                                pack_validity)
from ..core.schema import Field, Schema
from .expressions import filter_mask
from .sql import Query, SelectItem, parse
from .table import Catalog, Table


class QueryReader:
    """Streaming cursor over query results (RecordBatchReader protocol)."""

    def __init__(self, schema: Schema, batches: Iterator[RecordBatch]):
        self.schema = schema
        self._it = batches
        self.batches_read = 0

    def read_next(self) -> RecordBatch | None:
        try:
            b = next(self._it)
        except StopIteration:
            return None
        self.batches_read += 1
        return b

    def read_all(self) -> list[RecordBatch]:
        out = []
        while (b := self.read_next()) is not None:
            out.append(b)
        return out


class Engine:
    """The DuckDB stand-in: parse → plan → stream batches."""

    def __init__(self, catalog: Catalog | None = None):
        self.catalog = catalog or Catalog()

    def register(self, path: str, table: Table) -> None:
        self.catalog.register(path, table)

    # -- QueryEngine protocol ------------------------------------------------
    def execute(self, sql: str, dataset: str) -> QueryReader:
        query = parse(sql)
        table = self.catalog.get(dataset)
        if query.is_aggregate:
            return self._execute_aggregate(query, table)
        return self._execute_scan(query, table)

    def estimate_batches(self, sql: str, dataset: str) -> int | None:
        """Planner statistics: the exact result-batch count when it is known
        without evaluation (projection-only scans, aggregates), else None —
        the caller must fall back to draining a planning reader. Filters and
        limits can drop batches, so those shapes are not estimable."""
        query = parse(sql)
        table = self.catalog.get(dataset)
        if query.is_aggregate:
            return 1
        if query.where is None and query.limit is None:
            return len(table.batches)
        return None

    # -- plain scans: project + filter + limit, streamed ---------------------
    def _execute_scan(self, query: Query, table: Table) -> QueryReader:
        names = (list(table.schema.names) if query.select is None
                 else [s.column for s in query.select])
        out_schema = table.schema.select(names)

        def gen() -> Iterator[RecordBatch]:
            remaining = query.limit
            for batch in table.scan():
                if query.where is not None:
                    mask = filter_mask(query.where, batch)
                    if not mask.any():
                        continue
                    if mask.all():
                        out = batch.select(names)       # zero-copy projection
                    else:
                        out = batch.take(np.flatnonzero(mask)).select(names)
                else:
                    out = batch.select(names)           # zero-copy projection
                if remaining is not None:
                    if remaining <= 0:
                        return
                    if out.num_rows > remaining:
                        out = out.slice(0, remaining)
                    remaining -= out.num_rows
                yield out

        return QueryReader(out_schema, gen())

    # -- aggregates: single output batch --------------------------------------
    def _execute_aggregate(self, query: Query, table: Table) -> QueryReader:
        accs = [_Accumulator(item) for item in query.select]
        for batch in table.scan():
            if query.where is not None:
                mask = filter_mask(query.where, batch)
            else:
                mask = None
            for acc in accs:
                acc.update(batch, mask)
        fields, arrays = [], []
        for acc in accs:
            v = acc.result()
            dt = "int64" if isinstance(v, (int, np.integer)) else "float64"
            fields.append(Field(acc.item.output_name, dt, nullable=False))
            arrays.append(np.array([v], dtype=dt))
        sch = Schema(tuple(fields))
        out = batch_from_arrays(sch, arrays)
        return QueryReader(sch, iter([out]))


@dataclasses.dataclass
class _Accumulator:
    item: SelectItem
    count: int = 0
    total: float = 0.0
    lo: float = float("inf")
    hi: float = float("-inf")

    def update(self, batch: RecordBatch, mask: np.ndarray | None) -> None:
        if self.item.column is None:        # count(*)
            self.count += int(mask.sum()) if mask is not None else batch.num_rows
            return
        col = batch.column(self.item.column)
        valid = col.valid_mask()
        if mask is not None:
            valid = valid & mask
        if not valid.any():
            return
        vals = col.values[valid]
        self.count += int(valid.sum())
        if self.item.agg in ("sum", "avg"):
            self.total += float(vals.sum())
        if self.item.agg == "min":
            self.lo = min(self.lo, float(vals.min()))
        if self.item.agg == "max":
            self.hi = max(self.hi, float(vals.max()))

    def result(self):
        agg = self.item.agg
        if agg == "count":
            return self.count
        if agg == "sum":
            return self.total
        if agg == "avg":
            return self.total / self.count if self.count else float("nan")
        if agg == "min":
            return self.lo
        if agg == "max":
            return self.hi
        raise ValueError(agg)
