"""Lease-boundary preemption: pause a heavy scan, run the lookup, resume.

The qos layer's deadline shedding rejects at grant time only — once a
batch-class fan-out holds its leases, an interactive arrival waits behind
the whole scan. But the dataplane already pulls in bounded ``max_batches``
leases, and a lease boundary is a natural preemption point: nothing is in
flight, every stream's resume offset is exact.

:class:`PreemptibleScan` drives a :class:`~repro.cluster.streams.
MultiStreamPuller` (or a :class:`~.steal.StealingPuller`) one lease round at
a time so the gateway can interleave scheduling decisions with execution:

* :meth:`run_round` pulls one bounded lease on every live stream and
  returns the modeled time the round added to the scan's critical path;
* :meth:`park` releases every stream's server lease **and its admission
  slot** back to the budget (``StreamPuller.park``), checkpointing resume
  offsets — the scan holds no server-side resources while parked;
* :meth:`resume` re-opens every stream where it stopped through fresh
  admission-gated leases (``init_scan(start_batch=…)``), once the WFQ
  virtual clock readmits the parked request.

The gateway decides *when*: it parks a batch-class scan as soon as a
higher-weight (interactive) request has arrived on the modeled clock, and
pushes the remainder back into the weighted-fair queue at its residual cost.
"""
from __future__ import annotations

import dataclasses

from ..cluster.streams import (ClusterStats, MultiStreamPuller,
                               notify_coordinator)


@dataclasses.dataclass(frozen=True)
class PreemptConfig:
    """Knobs for the gateway's preemption policy.

    ``preemptible_classes`` limits which client classes may be paused;
    ``None`` means any class outweighed by another configured class (with
    the default two-class split: batch yields to interactive).
    """

    preemptible_classes: tuple[str, ...] | None = None
    min_rounds_before_park: int = 1    # let a scan make some progress

    def applies_to(self, klass: str) -> bool:
        return (self.preemptible_classes is None
                or klass in self.preemptible_classes)


class PreemptibleScan:
    """A fan-out that executes in parkable lease-round bursts.

    Accumulates per-stream deliveries across bursts so the gateway can
    reassemble the final result exactly as if the scan had run unbroken.
    ``copy_out`` must be set when a pool is attached (pooled buffers recycle
    on the next pull; parked results must survive arbitrarily long).
    """

    def __init__(self, puller: MultiStreamPuller, copy_batch=None):
        self.puller = puller
        self._copy = copy_batch if puller.pool is not None else None
        self.per_stream: list[list] = [[] for _ in puller.pullers]
        self.rounds = 0
        self.parked = False
        self.park_count = 0
        self.elapsed_s = 0.0            # modeled execution time, bursts only

    # ------------------------------------------------------------ progress
    @property
    def done(self) -> bool:
        return all(p.drained for p in self.puller.pullers)

    @property
    def delivered(self) -> int:
        return sum(p.delivered for p in self.puller.pullers)

    @property
    def total_batches(self) -> int | None:
        """Known total for bounded (replica) plans, else ``None``."""
        totals = [p.endpoint.max_batches for p in self.puller.pullers]
        if any(t is None for t in totals):
            return None
        return sum(totals)

    def _clock_s(self) -> float:
        return max((p.stats.start_s + p.stats.clock_s
                    for p in self.puller.pullers), default=0.0)

    # --------------------------------------------------------------- drive
    def run_round(self) -> float:
        """One bounded lease on every live stream; returns the modeled time
        this round added to the scan's critical path."""
        if self.parked:
            raise RuntimeError("scan is parked; resume() before driving")
        before = self._clock_s()
        for idx, puller in enumerate(self.puller.pullers):
            if puller.drained:
                continue
            out = puller.pull_lease(self.puller.lease_batches)
            while out:
                batch, handle = out.pop(0)
                self.per_stream[idx].append(
                    self._copy(batch) if self._copy is not None else batch)
                if handle is not None:
                    self.puller.pool.release(handle)
        # stealing drivers may have appended thief pullers mid-round via
        # explicit rebalance() calls; keep the delivery table in step
        while len(self.per_stream) < len(self.puller.pullers):
            self.per_stream.append([])
        self.rounds += 1
        delta = self._clock_s() - before
        self.elapsed_s += delta
        return delta

    def rebalance(self) -> int:
        """Run the underlying driver's straggler check, when it has one
        (a :class:`~.steal.StealingPuller`). Returns new streams added."""
        maybe_steal = getattr(self.puller, "_maybe_steal", None)
        if maybe_steal is None:
            return 0
        added = list(maybe_steal())
        while len(self.per_stream) < len(self.puller.pullers):
            self.per_stream.append([])
        return len(added)

    # --------------------------------------------------------- park/resume
    def park(self) -> None:
        """Release every live lease (and its admission slot) at the current
        lease boundary; resume offsets are already checkpointed per stream
        (``StreamPuller.delivered``)."""
        if self.parked:
            return
        if self.puller.trace is not None:
            self.puller.trace.instant("scan.park", self._clock_s(),
                                      cat="sched", group="scan",
                                      rounds=self.rounds)
        for puller in self.puller.pullers:
            puller.park()
        self.parked = True
        self.park_count += 1
        notify_coordinator(self.puller.coordinator, "scan.park",
                           now_s=self._clock_s(), rounds=self.rounds)

    def resume(self) -> None:
        """Re-open every parked stream where it stopped. May raise
        ``qos.Backpressure`` — parking gave the slots back, so resuming is
        a fresh admission decision; on a partial failure the streams that
        did re-open are parked again (nothing leaks)."""
        if not self.parked:
            return
        reopened = []
        try:
            for puller in self.puller.pullers:
                puller.unpark()
                reopened.append(puller)
        except BaseException:
            for puller in reopened:
                puller.park()
            raise
        self.parked = False
        if self.puller.trace is not None:
            self.puller.trace.instant("scan.resume", self._clock_s(),
                                      cat="sched", group="scan",
                                      rounds=self.rounds)
        notify_coordinator(self.puller.coordinator, "scan.resume",
                           now_s=self._clock_s(), rounds=self.rounds)

    # -------------------------------------------------------------- finish
    def abandon(self) -> None:
        """Tear down leases for a scan that will never finish (its request
        was shed while parked)."""
        self.puller._abandon()

    def stats(self) -> ClusterStats:
        return self.puller.stats()
