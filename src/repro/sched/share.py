"""Shared tickets: coalesce identical queued scans into one fan-out.

The Arrow Flight benchmark paper (arXiv:2204.03032) motivates the *shared
ticket* model: when many clients ask for the same result, the server
executes once and every requester pulls the same stream. The qos gateway
sees every queued :class:`~repro.qos.ScanRequest` before it plans, which is
exactly the place to apply the trick: a :class:`TicketTable` keys tickets on
``(sql, dataset, start_batch)``; the first subscriber popped becomes the
**primary** and executes the fan-out; the reassembled batches are published
on the ticket and *multicast* — copy-on-read, each subscriber receives its
own deep copy at grant time — to everyone else, with per-subscriber
``QosStats`` attribution (a hit still counts granted batches/bytes for its
class, it just consumes no server-side service).

Tickets live for one gateway drain (``begin_drain`` clears the table): a
published result is a snapshot of the tables at execution time, and holding
it across drains would hand later subscribers stale data.

Everything is duck-typed (subscriber ids are opaque ints, results are
opaque lists), so this module imports nothing from :mod:`repro.qos` —
the gateway imports us, never the reverse.
"""
from __future__ import annotations

import dataclasses


TicketKey = tuple[str, str, int]        # (sql, dataset, start_batch)


@dataclasses.dataclass
class Ticket:
    """One coalesced result: its subscribers and, once executed, its data."""

    key: TicketKey
    subscribers: list[int] = dataclasses.field(default_factory=list)
    primary_id: int | None = None       # the request that ran the fan-out
    batches: list | None = None         # published reassembled batches
    cluster: object | None = None       # the primary's ClusterStats

    @property
    def published(self) -> bool:
        return self.batches is not None


@dataclasses.dataclass
class TicketStats:
    hits: int = 0                       # requests served by multicast
    misses: int = 0                     # requests that ran their own fan-out
    cancels: int = 0                    # subscribers shed while queued
    bytes_multicast: int = 0            # delivered without touching a server

    @property
    def fanouts_saved(self) -> int:
        return self.hits

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class TicketTable:
    """Keyed registry of in-flight/published shared tickets."""

    def __init__(self) -> None:
        self._tickets: dict[TicketKey, Ticket] = {}
        self.stats = TicketStats()

    @staticmethod
    def key_for(sql: str, dataset: str, start_batch: int = 0) -> TicketKey:
        return (sql, dataset, start_batch)

    def __len__(self) -> int:
        return len(self._tickets)

    def lookup(self, key: TicketKey) -> Ticket | None:
        return self._tickets.get(key)

    # ------------------------------------------------------------ telemetry
    def metrics(self) -> "MetricsRegistry":
        """This table's counters under the ``sched.tickets.*`` namespace of
        a fresh registry, plus the live in-flight ticket count — the same
        natural-root hook ``QosStats.registry()`` and
        ``AdmissionController.metrics()`` expose."""
        from ..obs.registry import MetricsRegistry, record_tickets
        reg = MetricsRegistry()
        record_tickets(reg, self.stats)
        reg.gauge("sched.tickets.in_flight", len(self))
        return reg

    # ------------------------------------------------------------ lifecycle
    def begin_drain(self) -> None:
        """Forget published results from earlier drains (data may have
        changed between drains); keep tickets that still have queued
        subscribers waiting."""
        self._tickets = {k: t for k, t in self._tickets.items()
                         if t.subscribers and not t.published}

    def subscribe(self, key: TicketKey, request_id: int) -> Ticket:
        """Register a queued request's interest — a later identical request
        may join an existing ticket mid-flight (after the primary was
        submitted, even after it executed within the same drain)."""
        ticket = self._tickets.setdefault(key, Ticket(key))
        if request_id not in ticket.subscribers:
            ticket.subscribers.append(request_id)
        return ticket

    def cancel(self, key: TicketKey, request_id: int) -> None:
        """A subscriber was shed while queued. Dropping the last subscriber
        of an unexecuted ticket drops the ticket — nobody will run it."""
        ticket = self._tickets.get(key)
        if ticket is None or request_id not in ticket.subscribers:
            return
        ticket.subscribers.remove(request_id)
        self.stats.cancels += 1
        if not ticket.subscribers and not ticket.published:
            del self._tickets[key]

    def publish(self, key: TicketKey, request_id: int, batches: list,
                cluster) -> Ticket:
        """The primary executed: record its reassembled result for every
        remaining subscriber to read."""
        ticket = self.subscribe(key, request_id)
        ticket.subscribers.remove(request_id)    # the primary is served
        ticket.primary_id = request_id
        ticket.batches = batches
        ticket.cluster = cluster
        self.stats.misses += 1
        return ticket

    def redeem(self, key: TicketKey, request_id: int) -> Ticket | None:
        """A subscriber reached the head of the queue: if its ticket is
        published, the caller multicasts (copy-on-read) instead of planning
        a fan-out. Returns ``None`` when the request must execute itself."""
        ticket = self._tickets.get(key)
        if ticket is None or not ticket.published:
            return None
        if request_id in ticket.subscribers:
            ticket.subscribers.remove(request_id)
        self.stats.hits += 1
        self.stats.bytes_multicast += getattr(ticket.cluster, "bytes", 0)
        return ticket
