"""repro.sched: the adaptive scan scheduler.

The execution layer between the qos :class:`~repro.qos.ScanGateway` and the
cluster :class:`~repro.cluster.streams.MultiStreamPuller`. Static plans
waste fast fabrics three ways, and each module here closes one gap:

* **work stealing** (:mod:`.steal`) — a lagging replica's remaining batch
  range is split at a lease boundary and re-leased to the fastest idle
  replica mid-scan, collapsing the straggler's critical path;
* **shared tickets** (:mod:`.share`) — identical queued requests coalesce
  onto one fan-out; the reassembled result is multicast (copy-on-read) to
  every subscriber with per-subscriber accounting;
* **preemption** (:mod:`.preempt`) — a batch-class scan pauses at its
  bounded-lease boundary when interactive traffic arrives, releasing its
  leases back to the admission budget, and resumes where it stopped when
  the weighted-fair queue readmits it.

:class:`AdaptiveScheduler` (:mod:`.scheduler`) bundles the three; the qos
gateway accepts one via ``ScanGateway(scheduler=…)``.
"""
from __future__ import annotations

from .preempt import PreemptConfig, PreemptibleScan  # noqa: F401
from .scheduler import AdaptiveScheduler  # noqa: F401
from .share import Ticket, TicketStats, TicketTable  # noqa: F401
from .steal import (  # noqa: F401
    ProgressTracker, RateHistory, ServerRateStats, StealConfig, StealEvent,
    StealingPuller,
)
