"""Work stealing: move a straggler's remaining batch range mid-scan.

The cluster dataplane executes a :class:`~repro.cluster.plan.ScanPlan`
statically — once the planner has dealt batch ranges to replicas, a lagging
endpoint drags the whole critical path while faster replicas sit idle after
draining their slices. On fast fabrics that scheduling gap, not the wire, is
the bottleneck (Rödiger et al., arXiv:1502.07169). This module closes it:

* :class:`ProgressTracker` watches every stream's **modeled clock** during
  the drive loop and projects a finish time (ETA) from its observed
  per-batch rate and remaining bounded range;
* when a stream's ETA exceeds the fleet median by ``StealConfig.factor``,
  :class:`StealingPuller` splits the victim's remaining
  ``(start_batch, end_batch)`` range at the current lease boundary
  (:meth:`StreamPuller.split`) and re-leases the tail to the **fastest idle
  replica** via a fresh ``init_scan(start_batch=…)`` lease;
* every move is recorded as a :class:`StealEvent` on the scan's
  :class:`~repro.cluster.streams.ClusterStats`.

With a :class:`RateHistory` attached the decisions become *stateful* —
informed by transport progress across scans AND by the admission layer:

* **hysteresis** — the history keeps an EWMA per-server rate and a count of
  past steals. A repeat straggler is stolen from *earlier*: its per-victim
  steal factor decays by ``repeat_decay`` per recorded steal (floored at
  ``min_factor``), so the static ``StealConfig.factor`` is only the
  first-offense threshold.
* **flap quarantine** — a server whose observed per-lease rate reverses
  direction by more than ``flap_ratio`` (fast→slow→fast, or the mirror) is
  flapping; it is quarantined from being a steal **victim or thief** for
  ``quarantine_rounds`` lease rounds — stealing from (or onto) a link that
  is about to flip back is churn, not progress.
* **shard-aware declines** — before re-leasing a tail, the thief's
  admission shard is asked for local
  :meth:`~repro.qos.distributed.ShardedAdmission.headroom`
  (via :meth:`ClusterCoordinator.admission_headroom`). A thief whose shard
  is at its local quota *declines* (a ``kind="decline"`` event) and the
  tracker offers the tail to the next-fastest idle replica — stealing onto
  a saturated shard trades a transport stall for an admission stall. A
  declined shard is retried only after a freed-slot event says it drained.
* **re-steal** — every steal is remembered; if the thief's observed rate
  later degrades past the victim's recovered rate (by ``resteal_margin``),
  the victim reclaims the remaining tail at the thief's next lease boundary
  (a ``kind="re_steal"`` event). One re-steal per stolen range, ever — the
  bound that makes victim↔thief ping-pong impossible.

With ``history=None`` every stateful path is disabled and the puller is
event-for-event identical to the static-factor behavior (the conformance
suite replays a recorded straggler trace against both).

Stealing requires ``replica`` placement — only a server holding a full copy
can serve an arbitrary batch range. Shard plans pass through untouched.

Modeled-time bookkeeping: a stolen stream does not start at t=0. Its
``StreamStats.start_s`` is seeded with the steal epoch — the moment its
thief server went idle (it cannot start earlier) — so
``ClusterStats.modeled_critical_path_s`` stays an honest makespan.
"""
from __future__ import annotations

import dataclasses
import heapq
import weakref
from typing import Iterator

from ..cluster.plan import Endpoint
from ..cluster.streams import (MultiStreamPuller, StreamPuller,
                               notify_coordinator)


@dataclasses.dataclass(frozen=True)
class StealEvent:
    """One range-migration decision, for the audit trail in ``ClusterStats``.

    ``kind`` distinguishes the three decisions: ``"steal"`` (a range moved
    to an idle replica), ``"decline"`` (a candidate thief's admission shard
    had no local headroom; nothing moved), ``"re_steal"`` (the original
    victim reclaimed a degraded thief's remaining tail). ``server_id`` is
    the *shard* the decision lands on — the thief's shard for a steal, the
    declining shard for a decline, the reclaiming victim's shard for a
    re-steal — so report tables can attribute migrations per shard.
    """

    victim: str              # server_id the range was taken from
    thief: str               # server_id it was re-leased to
    start_batch: int         # first stolen global batch index
    num_batches: int
    epoch_s: float           # modeled time the stolen stream started
    victim_eta_s: float      # victim's projected finish before the steal
    median_eta_s: float      # fleet median ETA at the decision
    kind: str = "steal"      # "steal" | "decline" | "re_steal"
    server_id: str = ""      # shard attribution (see class docstring)


@dataclasses.dataclass(frozen=True)
class StealConfig:
    """When and how aggressively to move work.

    ``factor`` is the straggler threshold: steal when a stream's projected
    finish exceeds the fleet median projection by this multiple. ``2.0`` is
    conservative (a replica must be twice as late as the median); lower it
    toward 1 for eager rebalancing, at the cost of more split/lease churn.
    With a :class:`RateHistory` it is the *first-offense* threshold — the
    history decays it per recorded steal of the same server.
    """

    factor: float = 2.0
    min_batches: int = 2       # never move a tail smaller than this
    max_steals: int = 16       # per scan — runaway-split guard
    steal_headroom_min: int = 1   # thief shard must hold >= this many free
    #                               admission slots or the steal is declined
    resteal_margin: float = 1.2   # thief must be this much slower than the
    #                               recovered victim before a re-steal

    def __post_init__(self) -> None:
        if self.factor < 1.0:
            raise ValueError("steal factor must be >= 1.0")
        if self.min_batches < 1:
            raise ValueError("min_batches must be >= 1")
        if self.steal_headroom_min < 1:
            raise ValueError("steal_headroom_min must be >= 1")
        if self.resteal_margin < 1.0:
            raise ValueError("resteal_margin must be >= 1.0")


# --------------------------------------------------------------- rate history
@dataclasses.dataclass
class ServerRateStats:
    """One server's persistent transport-rate record."""

    rate_s: float | None = None        # EWMA modeled seconds per batch
    last_rate_s: float | None = None   # previous instantaneous observation
    last_dir: int = 0                  # sign of the last significant move
    observations: int = 0
    flaps: int = 0                     # direction reversals past flap_ratio
    steals_from: int = 0               # times this server was a steal victim
    quarantined_until: int = -1        # lease round the quarantine lifts at


class RateHistory:
    """Per-server EWMA rate + flap record, persisted across scans.

    The :class:`StealingPuller` feeds it one observation per lease — the
    *instantaneous* modeled seconds/batch of that lease — and ticks a lease
    round. The EWMA smooths the straggler signal across scans (a new scan
    starts with last scan's verdicts instead of a cold tracker); the
    instantaneous sequence drives flap detection: a move of more than
    ``flap_ratio`` in one direction followed by one in the other is a flap,
    and the server is quarantined for exactly ``quarantine_rounds`` lease
    rounds from being a steal victim *or* thief.
    """

    def __init__(self, alpha: float = 0.3, flap_ratio: float = 2.0,
                 quarantine_rounds: int = 16, repeat_decay: float = 0.75,
                 min_factor: float = 1.1):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if flap_ratio <= 1.0:
            raise ValueError("flap_ratio must be > 1.0")
        if quarantine_rounds < 1:
            raise ValueError("quarantine_rounds must be >= 1")
        if not 0.0 < repeat_decay <= 1.0:
            raise ValueError("repeat_decay must be in (0, 1]")
        if min_factor < 1.0:
            raise ValueError("min_factor must be >= 1.0")
        self.alpha = alpha
        self.flap_ratio = flap_ratio
        self.quarantine_rounds = quarantine_rounds
        self.repeat_decay = repeat_decay
        self.min_factor = min_factor
        self.round = 0
        self.servers: dict[str, ServerRateStats] = {}

    def server(self, server_id: str) -> ServerRateStats:
        if server_id not in self.servers:
            self.servers[server_id] = ServerRateStats()
        return self.servers[server_id]

    # ---------------------------------------------------------- observation
    def observe(self, server_id: str, rate_s: float) -> None:
        """Fold one instantaneous per-lease rate into the server's record."""
        if rate_s <= 0:
            return
        h = self.server(server_id)
        h.observations += 1
        h.rate_s = (rate_s if h.rate_s is None
                    else h.rate_s + self.alpha * (rate_s - h.rate_s))
        if h.last_rate_s is not None:
            if rate_s > h.last_rate_s * self.flap_ratio:
                direction = 1                       # got slower, sharply
            elif rate_s * self.flap_ratio < h.last_rate_s:
                direction = -1                      # got faster, sharply
            else:
                direction = 0
            if direction and h.last_dir and direction != h.last_dir:
                h.flaps += 1
                h.quarantined_until = self.round + self.quarantine_rounds
            if direction:
                h.last_dir = direction
        h.last_rate_s = rate_s

    def tick(self) -> None:
        """Advance one lease round (quarantines are counted in these)."""
        self.round += 1

    # ------------------------------------------------------------- verdicts
    def rate_for(self, server_id: str) -> float | None:
        h = self.servers.get(server_id)
        return h.rate_s if h is not None else None

    def quarantined(self, server_id: str) -> bool:
        h = self.servers.get(server_id)
        return h is not None and self.round < h.quarantined_until

    def record_steal(self, server_id: str) -> None:
        self.server(server_id).steals_from += 1

    def factor_for(self, server_id: str, base_factor: float) -> float:
        """Per-victim steal threshold: the static factor decayed once per
        recorded steal of this server, floored at ``min_factor`` — repeat
        stragglers are stolen from earlier."""
        h = self.servers.get(server_id)
        n = h.steals_from if h is not None else 0
        return max(self.min_factor, base_factor * self.repeat_decay ** n)

    # ---------------------------------------------------------------- stats
    @property
    def total_flaps(self) -> int:
        return sum(h.flaps for h in self.servers.values())

    @property
    def total_steals(self) -> int:
        return sum(h.steals_from for h in self.servers.values())


class ProgressTracker:
    """Projects per-stream finish times from modeled clocks.

    All arithmetic is on ``StreamStats.modeled_wire_s`` (a pure function of
    bytes/segments/ops), so straggler detection is deterministic under any
    machine load — the same trick ``modeled_critical_path_s`` uses.
    """

    def __init__(self, config: StealConfig | None = None,
                 history: RateHistory | None = None):
        self.config = config or StealConfig()
        self.history = history

    @staticmethod
    def finish_s(puller: StreamPuller) -> float:
        """Modeled time at which this stream is (or was) done pulling."""
        return puller.stats.start_s + puller.stats.modeled_wire_s

    def rate_s(self, puller: StreamPuller) -> float | None:
        """Observed modeled seconds per batch; ``None`` before first batch."""
        s = puller.stats
        return s.modeled_wire_s / s.batches if s.batches > 0 else None

    def eta_s(self, puller: StreamPuller) -> float | None:
        """Projected finish: progress so far plus remaining batches at the
        observed rate. ``None`` when unmeasurable (no batches yet) or
        unbounded (no known remaining range)."""
        if puller.drained:
            return self.finish_s(puller)
        rate, remaining = self.rate_s(puller), puller.remaining
        if rate is None or remaining is None:
            return None
        return self.finish_s(puller) + remaining * rate

    def victim_factor(self, server_id: str) -> float:
        """The steal threshold for this victim: static without history,
        decayed per recorded steal with it (repeat-straggler hysteresis)."""
        if self.history is None:
            return self.config.factor
        return self.history.factor_for(server_id, self.config.factor)

    def find_straggler(self, pullers: list[StreamPuller]
                       ) -> tuple[int, float, float] | None:
        """The stream to steal from, or ``None`` if the fleet is balanced.

        Returns ``(victim_index, victim_eta, median_eta)``. A victim must be
        live, bounded, measurable, owe at least ``min_batches``, project
        past its per-victim factor × the fleet median ETA, and (with a
        history) not be quarantined for flapping.
        """
        etas = [self.eta_s(p) for p in pullers]
        known = sorted(e for e in etas if e is not None)
        if len(known) < 2:
            return None
        median = known[(len(known) - 1) // 2]
        victim, victim_eta = None, 0.0
        for idx, (p, eta) in enumerate(zip(pullers, etas)):
            if (eta is None or p.drained or p.parked
                    or (p.remaining or 0) < self.config.min_batches):
                continue
            if (self.history is not None
                    and self.history.quarantined(p.endpoint.server_id)):
                continue
            if eta > victim_eta:
                victim, victim_eta = idx, eta
        if victim is None:
            return None
        factor = self.victim_factor(pullers[victim].endpoint.server_id)
        if victim_eta <= factor * max(median, 1e-30):
            return None
        return victim, victim_eta, median


@dataclasses.dataclass
class _StealRecord:
    """Live bookkeeping for one executed steal (drives re-steal)."""

    thief_idx: int           # index of the thief's puller in self.pullers
    victim_sid: str          # original victim server
    thief_sid: str
    re_stolen: bool = False  # the one-re-steal-per-range bound


class StealingPuller(MultiStreamPuller):
    """A first-ready multi-stream drive that rebalances between leases.

    Drop-in for :class:`~repro.cluster.streams.MultiStreamPuller`: same
    batches, same streaming contract, plus work stealing. Consumers that
    index per-stream output by stream id must size for growth — stolen
    streams append pullers past the original plan width (the qos gateway
    reassembles by endpoint range, so it is unaffected).

    ``history`` (a :class:`RateHistory`, usually owned by the
    :class:`~repro.sched.scheduler.AdaptiveScheduler` so it persists across
    scans) turns on hysteresis, flap quarantine and re-steal; shard-aware
    declines only need the coordinator's admission controller to answer
    ``headroom`` queries (see :meth:`ClusterCoordinator.admission_headroom`).
    """

    def __init__(self, coordinator, plan, steal: StealConfig | None = None,
                 history: RateHistory | None = None, **kwargs):
        kwargs.setdefault("schedule", "first_ready")
        super().__init__(coordinator, plan, **kwargs)
        self.history = history
        self.tracker = ProgressTracker(steal, history=history)
        self._stealable = (plan.placement == "replica")
        self._records: list[_StealRecord] = []
        self._declined: set[str] = set()    # shards declined until they drain
        self._observed: dict[int, tuple[float, int]] = {}  # idx -> wire,batches
        self._release_cb = None
        self._release_admission = None
        admission = getattr(coordinator, "admission", None)
        if (self._stealable and admission is not None
                and hasattr(admission, "headroom")
                and hasattr(admission, "subscribe_release")):
            # freed-slot hook: a declined shard becomes a candidate again
            # the moment a slot on it drains. Subscribed through a weakref
            # (a long-lived controller must not pin a dead puller) and
            # unsubscribed when the drive loop ends (_abandon) — one scan's
            # scheduler must not leave a callback behind on a controller
            # that outlives thousands of scans.
            ref = weakref.ref(self)

            def _on_release(server_id=None, client_id=None, now_s=None,
                            _ref=ref):
                puller = _ref()
                if puller is not None and server_id is not None:
                    puller._declined.discard(server_id)

            admission.subscribe_release(_on_release)
            self._release_cb = _on_release
            self._release_admission = admission

    def _abandon(self) -> None:
        super()._abandon()
        # the scan is over (drained, abandoned, or failed mid-open):
        # retire the freed-slot subscription. Idempotent — _abandon can run
        # more than once, and the base __init__ error path reaches here
        # before this subclass's fields exist.
        cb = getattr(self, "_release_cb", None)
        if cb is not None:
            unsubscribe = getattr(self._release_admission,
                                  "unsubscribe_release", None)
            if unsubscribe is not None:
                unsubscribe(cb)
            self._release_cb = None

    @staticmethod
    def _modeled_clock(puller: StreamPuller) -> float:
        """Stream progress on the *modeled* timeline only. The drive loop
        must sequence leases (and therefore steal decisions) by modeled
        time — the measured components of ``clock_s`` (host memcpy wall
        time) are similar across streams and would mask the very lag the
        tracker is looking for."""
        s = puller.stats
        return (s.start_s + s.modeled_wire_s + s.control_rpc_s
                + s.throttle_wait_s)

    # ----------------------------------------------------------- drive loop
    def _drive(self):
        try:
            heap = [(0.0, idx) for idx in range(len(self.pullers))]
            heapq.heapify(heap)
            while heap:
                _, idx = heapq.heappop(heap)
                yield from self._lease(idx)
                self._observe(idx)
                puller = self.pullers[idx]
                if not puller.drained:
                    heapq.heappush(heap, (self._modeled_clock(puller), idx))
                for new_idx in self._rebalance():
                    thief = self.pullers[new_idx]
                    heapq.heappush(
                        heap, (self._modeled_clock(thief), new_idx))
        finally:
            self._abandon()

    # ------------------------------------------------------------- stealing
    def _observe(self, idx: int) -> None:
        """Feed the history one instantaneous per-lease rate observation and
        tick the lease round (quarantine's unit of time)."""
        if self.history is None:
            return
        puller = self.pullers[idx]
        s = puller.stats
        prev_wire, prev_batches = self._observed.get(idx, (0.0, 0))
        if s.batches > prev_batches:
            rate = (s.modeled_wire_s - prev_wire) / (s.batches - prev_batches)
            self.history.observe(puller.endpoint.server_id, rate)
        self._observed[idx] = (s.modeled_wire_s, s.batches)
        self.history.tick()

    def _migrations(self) -> int:
        """Executed moves so far (declines are free — they moved nothing)."""
        return sum(1 for e in self.steal_events
                   if getattr(e, "kind", "steal") != "decline")

    def _rebalance(self) -> Iterator[int]:
        """One inter-lease scheduling pass: re-steal checks, then the
        straggler check. Yields indices of new pullers for the heap."""
        if not self._stealable:
            return
        yield from self._maybe_resteal()
        yield from self._maybe_steal()

    def _idle_servers(self) -> dict[str, float]:
        """server_id → idle-since epoch for replicas with no live stream of
        this scan. A server never leased by this scan is idle from t=0.
        A crashed process or a health-quarantined server is never idle in
        the thieving sense — re-leasing a tail onto it would just fault the
        tail back off (both checks duck-typed: plain deployments with
        neither crash hooks nor a monitor steal exactly as before)."""
        hosts = self.coordinator.hosts(self.plan.dataset)
        busy = {p.endpoint.server_id for p in self.pullers if not p.drained}
        monitor = getattr(self.coordinator, "health", None)
        state = getattr(monitor, "state", None) if monitor is not None \
            else None
        idle: dict[str, float] = {}
        for sid, server in hosts.items():
            if sid in busy:
                continue
            if getattr(server, "crashed", False):
                continue
            if state is not None and state(sid) == "quarantined":
                continue
            drained = [p for p in self.pullers
                       if p.endpoint.server_id == sid and p.drained]
            idle[sid] = max((self.tracker.finish_s(p) for p in drained),
                            default=0.0)
        return idle

    def _server_rate(self, server_id: str) -> float | None:
        """Observed per-batch modeled rate of a server's drained streams."""
        rates = [self.tracker.rate_s(p) for p in self.pullers
                 if p.endpoint.server_id == server_id
                 and p.stats.batches > 0]
        rates = [r for r in rates if r is not None]
        return min(rates) if rates else None

    def _thief_rate(self, server_id: str) -> float | None:
        """A candidate thief's modeled rate. With a history, its EWMA wins:
        the scan-local view is the *minimum* over drained streams, which
        goes stale the moment a server degrades mid-scan (exactly the
        server re-steal exists for), while the EWMA tracks the drift.
        Without one, the scan-local observation is all there is."""
        if self.history is not None:
            rate = self.history.rate_for(server_id)
            if rate is not None:
                return rate
        return self._server_rate(server_id)

    def _spawn(self, endpoint: Endpoint, like: StreamPuller,
               epoch_s: float) -> StreamPuller | None:
        """Open a re-leased stream mirroring the source stream's transport
        options; ``None`` when admission denies the extra lease."""
        stream_trace = self._stream_trace(len(self.pullers), endpoint)
        try:
            puller = StreamPuller(self.coordinator, endpoint, pool=self.pool,
                                  max_resumes=like.max_resumes,
                                  prefetch=like.prefetch,
                                  client_id=like.client_id,
                                  trace=stream_trace)
        except Exception:
            return None
        puller.stats.start_s = epoch_s
        if stream_trace is not None:
            # place the thief's local clock at its spawn epoch on the scan
            # timeline — its spans shift as a group at commit
            self.trace.set_shift(stream_trace.group, epoch_s)
        return puller

    def _trace_instant(self, name: str, at_s: float, **args) -> None:
        """A steal-decision instant on the scan-level track (scan-relative
        timeline: shifted by the gateway's grant clock at commit)."""
        if self.trace is not None:
            self.trace.instant(name, at_s, cat="steal", group="scan", **args)

    def _maybe_steal(self) -> Iterator[int]:
        """Run one straggler check; yields indices of new (thief) pullers."""
        if self._migrations() >= self.tracker.config.max_steals:
            return
        found = self.tracker.find_straggler(self.pullers)
        if found is None:
            return
        victim_idx, victim_eta, median_eta = found
        victim = self.pullers[victim_idx]
        victim_sid = victim.endpoint.server_id
        idle = self._idle_servers()
        if self.history is not None:
            # a flapping server may not thieve either: its rate estimate is
            # exactly as untrustworthy as when it was the victim
            idle = {sid: t for sid, t in idle.items()
                    if not self.history.quarantined(sid)}
        if not idle:
            return                       # nobody free to take the tail
        rate_v = self.tracker.rate_s(victim)
        # fastest idle replica first: best observed rate, unmeasured last
        order = sorted(idle, key=lambda sid: (self._thief_rate(sid) is None,
                                              self._thief_rate(sid) or 0.0,
                                              sid))
        for thief_sid in order:
            if thief_sid in self._declined:
                continue                 # declined; waiting on a freed slot
            headroom = self.coordinator.admission_headroom(thief_sid,
                                                           victim.client_id)
            if (headroom is not None
                    and headroom < self.tracker.config.steal_headroom_min):
                # thief's shard is at/near its local quota: stealing onto
                # it would trade the transport stall for an admission stall.
                # Decline, remember, and offer the next-fastest replica;
                # the freed-slot hook re-opens this shard when it drains.
                self._declined.add(thief_sid)
                self.steal_events.append(StealEvent(
                    victim=victim_sid, thief=thief_sid,
                    start_batch=(victim.endpoint.start_batch
                                 + victim.delivered),
                    num_batches=victim.remaining,
                    epoch_s=idle[thief_sid], victim_eta_s=victim_eta,
                    median_eta_s=median_eta, kind="decline",
                    server_id=thief_sid))
                self._trace_instant("steal.decline", idle[thief_sid],
                                    victim=victim_sid, thief=thief_sid)
                notify_coordinator(self.coordinator, "steal.decline",
                                   server_id=thief_sid,
                                   now_s=idle[thief_sid], victim=victim_sid,
                                   headroom=headroom)
                continue
            rate_t = self._thief_rate(thief_sid) or rate_v
            remaining = victim.remaining
            # split so victim and thief project to finish together:
            # keep × rate_v ≈ (remaining − keep) × rate_t — but never move
            # a tail smaller than min_batches (the churn floor)
            keep = int(remaining * rate_t / max(rate_v + rate_t, 1e-30))
            keep = min(max(keep, 0),
                       remaining - self.tracker.config.min_batches)
            epoch = max(idle[thief_sid],
                        self.tracker.finish_s(victim))   # detection point
            endpoint = Endpoint(thief_sid, victim.endpoint.sql,
                                victim.endpoint.dataset,
                                start_batch=(victim.endpoint.start_batch
                                             + victim.delivered + keep),
                                max_batches=remaining - keep)
            thief = self._spawn(endpoint, victim, epoch)
            if thief is None:
                return                   # admission denied the extra lease
            victim.split(keep)           # truncate only once the lease holds
            self.steal_events.append(StealEvent(
                victim=victim_sid, thief=thief_sid,
                start_batch=endpoint.start_batch,
                num_batches=endpoint.max_batches,
                epoch_s=epoch, victim_eta_s=victim_eta,
                median_eta_s=median_eta, server_id=thief_sid))
            self._trace_instant("steal", epoch, victim=victim_sid,
                                thief=thief_sid,
                                batches=endpoint.max_batches)
            notify_coordinator(self.coordinator, "steal",
                               server_id=thief_sid, now_s=epoch,
                               victim=victim_sid,
                               batches=endpoint.max_batches)
            self.pullers.append(thief)
            if self.history is not None:
                self.history.record_steal(victim_sid)
                self._records.append(_StealRecord(
                    thief_idx=len(self.pullers) - 1,
                    victim_sid=victim_sid, thief_sid=thief_sid))
            yield len(self.pullers) - 1
            return

    def _maybe_resteal(self) -> Iterator[int]:
        """Victim re-steal: when a thief's observed rate degrades past the
        original victim's recovered rate, the (now idle) victim reclaims the
        whole remaining tail at the thief's current lease boundary. At most
        once per stolen range — a re-stolen range is never re-examined, so
        victim↔thief ping-pong cannot happen."""
        if self.history is None:
            return
        config = self.tracker.config
        for record in self._records:
            if record.re_stolen or self._migrations() >= config.max_steals:
                continue
            thief = self.pullers[record.thief_idx]
            remaining = thief.remaining
            if (thief.drained or thief.parked or remaining is None
                    or remaining < config.min_batches):
                continue
            rate_t = self.tracker.rate_s(thief)
            if rate_t is None:
                continue
            # the victim's *recovered* rate: what its server shows now
            rate_v = self._thief_rate(record.victim_sid)
            if rate_v is None or rate_t <= rate_v * config.resteal_margin:
                continue
            idle = self._idle_servers()
            if (record.victim_sid not in idle
                    or self.history.quarantined(record.victim_sid)):
                continue                 # victim busy (or flapping itself)
            epoch = max(idle[record.victim_sid],
                        self.tracker.finish_s(thief))
            endpoint = Endpoint(record.victim_sid, thief.endpoint.sql,
                                thief.endpoint.dataset,
                                start_batch=(thief.endpoint.start_batch
                                             + thief.delivered),
                                max_batches=remaining)
            back = self._spawn(endpoint, thief, epoch)
            if back is None:
                continue                 # victim's shard denied: tail stays
            thief.split(0)               # thief keeps only what it delivered
            record.re_stolen = True
            self.steal_events.append(StealEvent(
                victim=record.thief_sid, thief=record.victim_sid,
                start_batch=endpoint.start_batch,
                num_batches=endpoint.max_batches, epoch_s=epoch,
                victim_eta_s=self.tracker.eta_s(thief) or epoch,
                median_eta_s=rate_v * remaining, kind="re_steal",
                server_id=record.victim_sid))
            self._trace_instant("steal.re_steal", epoch,
                                victim=record.thief_sid,
                                thief=record.victim_sid,
                                batches=endpoint.max_batches)
            notify_coordinator(self.coordinator, "steal.re_steal",
                               server_id=record.victim_sid, now_s=epoch,
                               victim=record.thief_sid,
                               batches=endpoint.max_batches)
            self.pullers.append(back)
            yield len(self.pullers) - 1
