"""Work stealing: move a straggler's remaining batch range mid-scan.

The cluster dataplane executes a :class:`~repro.cluster.plan.ScanPlan`
statically — once the planner has dealt batch ranges to replicas, a lagging
endpoint drags the whole critical path while faster replicas sit idle after
draining their slices. On fast fabrics that scheduling gap, not the wire, is
the bottleneck (Rödiger et al., arXiv:1502.07169). This module closes it:

* :class:`ProgressTracker` watches every stream's **modeled clock** during
  the drive loop and projects a finish time (ETA) from its observed
  per-batch rate and remaining bounded range;
* when a stream's ETA exceeds the fleet median by ``StealConfig.factor``,
  :class:`StealingPuller` splits the victim's remaining
  ``(start_batch, end_batch)`` range at the current lease boundary
  (:meth:`StreamPuller.split`) and re-leases the tail to the **fastest idle
  replica** via a fresh ``init_scan(start_batch=…)`` lease;
* every move is recorded as a :class:`StealEvent` on the scan's
  :class:`~repro.cluster.streams.ClusterStats`.

Stealing requires ``replica`` placement — only a server holding a full copy
can serve an arbitrary batch range. Shard plans pass through untouched.

Modeled-time bookkeeping: a stolen stream does not start at t=0. Its
``StreamStats.start_s`` is seeded with the steal epoch — the moment its
thief server went idle (it cannot start earlier) — so
``ClusterStats.modeled_critical_path_s`` stays an honest makespan.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator

from ..cluster.plan import Endpoint
from ..cluster.streams import MultiStreamPuller, StreamPuller


@dataclasses.dataclass(frozen=True)
class StealEvent:
    """One range migration, for the audit trail in ``ClusterStats``."""

    victim: str              # server_id the range was taken from
    thief: str               # server_id it was re-leased to
    start_batch: int         # first stolen global batch index
    num_batches: int
    epoch_s: float           # modeled time the stolen stream started
    victim_eta_s: float      # victim's projected finish before the steal
    median_eta_s: float      # fleet median ETA at the decision


@dataclasses.dataclass(frozen=True)
class StealConfig:
    """When and how aggressively to move work.

    ``factor`` is the straggler threshold: steal when a stream's projected
    finish exceeds the fleet median projection by this multiple. ``2.0`` is
    conservative (a replica must be twice as late as the median); lower it
    toward 1 for eager rebalancing, at the cost of more split/lease churn.
    """

    factor: float = 2.0
    min_batches: int = 2       # never move a tail smaller than this
    max_steals: int = 16       # per scan — runaway-split guard

    def __post_init__(self) -> None:
        if self.factor < 1.0:
            raise ValueError("steal factor must be >= 1.0")
        if self.min_batches < 1:
            raise ValueError("min_batches must be >= 1")


class ProgressTracker:
    """Projects per-stream finish times from modeled clocks.

    All arithmetic is on ``StreamStats.modeled_wire_s`` (a pure function of
    bytes/segments/ops), so straggler detection is deterministic under any
    machine load — the same trick ``modeled_critical_path_s`` uses.
    """

    def __init__(self, config: StealConfig | None = None):
        self.config = config or StealConfig()

    @staticmethod
    def finish_s(puller: StreamPuller) -> float:
        """Modeled time at which this stream is (or was) done pulling."""
        return puller.stats.start_s + puller.stats.modeled_wire_s

    def rate_s(self, puller: StreamPuller) -> float | None:
        """Observed modeled seconds per batch; ``None`` before first batch."""
        s = puller.stats
        return s.modeled_wire_s / s.batches if s.batches > 0 else None

    def eta_s(self, puller: StreamPuller) -> float | None:
        """Projected finish: progress so far plus remaining batches at the
        observed rate. ``None`` when unmeasurable (no batches yet) or
        unbounded (no known remaining range)."""
        if puller.drained:
            return self.finish_s(puller)
        rate, remaining = self.rate_s(puller), puller.remaining
        if rate is None or remaining is None:
            return None
        return self.finish_s(puller) + remaining * rate

    def find_straggler(self, pullers: list[StreamPuller]
                       ) -> tuple[int, float, float] | None:
        """The stream to steal from, or ``None`` if the fleet is balanced.

        Returns ``(victim_index, victim_eta, median_eta)``. A victim must be
        live, bounded, measurable, owe at least ``min_batches``, and project
        past ``factor ×`` the fleet median ETA.
        """
        etas = [self.eta_s(p) for p in pullers]
        known = sorted(e for e in etas if e is not None)
        if len(known) < 2:
            return None
        median = known[(len(known) - 1) // 2]
        victim, victim_eta = None, 0.0
        for idx, (p, eta) in enumerate(zip(pullers, etas)):
            if (eta is None or p.drained or p.parked
                    or (p.remaining or 0) < self.config.min_batches):
                continue
            if eta > victim_eta:
                victim, victim_eta = idx, eta
        if victim is None or victim_eta <= self.config.factor * max(median,
                                                                    1e-30):
            return None
        return victim, victim_eta, median


class StealingPuller(MultiStreamPuller):
    """A first-ready multi-stream drive that rebalances between leases.

    Drop-in for :class:`~repro.cluster.streams.MultiStreamPuller`: same
    batches, same streaming contract, plus work stealing. Consumers that
    index per-stream output by stream id must size for growth — stolen
    streams append pullers past the original plan width (the qos gateway
    reassembles by endpoint range, so it is unaffected).
    """

    def __init__(self, coordinator, plan, steal: StealConfig | None = None,
                 **kwargs):
        kwargs.setdefault("schedule", "first_ready")
        super().__init__(coordinator, plan, **kwargs)
        self.tracker = ProgressTracker(steal)
        self._stealable = (plan.placement == "replica")

    @staticmethod
    def _modeled_clock(puller: StreamPuller) -> float:
        """Stream progress on the *modeled* timeline only. The drive loop
        must sequence leases (and therefore steal decisions) by modeled
        time — the measured components of ``clock_s`` (host memcpy wall
        time) are similar across streams and would mask the very lag the
        tracker is looking for."""
        s = puller.stats
        return (s.start_s + s.modeled_wire_s + s.control_rpc_s
                + s.throttle_wait_s)

    # ----------------------------------------------------------- drive loop
    def _drive(self):
        try:
            heap = [(0.0, idx) for idx in range(len(self.pullers))]
            heapq.heapify(heap)
            while heap:
                _, idx = heapq.heappop(heap)
                yield from self._lease(idx)
                puller = self.pullers[idx]
                if not puller.drained:
                    heapq.heappush(heap, (self._modeled_clock(puller), idx))
                for new_idx in self._maybe_steal():
                    thief = self.pullers[new_idx]
                    heapq.heappush(
                        heap, (self._modeled_clock(thief), new_idx))
        finally:
            self._abandon()

    # ------------------------------------------------------------- stealing
    def _idle_servers(self) -> dict[str, float]:
        """server_id → idle-since epoch for replicas with no live stream of
        this scan. A server never leased by this scan is idle from t=0."""
        hosts = self.coordinator.hosts(self.plan.dataset)
        busy = {p.endpoint.server_id for p in self.pullers if not p.drained}
        idle: dict[str, float] = {}
        for sid in hosts:
            if sid in busy:
                continue
            drained = [p for p in self.pullers
                       if p.endpoint.server_id == sid and p.drained]
            idle[sid] = max((self.tracker.finish_s(p) for p in drained),
                            default=0.0)
        return idle

    def _server_rate(self, server_id: str) -> float | None:
        """Observed per-batch modeled rate of a server's drained streams."""
        rates = [self.tracker.rate_s(p) for p in self.pullers
                 if p.endpoint.server_id == server_id
                 and p.stats.batches > 0]
        rates = [r for r in rates if r is not None]
        return min(rates) if rates else None

    def _maybe_steal(self) -> Iterator[int]:
        """Run one straggler check; yields indices of new (thief) pullers."""
        if (not self._stealable
                or len(self.steal_events) >= self.tracker.config.max_steals):
            return
        found = self.tracker.find_straggler(self.pullers)
        if found is None:
            return
        victim_idx, victim_eta, median_eta = found
        victim = self.pullers[victim_idx]
        idle = self._idle_servers()
        if not idle:
            return                       # nobody free to take the tail
        # fastest idle replica: best observed rate, unmeasured servers last
        rate_v = self.tracker.rate_s(victim)
        thief_sid = min(
            idle, key=lambda sid: (self._server_rate(sid) is None,
                                   self._server_rate(sid) or 0.0, sid))
        rate_t = self._server_rate(thief_sid) or rate_v
        remaining = victim.remaining
        # split so victim and thief project to finish together:
        # keep × rate_v ≈ (remaining − keep) × rate_t — but never move a
        # tail smaller than min_batches (the churn floor)
        keep = int(remaining * rate_t / max(rate_v + rate_t, 1e-30))
        keep = min(max(keep, 0), remaining - self.tracker.config.min_batches)
        epoch = max(idle[thief_sid],
                    self.tracker.finish_s(victim))   # detection point
        endpoint = Endpoint(thief_sid, victim.endpoint.sql,
                            victim.endpoint.dataset,
                            start_batch=(victim.endpoint.start_batch
                                         + victim.delivered + keep),
                            max_batches=remaining - keep)
        try:
            thief = StreamPuller(self.coordinator, endpoint, pool=self.pool,
                                 max_resumes=victim.max_resumes,
                                 prefetch=victim.prefetch,
                                 client_id=victim.client_id)
        except Exception:
            return                       # admission denied the extra lease
        thief.stats.start_s = epoch
        victim.split(keep)               # truncate only once the lease holds
        self.steal_events.append(StealEvent(
            victim=victim.endpoint.server_id, thief=thief_sid,
            start_batch=endpoint.start_batch,
            num_batches=endpoint.max_batches,
            epoch_s=epoch, victim_eta_s=victim_eta, median_eta_s=median_eta))
        self.pullers.append(thief)
        yield len(self.pullers) - 1
