"""The adaptive scheduler: one object bundling the three mechanisms.

``AdaptiveScheduler`` is what callers hand to the qos
:class:`~repro.qos.ScanGateway` (or use directly against a coordinator):
turn on any subset of work stealing (:mod:`.steal`), shared tickets
(:mod:`.share`) and lease-boundary preemption (:mod:`.preempt`) by setting
the corresponding config. ``AdaptiveScheduler.default()`` enables all three
with conservative knobs.
"""
from __future__ import annotations

import dataclasses

from ..cluster.plan import ScanPlan
from ..cluster.streams import MultiStreamPuller
from .preempt import PreemptConfig
from .share import TicketTable
from .steal import StealConfig, StealingPuller


@dataclasses.dataclass
class AdaptiveScheduler:
    """Adaptive execution policy between the gateway and the dataplane."""

    steal: StealConfig | None = None
    tickets: TicketTable | None = None
    preempt: PreemptConfig | None = None

    @classmethod
    def default(cls) -> "AdaptiveScheduler":
        """All three mechanisms on, conservative thresholds."""
        return cls(steal=StealConfig(), tickets=TicketTable(),
                   preempt=PreemptConfig())

    def make_puller(self, coordinator, plan: ScanPlan,
                    **kwargs) -> MultiStreamPuller:
        """The dataplane driver for one fan-out: a stealing puller when
        stealing is enabled, the plain static one otherwise."""
        if self.steal is not None:
            return StealingPuller(coordinator, plan, steal=self.steal,
                                  **kwargs)
        return MultiStreamPuller(coordinator, plan, **kwargs)
