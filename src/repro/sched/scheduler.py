"""The adaptive scheduler: one object bundling the three mechanisms.

``AdaptiveScheduler`` is what callers hand to the qos
:class:`~repro.qos.ScanGateway` (or use directly against a coordinator):
turn on any subset of work stealing (:mod:`.steal`), shared tickets
(:mod:`.share`) and lease-boundary preemption (:mod:`.preempt`) by setting
the corresponding config. ``AdaptiveScheduler.default()`` enables all three
with conservative knobs.

The scheduler also owns the cross-scan state the mechanisms learn from:
``history`` (a :class:`~.steal.RateHistory`) lives here — NOT on the
per-scan puller — so per-server EWMA rates, flap quarantines and
repeat-straggler counts persist across every fan-out this scheduler drives.
A repeat straggler is stolen from earlier on the next scan, and a server
quarantined for flapping stays quarantined into the next scan's decisions.
"""
from __future__ import annotations

import dataclasses

from ..cluster.plan import ScanPlan
from ..cluster.streams import MultiStreamPuller
from .preempt import PreemptConfig
from .share import TicketTable
from .steal import RateHistory, StealConfig, StealingPuller


@dataclasses.dataclass
class AdaptiveScheduler:
    """Adaptive execution policy between the gateway and the dataplane."""

    steal: StealConfig | None = None
    tickets: TicketTable | None = None
    preempt: PreemptConfig | None = None
    history: RateHistory | None = None

    @classmethod
    def default(cls) -> "AdaptiveScheduler":
        """All three mechanisms on, conservative thresholds, with a
        persistent rate history feeding the steal decisions."""
        return cls(steal=StealConfig(), tickets=TicketTable(),
                   preempt=PreemptConfig(), history=RateHistory())

    def make_puller(self, coordinator, plan: ScanPlan,
                    **kwargs) -> MultiStreamPuller:
        """The dataplane driver for one fan-out: a stealing puller when
        stealing is enabled, the plain static one otherwise. The shared
        ``history`` rides along so this scan's rate observations inform the
        next scan's steal thresholds. A ``trace=`` kwarg (an
        ``obs.TraceContext`` from the gateway) passes through untouched —
        the puller fans it out into per-stream child traces."""
        if self.steal is not None:
            return StealingPuller(coordinator, plan, steal=self.steal,
                                  history=self.history, **kwargs)
        return MultiStreamPuller(coordinator, plan, **kwargs)
