"""Request batcher for serving: aligned-cohort continuous batching.

The decode step is batch-uniform (one scalar position — see
``transformer_decode``), so the batcher groups requests into *cohorts*:
prompts padded left to a common length, decoded in lockstep, retired when
they emit EOS or hit ``max_new_tokens``. Freed slots are refilled from the
queue at the next cohort boundary. Responses leave the server as record
batches over the Thallus transport (the paper's protocol in the serving
direction).

Prompt ingestion rides the qos gateway: :meth:`Batcher.submit_scan` turns a
prompt-table query into one logical :class:`~repro.qos.ScanRequest` — the
gateway fans it out across shard servers, pulls the streams concurrently,
reassembles them in scan order, and :meth:`Batcher.ingest_batches` converts
the resulting token batches into decode requests. Serving traffic thereby
competes under the same weighted-fair admission as every other client
(interactive class by default) instead of bypassing the reader map.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from ..core.recordbatch import batch_from_pydict
from ..core.schema import schema as make_schema

RESPONSE_SCHEMA = make_schema(("request_id", "int64"), ("token", "int32"),
                              ("position", "int32"))


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: list[int]


class Batcher:
    """prefill_fn(tokens (B,S)) -> (logits, cache);
    decode_fn(cache, tokens (B,1), position) -> (logits, cache)."""

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 batch_size: int, pad_id: int = 0):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: deque[Request] = deque()

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- qos-gateway ingestion ---------------------------------------------
    def submit_scan(self, gateway, sql: str, dataset: str, *,
                    client_id: str = "serving", klass: str = "interactive",
                    cost_hint: float = 1.0, deadline_s: float | None = None,
                    num_streams: int | None = None, start_batch: int = 0,
                    arrival_s: float = 0.0):
        """Submit the prompt-fetch scan as one logical gateway request.
        Returns the id-assigned :class:`~repro.qos.ScanRequest`, or ``None``
        when the gateway shed it at submit (deadline would be blown).
        Run the gateway, then feed ``gateway.result(req.request_id)`` to
        :meth:`ingest_batches` (or use :meth:`ingest_scan`).

        Under a gateway with a ``repro.sched`` scheduler attached, serving
        traffic gets the adaptive behaviors for free: replicas submitting
        the same ``(sql, dataset, start_batch)`` prompt fetch coalesce onto
        one shared-ticket fan-out, and — being interactive-class by
        default — an arriving prompt fetch preempts heavy batch scans at
        their next lease boundary instead of waiting behind them."""
        from ..qos import ScanRequest   # serving -> qos only on this path
        return gateway.submit(ScanRequest(
            client_id=client_id, klass=klass, sql=sql, dataset=dataset,
            cost_hint=cost_hint, deadline_s=deadline_s,
            num_streams=num_streams, start_batch=start_batch,
            arrival_s=arrival_s))

    def ingest_scan(self, gateway, request, seq_len: int, *,
                    max_new_tokens: int = 16, eos_id: int | None = None,
                    start_id: int = 0) -> tuple[int, bool]:
        """Fetch a completed :meth:`submit_scan` result and enqueue its
        sequences. Returns ``(num_requests, shared)`` — ``shared`` is True
        when the result arrived by shared-ticket multicast (another
        subscriber's fan-out did the server-side work)."""
        result = gateway.result(request.request_id)
        if result is None:              # shed or failed while queued
            return 0, False
        n = self.ingest_batches(result.batches, seq_len,
                                max_new_tokens=max_new_tokens,
                                eos_id=eos_id, start_id=start_id)
        return n, result.shared

    def ingest_batches(self, batches, seq_len: int, *,
                       max_new_tokens: int = 16, eos_id: int | None = None,
                       start_id: int = 0) -> int:
        """Turn reassembled token record batches (a gateway ``ScanResult``'s
        payload) into decode requests, one per sequence, in scan order.
        Returns the number of requests enqueued."""
        from ..data.tokens import batch_to_tokens
        rid = start_id
        for rb in batches:
            for seq in batch_to_tokens(rb, seq_len):
                self.submit(Request(rid, np.asarray(seq, np.int32).copy(),
                                    max_new_tokens=max_new_tokens,
                                    eos_id=eos_id))
                rid += 1
        return rid - start_id

    def _next_cohort(self) -> list[Request]:
        cohort = []
        while self.queue and len(cohort) < self.batch_size:
            cohort.append(self.queue.popleft())
        return cohort

    def run(self) -> list[Completion]:
        """Drain the queue, cohort by cohort. Greedy decoding."""
        done: list[Completion] = []
        while self.queue:
            cohort = self._next_cohort()
            B = len(cohort)
            max_prompt = max(len(r.prompt) for r in cohort)
            toks = np.full((B, max_prompt), self.pad_id, np.int32)
            for i, r in enumerate(cohort):
                toks[i, max_prompt - len(r.prompt):] = r.prompt  # left pad
            logits, cache = self.prefill_fn(jnp.asarray(toks))
            # grow cache along seq for the new tokens
            budget = max(r.max_new_tokens for r in cohort)
            cache = jax.tree.map(
                lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, budget)]
                                  + [(0, 0)] * (x.ndim - 3))
                if x.ndim >= 4 and x.shape[2] == max_prompt else x, cache)
            outputs: list[list[int]] = [[] for _ in cohort]
            alive = np.ones(B, bool)
            next_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                                  np.int32)
            for step in range(budget):
                pos = max_prompt + step
                for i, r in enumerate(cohort):
                    if alive[i]:
                        outputs[i].append(int(next_tok[i]))
                        if ((r.eos_id is not None and next_tok[i] == r.eos_id)
                                or len(outputs[i]) >= r.max_new_tokens):
                            alive[i] = False
                if not alive.any() or step == budget - 1:
                    break
                logits, cache = self.decode_fn(
                    cache, jnp.asarray(next_tok)[:, None], jnp.int32(pos))
                next_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                                      np.int32)
            done.extend(Completion(r.request_id, outputs[i])
                        for i, r in enumerate(cohort))
        return done


def completions_to_batch(completions: list[Completion]):
    """Results as a record batch (rides the Thallus transport back)."""
    rid, tok, pos = [], [], []
    for c in completions:
        for j, t in enumerate(c.tokens):
            rid.append(c.request_id)
            tok.append(int(t))
            pos.append(j)
    return batch_from_pydict(RESPONSE_SCHEMA,
                             {"request_id": rid, "token": tok, "position": pos})
