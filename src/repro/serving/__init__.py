from .batcher import Batcher, Completion, Request, completions_to_batch  # noqa: F401
