"""Oracle for the flash-attention kernel: plain materialized attention."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """q/k/v: (BH, S, hd) (heads pre-expanded) -> (BH, Sq, hd)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)
