"""jit wrapper: GQA expansion + shape management for the flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import flash_attention
from .ref import attention_ref  # noqa: F401 (re-export oracle)


def flash_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) with H % KV == 0.
    Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    out = flash_attention(qf, kf, vf, causal=causal, interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
