"""Pallas TPU flash-attention (forward): the kernel behind the
``vmem_fused_attention`` roofline accounting.

Grid: (batch·heads, Sq/BLK_Q). Each step holds one query block in VMEM and
loops over KV blocks with the online-softmax recurrence — scores and p
matrices NEVER touch HBM; per-step HBM traffic is exactly q-block + the
streamed k/v blocks + the output block, which is what the fused memory
model in repro.utils.hlo_cost charges.

Production notes (real-TPU variant): k/v would stream via double-buffered
async copies and the backward recomputes p per block (same schedule our
checkpointed jnp scan uses); this forward is the validated seed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30
BLK_Q = 128
BLK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, sk: int,
                  blk_k: int, scale: float):
    # index the unit batch axis with a length-1 slice, not a bare int:
    # jax 0.4.37's interpret-mode discharge rule only accepts Slice/array
    # indices inside pl.load/pl.store
    q = pl.load(q_ref, (pl.ds(0, 1), slice(None), slice(None))
                )[0].astype(jnp.float32) * scale      # (BLK_Q, hd)
    q_block = pl.program_id(1)
    q_pos = q_block * BLK_Q + jax.lax.broadcasted_iota(jnp.int32, (BLK_Q, 1), 0)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_blk = pl.load(k_ref, (pl.ds(0, 1), pl.ds(i * blk_k, blk_k),
                                slice(None)))[0].astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.ds(0, 1), pl.ds(i * blk_k, blk_k),
                                slice(None)))[0].astype(jnp.float32)
        s = q @ k_blk.T                                # (BLK_Q, blk_k) VMEM
        if causal:
            k_pos = i * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, blk_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        acc = acc * corr + p @ v_blk
        return m_new, l_new, acc

    m0 = jnp.full((BLK_Q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((BLK_Q, 1), jnp.float32)
    a0 = jnp.zeros((BLK_Q, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, sk // blk_k, body, (m0, l0, a0))
    out = (acc / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)
    pl.store(o_ref, (pl.ds(0, 1), slice(None), slice(None)), out[None])


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd); heads pre-expanded (GQA handled
    by the ops wrapper). Sq % 128 == 0, Sk % 128 == 0."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    kernel = functools.partial(_flash_kernel, causal=causal, sk=Sk,
                               blk_k=min(BLK_K, Sk), scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // BLK_Q),
        in_specs=[
            pl.BlockSpec((1, BLK_Q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLK_Q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
