"""Pallas TPU kernels: selection-vector row gather + validity-bitmap expand.

``take`` drives the column-selectivity path: after a WHERE filter produces a
selection vector, every projected column gathers its surviving rows. The
selection vector rides in scalar-prefetch SMEM so the HBM→VMEM DMA for each
row block is steered directly by indices (no second pass).

Row blocking: indices are processed in blocks of ``ROW_BLOCK`` output rows;
each kernel step copies one (1, width_block) row stripe. Width is tiled at
128 lanes (VPU lane width). For f32 the sublane dim wants multiples of 8 —
we gather row-at-a-time which Mosaic handles via strided DMA; on real HW a
production variant would gather 8 rows per step into a (8,128) tile.

``bitmap_expand`` turns Arrow's LSB-packed validity bytes into a bool mask
with a shift-and-mask inside VMEM: (8,128) bytes → (8,1024) bools per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BITS = 8


def _take_kernel(idx_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def take_rows(values: jax.Array, indices: jax.Array, *,
              interpret: bool = True) -> jax.Array:
    """out[i] = values[indices[i]]. values: (n_rows, width) with width a
    multiple of 128; indices: (n_out,) int32."""
    n_out = indices.shape[0]
    width = values.shape[1]
    w_tiles = width // LANES
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out, w_tiles),
        in_specs=[
            pl.BlockSpec((1, LANES), lambda i, j, idx: (idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i, j, idx: (i, j)),
    )
    return pl.pallas_call(
        _take_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, width), values.dtype),
        interpret=interpret,
    )(indices, values)


def _bitmap_kernel(bm_ref, out_ref):
    bytes_ = bm_ref[...]                                   # (8, 128) uint8
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (8, LANES, BITS), 2)
    bits = (bytes_[:, :, None] >> shifts) & jnp.uint8(1)   # (8, 128, 8)
    out_ref[...] = bits.reshape(8, LANES * BITS).astype(jnp.bool_)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitmap_expand(bitmap: jax.Array, *, interpret: bool = True) -> jax.Array:
    """LSB-packed bits -> bool. bitmap: (n_bytes,) uint8 with n_bytes a
    multiple of 8*128; -> (n_bytes * 8,) bool."""
    n_bytes = bitmap.shape[0]
    rows = n_bytes // LANES
    bm2d = bitmap.reshape(rows, LANES)
    out = pl.pallas_call(
        _bitmap_kernel,
        grid=(rows // 8,),
        in_specs=[pl.BlockSpec((8, LANES), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((8, LANES * BITS), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES * BITS), jnp.bool_),
        interpret=interpret,
    )(bm2d)
    return out.reshape(-1)
