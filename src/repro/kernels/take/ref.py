"""Pure-jnp oracles for the selection-vector kernels (query-engine hot spot
behind the paper's column-selectivity experiments)."""
from __future__ import annotations

import jax.numpy as jnp


def take_ref(values: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """out[i, :] = values[indices[i], :] — row gather on a fixed-width column
    laid out (rows, width)."""
    return values[indices]


def bitmap_expand_ref(bitmap: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """LSB-packed uint8[ceil(n/8)] -> bool[num_rows] (Arrow validity)."""
    bits = jnp.unpackbits(bitmap, bitorder="little")
    return bits[:num_rows].astype(jnp.bool_)
