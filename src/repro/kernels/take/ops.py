"""jit'd wrappers: padding/shape management for take + bitmap_expand."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .ref import bitmap_expand_ref, take_ref  # noqa: F401 (re-export oracles)
from .take import LANES, bitmap_expand, take_rows

_BM_ALIGN = 8 * LANES  # bitmap kernel granularity in bytes


def take_column(values: np.ndarray | jax.Array, indices: np.ndarray | jax.Array,
                *, interpret: bool = True) -> jax.Array:
    """Row-gather a 1-D or 2-D fixed-width column by a selection vector.
    Handles width padding to the 128-lane tile and restores the shape."""
    values = jnp.asarray(values)
    indices = jnp.asarray(indices, jnp.int32)
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    n, w = values.shape
    w_pad = -w % LANES
    if w_pad:
        values = jnp.pad(values, ((0, 0), (0, w_pad)))
    out = take_rows(values, indices, interpret=interpret)
    out = out[:, :w]
    return out[:, 0] if squeeze else out


def expand_validity(bitmap: np.ndarray | jax.Array, num_rows: int, *,
                    interpret: bool = True) -> jax.Array:
    """Arrow validity bitmap -> bool mask of length num_rows."""
    bitmap = jnp.asarray(bitmap, jnp.uint8)
    pad = -bitmap.shape[0] % _BM_ALIGN
    if pad:
        bitmap = jnp.pad(bitmap, (0, pad))
    mask = bitmap_expand(bitmap, interpret=interpret)
    return mask[:num_rows]
