from .ops import expand_validity, take_column  # noqa: F401
from .take import bitmap_expand, take_rows  # noqa: F401
from .ref import bitmap_expand_ref, take_ref  # noqa: F401
