"""Pallas TPU kernels for the paper's memory-movement hot spots."""
