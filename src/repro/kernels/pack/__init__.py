from .ops import pack_segments, unpack_segments, packed_nbytes, routing, inverse_routing  # noqa: F401
from .pack import pack_tiles, unpack_tiles  # noqa: F401
from .ref import TILE_BYTES, TILE_LANES, TILE_ROWS, pack_ref, unpack_ref, stage_segments, layout_segments, tiles_for  # noqa: F401
