"""jit'd public wrappers around the pack/unpack Pallas kernels.

``pack_segments`` is the end-to-end on-device serialize: numpy/JAX buffers →
staged ragged-2D form → tile-routed gather → one contiguous packed buffer.
``unpack_segments`` reverses it. These are the device analogues of
:func:`repro.core.serialize.pack` / ``unpack`` and the benchmark units for
the serialization-overhead measurements.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .pack import pack_tiles, unpack_tiles
from .ref import (TILE_BYTES, TILE_LANES, TILE_ROWS, layout_segments,
                  stage_segments, tiles_for)


def routing(seg_lens: list[int]) -> tuple[np.ndarray, np.ndarray]:
    seg_ids, tile_ids, _ = layout_segments(seg_lens)
    return seg_ids, tile_ids


def inverse_routing(seg_lens: list[int], max_tiles: int) -> np.ndarray:
    """gather_ids[s*max_tiles + t] = packed index of (s, t), or the zero-tile
    sentinel (== n_out_tiles) for ragged padding."""
    seg_ids, tile_ids, n_out = layout_segments(seg_lens)
    n_seg = len(seg_lens)
    inv = np.full(n_seg * max_tiles, n_out, dtype=np.int32)
    for packed_idx, (s, t) in enumerate(zip(seg_ids, tile_ids)):
        inv[s * max_tiles + t] = packed_idx
    return inv


def pack_segments(segments: list[np.ndarray], *,
                  interpret: bool = True) -> tuple[jax.Array, list[int]]:
    """Serialize: list of arbitrary-dtype buffers -> (packed uint8 tiles,
    per-segment byte lengths). packed shape: (n_out_tiles, 32, 128)."""
    staged, seg_lens = stage_segments(segments)
    seg_ids, tile_ids = routing([int(n) for n in seg_lens])
    packed = pack_tiles(jnp.asarray(staged), jnp.asarray(seg_ids),
                        jnp.asarray(tile_ids), interpret=interpret)
    return packed, [int(n) for n in seg_lens]


def unpack_segments(packed: jax.Array, seg_lens: list[int], *,
                    interpret: bool = True) -> list[np.ndarray]:
    """Deserialize: packed tiles + size vector -> per-segment uint8 buffers
    (caller re-views dtypes, as in Arrow's buffers+sizes+dtypes assembly)."""
    max_tiles = max(tiles_for(n) for n in seg_lens)
    inv = inverse_routing(seg_lens, max_tiles)
    zero = jnp.zeros((1, TILE_ROWS, TILE_LANES), jnp.uint8)
    padded = jnp.concatenate([packed, zero], axis=0)
    ragged = unpack_tiles(padded, jnp.asarray(inv), n_seg=len(seg_lens),
                          max_tiles=max_tiles, interpret=interpret)
    out = []
    for i, n in enumerate(seg_lens):
        flat = np.asarray(ragged[i]).reshape(-1)
        out.append(flat[:n])
    return out


def packed_nbytes(seg_lens: list[int]) -> int:
    return sum(tiles_for(n) for n in seg_lens) * TILE_BYTES
