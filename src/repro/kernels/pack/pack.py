"""Pallas TPU kernels: tile-routed segment pack / unpack.

This is the *baseline's* serialization memcpy expressed as a TPU kernel (the
cost Thallus deletes) plus its inverse. Both are pure data-movement kernels:
grid = one step per tile, the routing table (which segment / which tile)
rides in scalar-prefetch SMEM so the BlockSpec ``index_map`` can steer the
HBM→VMEM DMA directly — the copy itself is a single VMEM tile assignment,
i.e. the kernel runs at DMA speed, which is the roofline for serialization.

Block shape: (TILE_ROWS=32, TILE_LANES=128) uint8 — the minimal aligned tile
for 8-bit data on TPU, 4 KiB per step, well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import TILE_LANES, TILE_ROWS


def _copy_kernel(seg_ids, tile_ids, src_ref, out_ref):
    # Routing already happened in the index_map; the body is the DMA'd copy.
    # src block is (1, 1, 32, 128); out block is (1, 32, 128).
    out_ref[...] = src_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack_tiles(src: jax.Array, seg_ids: jax.Array, tile_ids: jax.Array,
               *, interpret: bool = True) -> jax.Array:
    """Gather routed tiles: out[t] = src[seg_ids[t], tile_ids[t]].

    src: (n_seg, max_tiles, 32, 128) uint8
    seg_ids/tile_ids: (n_out_tiles,) int32 scalar-prefetch routing table
    -> (n_out_tiles, 32, 128) uint8 packed buffer
    """
    n_out = seg_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_out,),
        in_specs=[
            pl.BlockSpec((1, 1, TILE_ROWS, TILE_LANES),
                         lambda t, seg_ids, tile_ids: (seg_ids[t], tile_ids[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE_ROWS, TILE_LANES),
                               lambda t, seg_ids, tile_ids: (t, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, TILE_ROWS, TILE_LANES), jnp.uint8),
        interpret=interpret,
    )(seg_ids, tile_ids, src)


@functools.partial(jax.jit, static_argnames=("n_seg", "max_tiles", "interpret"))
def unpack_tiles(packed: jax.Array, gather_ids: jax.Array,
                 *, n_seg: int, max_tiles: int,
                 interpret: bool = True) -> jax.Array:
    """Inverse gather: out[s, t] = packed[gather_ids[s*max_tiles + t]].

    ``gather_ids`` is the *inverse* routing table (see
    :func:`repro.kernels.pack.ops.inverse_routing`); padding tiles point at a
    zero tile appended past the packed payload, so the kernel stays a pure
    gather — every output tile is written exactly once, no scatter hazards.
    packed: (n_out_tiles + 1, 32, 128) with packed[-1] == 0.
    """
    n_total = n_seg * max_tiles

    def kernel(gather_ids, packed_ref, out_ref):
        # packed block (1, 32, 128) -> out block (1, 1, 32, 128).
        out_ref[...] = packed_ref[...][None]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_total,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_LANES),
                         lambda t, gather_ids: (gather_ids[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, TILE_ROWS, TILE_LANES),
                               lambda t, gather_ids: (t // max_tiles, t % max_tiles, 0, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_seg, max_tiles, TILE_ROWS, TILE_LANES),
                                       jnp.uint8),
        interpret=interpret,
    )(gather_ids, packed)
    return out
