"""Pure-jnp oracle for the segment pack/unpack kernels.

Layout convention (TPU-native adaptation of Arrow buffer padding):

* every segment is padded to a multiple of one VMEM tile
  (``TILE_ROWS×TILE_LANES`` bytes — Arrow pads to 64 B for the same
  alignment reason, we pad to the TPU tile);
* the packed buffer is the tile-aligned concatenation, so segment starts
  are always tile boundaries and the kernel is a pure tile-gather with a
  scalar-prefetched routing table (no unaligned copies on the MXU-free
  data path).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

TILE_ROWS = 32
TILE_LANES = 128
TILE_BYTES = TILE_ROWS * TILE_LANES  # 4096


def tiles_for(nbytes: int) -> int:
    return max(1, -(-nbytes // TILE_BYTES))


def layout_segments(seg_lens: list[int]) -> tuple[np.ndarray, np.ndarray, int]:
    """Routing table for the kernel.

    Returns (seg_ids, tile_ids, total_tiles): for every *output* tile t,
    which segment it comes from and which tile within that segment.
    """
    seg_ids, tile_ids = [], []
    for s, n in enumerate(seg_lens):
        for t in range(tiles_for(n)):
            seg_ids.append(s)
            tile_ids.append(t)
    return (np.asarray(seg_ids, np.int32), np.asarray(tile_ids, np.int32),
            len(seg_ids))


def stage_segments(segments: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Host-side staging into the kernel's ragged-2D form:
    (n_seg, max_tiles, TILE_ROWS, TILE_LANES) uint8 + per-segment byte lens."""
    seg_lens = np.asarray([s.nbytes for s in segments], np.int32)
    max_tiles = max(tiles_for(int(n)) for n in seg_lens)
    out = np.zeros((len(segments), max_tiles, TILE_ROWS, TILE_LANES), np.uint8)
    for i, s in enumerate(segments):
        raw = s.reshape(-1).view(np.uint8)
        out[i].reshape(-1)[: raw.nbytes] = raw
    return out, seg_lens


def pack_ref(src: jnp.ndarray, seg_ids: jnp.ndarray,
             tile_ids: jnp.ndarray) -> jnp.ndarray:
    """Oracle: gather the routed tiles. src (n_seg, max_tiles, R, L) ->
    (n_out_tiles, R, L)."""
    return src[seg_ids, tile_ids]


def unpack_ref(packed: jnp.ndarray, seg_ids: jnp.ndarray,
               tile_ids: jnp.ndarray, n_seg: int,
               max_tiles: int) -> jnp.ndarray:
    """Oracle for the inverse: scatter packed tiles back into the ragged-2D
    segment form (tiles not covered stay zero)."""
    out = jnp.zeros((n_seg, max_tiles) + packed.shape[1:], packed.dtype)
    return out.at[seg_ids, tile_ids].set(packed)
