"""Roofline terms from a compiled dry-run artifact.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_wire_bytes_per_device / link_bw

``cost_analysis()`` on the partitioned module reports *per-device* flops and
bytes; the collective bytes come from the ring-model HLO parse
(:mod:`repro.utils.hlo`). MODEL_FLOPS uses the 6·N·D (train) / 2·N·D
(inference) convention with N_active for MoE.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_per_device: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        if self.flops_per_device == 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization the roofline permits (useful work over
        peak at the bottleneck-dictated step time)."""
        if self.step_s == 0:
            return 0.0
        return self.model_flops_per_device / (self.step_s * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops(num_params: int, tokens: int, kind: str) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * num_params * tokens
