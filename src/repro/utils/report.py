"""Render EXPERIMENTS.md tables: dryrun/roofline artifacts, plus the
transport-side buffer-pool and qos summaries (duck-typed against
``repro.cluster.PoolStats`` / ``repro.qos.QosStats`` so this module stays
dependency-free)."""
from __future__ import annotations

import glob
import json
import os


def load_artifacts(art_dir: str = "artifacts/dryrun") -> list[dict]:
    arts = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            arts.append(json.load(f))
    return arts


def mesh_tag(art: dict) -> str:
    return "x".join(str(v) for v in art["mesh"].values())


def roofline_table(arts: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | fits | peak GiB/dev | C (ms) | M (ms) | "
            "M fused (ms) | X (ms) | bottleneck | useful | MFU bound | "
            "one-line lever |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    levers = {
        "collective": "cut TP activation/weight gathers (layout or replication)",
        "memory": "fuse attention interior into VMEM (Pallas splash) / smaller dtype",
        "compute": "already MXU-bound: raise per-chip batch or quit early",
    }
    for a in arts:
        if mesh_tag(a) != mesh:
            continue
        if a["status"] == "skipped":
            rows.append(f"| {a['arch']} | {a['shape']} | — | — | — | — | — | "
                        f"— | skipped | — | — | {a['skip_reason']} |")
            continue
        if a["status"] == "error":
            rows.append(f"| {a['arch']} | {a['shape']} | — | — | — | — | — | "
                        f"— | ERROR | — | — | {a['error'][:60]} |")
            continue
        r = a["roofline"]
        rf = a.get("roofline_fused", r)
        m = a["memory"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | "
            f"{'✓' if m.get('fits_hbm') else '✗'} | "
            f"{m['peak_bytes_per_device']/2**30:.2f} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{rf['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {rf['bottleneck']} | "
            f"{r['useful_flops_ratio']:.2f} | {rf['mfu_bound']:.3f} | "
            f"{levers[rf['bottleneck']]} |")
    return "\n".join(rows)


def dryrun_table(arts: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | status | compile s | args GiB | temp GiB | "
            "flops/dev | bytes/dev | collectives (count) | wire MiB/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if mesh_tag(a) != mesh:
            continue
        if a["status"] != "ok":
            rows.append(f"| {a['arch']} | {a['shape']} | {a['status']} | — | — "
                        f"| — | — | — | — | — |")
            continue
        lc = a["loop_cost"]
        counts = ", ".join(f"{k}:{int(v)}" for k, v in
                           sorted(lc["collective_counts"].items()))
        rows.append(
            f"| {a['arch']} | {a['shape']} | ok | {a['compile_s']:.0f} | "
            f"{a['memory']['argument_bytes']/2**30:.2f} | "
            f"{a['memory']['temp_bytes']/2**30:.2f} | "
            f"{lc['flops']:.2e} | {lc['bytes']:.2e} | {counts} | "
            f"{lc['collective_wire_bytes']/2**20:.0f} |")
    return "\n".join(rows)


def render_table(columns, rows) -> str:
    """The one markdown table builder every ``*_table`` helper sits on:
    a header row, the ``|---|`` separator, one row per cell list. Cells
    are stringified as-is — formatting (units, precision) stays with the
    callers, which own their stats' semantics."""
    out = ["| " + " | ".join(str(c) for c in columns) + " |",
           "|" + "---|" * len(columns)]
    for row in rows:
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(out)


def pool_table(stats) -> str:
    """One-row markdown table for a ``repro.cluster.PoolStats``."""
    return render_table(
        ["hit rate", "hits", "misses", "slabs", "resident MiB",
         "evictions", "evicted MiB", "registered segs", "register us"],
        [[f"{stats.hit_rate:.2f}", stats.hits, stats.misses,
          stats.slabs_created, f"{stats.bytes_resident / 2**20:.2f}",
          stats.evictions, f"{stats.bytes_evicted / 2**20:.2f}",
          stats.registered_segments,
          f"{stats.modeled_register_s * 1e6:.1f}"]])


def qos_table(qos) -> str:
    """Per-class markdown table for a ``repro.qos.QosStats`` (grant latency,
    sheds, throughput), with the gateway-level counters in a footer row."""
    rows = []
    for name in sorted(qos.classes):
        c = qos.classes[name]
        rows.append([name, f"{c.granted}/{c.submitted}", c.shed,
                     f"{c.p50_grant_latency_s * 1e3:.3f}",
                     f"{c.max_grant_latency_s * 1e3:.3f}",
                     f"{c.throughput_bytes_per_s / 1e6:.1f}", c.bytes])
    rows.append(["*gateway*", f"{qos.granted}/{qos.submitted}", qos.shed,
                 f"depth_max={qos.queue_depth_max}",
                 f"throttle={qos.throttle_wait_s * 1e3:.3f}",
                 f"makespan={qos.makespan_s * 1e3:.3f}", qos.bytes])
    return render_table(
        ["class", "granted", "shed", "p50 grant ms", "max grant ms",
         "throughput MB/s", "bytes"], rows)


def sched_table(qos) -> str:
    """Per-class markdown table for the adaptive-scheduler counters a
    ``repro.qos.QosStats`` carries (steals / shared-ticket hits /
    preemptions), rendered alongside :func:`pool_table` / :func:`qos_table`.
    Duck-typed like its siblings so this module stays dependency-free."""
    rows = []
    for name in sorted(qos.classes):
        c = qos.classes[name]
        rows.append([name, f"{c.granted}/{c.submitted}", c.ticket_hits,
                     c.preemptions, f"{c.p50_grant_latency_s * 1e3:.3f}",
                     f"{c.service_s * 1e3:.3f}"])
    hit_rate = qos.ticket_hits / qos.granted if qos.granted else 0.0
    rows.append(["*sched*", f"steals={qos.steals}",
                 f"hit_rate={hit_rate:.2f}", f"preempt={qos.preemptions}",
                 f"fanouts={len(qos.cluster)}",
                 f"makespan={qos.makespan_s * 1e3:.3f}"])
    return render_table(
        ["class", "granted", "ticket hits", "preemptions",
         "p50 grant ms", "service ms"], rows)


def steal_table(stats) -> str:
    """Per-shard markdown table of work-stealing decisions — steals landed,
    batches moved, admission declines and re-steals, attributed by each
    event's ``server_id`` (events recorded before the field existed fall
    back to their thief). Accepts a ``repro.qos.QosStats`` (aggregates its
    per-request clusters) or a single ``repro.cluster.ClusterStats``.
    Duck-typed like its siblings so this module stays dependency-free."""
    clusters = getattr(stats, "cluster", None)
    if clusters is None:
        clusters = [stats]
    agg: dict = {}
    for c in clusters:   # ClusterStats owns the per-event attribution rule
        for sid, per in c.steal_attribution().items():
            row = agg.setdefault(sid, {})
            for key, count in per.items():
                row[key] = row.get(key, 0) + count
    keys = ("steal", "batches", "decline", "re_steal")
    rows = [[sid] + [agg[sid].get(k, 0) for k in keys]
            for sid in sorted(agg)]
    rows.append(["*total*"] + [sum(r.get(k, 0) for r in agg.values())
                               for k in keys])
    return render_table(
        ["shard", "steals in", "batches in", "declines", "re-steals in"],
        rows)


def admission_table(stats) -> str:
    """Per-shard markdown table for a ``repro.qos.DistributedStats`` —
    grant/denial/borrow/reconcile counters plus the token-bucket traffic —
    with the cluster-wide aggregate in a footer row. Also accepts a plain
    ``AdmissionStats`` (centralized controller): one ``*global*`` row.
    Duck-typed like its siblings so this module stays dependency-free."""
    columns = ["shard", "grants", "denials (quota/total/mem)", "borrows",
               "lends", "reconciles", "tokens in/out", "throttle ms",
               "peak"]

    def denials(s) -> str:
        return (f"{s.stream_denials}/{s.total_denials}/{s.memory_denials}")

    shards = getattr(stats, "shards", None)
    if not shards:
        return render_table(columns, [
            ["*global*", stats.stream_grants, denials(stats), "—", "—",
             "—", "—", f"{stats.throttle_wait_s * 1e3:.3f}",
             stats.peak_active]])
    rows = []
    for sid in sorted(shards):
        s = shards[sid]
        rows.append([sid, s.stream_grants, denials(s), s.borrows, s.lends,
                     s.reconciles, f"{s.tokens_in:.1f}/{s.tokens_out:.1f}",
                     f"{s.throttle_wait_s * 1e3:.3f}", s.peak_active])
    rows.append(["*cluster*", stats.stream_grants, denials(stats),
                 stats.borrows, stats.lends, stats.reconciles,
                 f"moved={stats.tokens_rebalanced:.1f}",
                 f"{stats.throttle_wait_s * 1e3:.3f}", stats.peak_total])
    return render_table(columns, rows)


def trace_table(tracer) -> str:
    """Per-(category, name) span aggregates for an ``obs.Tracer`` — count,
    total and max modeled duration — the textual companion to the Chrome
    trace export. Duck-typed on ``tracer.summary()`` so this module stays
    dependency-free."""
    rows = []
    for (cat, name), agg in sorted(tracer.summary().items()):
        rows.append([cat, name, agg["count"],
                     f"{agg['total_s'] * 1e6:.1f}",
                     f"{agg['max_s'] * 1e6:.1f}"])
    return render_table(["cat", "span", "count", "total us", "max us"],
                        rows)


def health_table(monitor) -> str:
    """Per-server health verdicts for an ``obs.HealthMonitor`` — state,
    when it last changed, the signals behind it — plus the cluster-wide
    pool-pressure/heartbeat footer. Duck-typed on ``monitor.snapshot()``
    so this module stays dependency-free."""
    snap = monitor.snapshot()
    rows = []
    for sid, h in snap.get("servers", {}).items():
        rate = h.get("rate_us_per_batch")
        rows.append([
            sid, h.get("state", "?"),
            f"{h.get('since_s', 0.0) * 1e3:.3f}",
            "-" if rate is None else f"{rate:.1f}",
            h.get("flaps", 0), h.get("faults", 0), h.get("denials", 0),
            h.get("declines", 0), h.get("transitions", 0),
            h.get("reason", ""),
        ])
    table = render_table(
        ["server", "state", "since ms", "rate us/b", "flaps", "faults",
         "denials", "declines", "trans", "reason"], rows)
    footer = (f"heartbeats={snap.get('heartbeats', 0)} "
              f"pool_pressure={snap.get('pool_pressure', 0.0):.2f}")
    return f"{table}\n{footer}"


def workload_table(driver) -> str:
    """Per-population markdown table for an ``obs.StressDriver`` — grants,
    causally attributed deadline sheds vs admission declines, grant-latency
    p50/p99 and window throughput — with the cross-population fairness
    verdict (Jain's index, latency inflation) in the footer. Duck-typed on
    the driver's ``populations``/``gateway.stats``/``fairness()`` surface
    so this module stays dependency-free."""
    fair = driver.fairness()
    window_s = driver.window_s
    rows = []
    for pop in driver.populations:
        c = driver.gateway.stats.classes.get(pop.name)
        if c is None or c.submitted == 0:
            rows.append([pop.name, "0/0", 0, 0, "-", "-", "-"])
            continue
        rows.append([
            pop.name, f"{c.granted}/{c.submitted}",
            driver.sheds.get(pop.name, 0),
            driver.declines.get(pop.name, 0),
            f"{c.p50_grant_latency_s * 1e6:.1f}",
            f"{c.p99_grant_latency_s * 1e6:.1f}",
            f"{c.throughput_over(window_s) / 1e6:.1f}",
        ])
    table = render_table(
        ["population", "granted", "shed", "declined", "p50 grant us",
         "p99 grant us", "throughput MB/s"], rows)
    footer = (f"jain={fair['jain']:.3f} "
              f"latency_inflation={fair['latency_inflation']:.2f} "
              f"beats={driver.beats} window_us={window_s * 1e6:.1f}")
    return f"{table}\n{footer}"


def export_trace(tracer, path: str) -> str:
    """Write an ``obs.Tracer``'s collected scans as Chrome ``trace_event``
    JSON (load in ``chrome://tracing`` or https://ui.perfetto.dev).
    Returns ``path``. Duck-typed on ``tracer.to_chrome()``."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(tracer.to_chrome(), f)
    return path


def summary_stats(arts: list[dict]) -> dict:
    ok = sum(1 for a in arts if a["status"] == "ok")
    skip = sum(1 for a in arts if a["status"] == "skipped")
    err = sum(1 for a in arts if a["status"] == "error")
    return {"ok": ok, "skipped": skip, "errors": err, "total": len(arts)}
