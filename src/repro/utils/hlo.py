"""Post-SPMD HLO analysis: collective inventory and byte accounting.

``compiled.as_text()`` is the partitioned per-device program; every
collective appears as ``%name = TYPE[SHAPE]{layout} op-name(...),
replica_groups=...``. We parse result shapes + replica-group sizes and
convert to *per-device wire bytes* with ring-algorithm formulas:

  all-gather         (g-1)/g × result_bytes
  reduce-scatter     (g-1)   × result_bytes          (operand = g × result)
  all-reduce         2(g-1)/g × result_bytes
  all-to-all         (g-1)/g × result_bytes
  collective-permute 1 × result_bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[2,4096,128]{2,1,0} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*"                        # possibly tuple-shaped
    r"((?:\w+\[[\d,]*\]\S*\s*,?\s*)+)"       # one or more typed shapes
    r"\)?\s*"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def shape_bytes(dtype: str, dims_csv: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims_csv.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    result_bytes: dict[str, int]       # summed result sizes per op kind
    wire_bytes: dict[str, float]       # ring-model per-device bytes
    total_wire_bytes: float

    def summary(self) -> str:
        parts = [f"{k}×{self.counts[k]} ({self.wire_bytes[k]/1e6:.1f} MB)"
                 for k in sorted(self.counts)]
        return ", ".join(parts) if parts else "none"


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_stats(hlo_text: str, num_devices: int) -> CollectiveStats:
    counts: dict[str, int] = defaultdict(int)
    result_bytes: dict[str, int] = defaultdict(int)
    wire: dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes_blob, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = sum(shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(shapes_blob))
        g = _group_size(line, num_devices)
        if g <= 1:
            continue
        counts[op] += 1
        result_bytes[op] += nbytes
        if op == "all-gather":
            wire[op] += (g - 1) / g * nbytes
        elif op == "reduce-scatter":
            wire[op] += (g - 1) * nbytes
        elif op == "all-reduce":
            wire[op] += 2 * (g - 1) / g * nbytes
        elif op == "all-to-all":
            wire[op] += (g - 1) / g * nbytes
        else:  # collective-permute
            wire[op] += nbytes
    return CollectiveStats(dict(counts), dict(result_bytes), dict(wire),
                           float(sum(wire.values())))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
