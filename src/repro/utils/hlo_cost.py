"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — a
scan-over-layers model therefore under-reports FLOPs/bytes/collectives by a
factor of ``num_layers``. This analyzer parses the post-SPMD HLO text,
resolves instruction result shapes, and walks computations recursively,
multiplying every ``while`` body/cond by its trip count (XLA annotates
``backend_config={"known_trip_count":{"n":...}}``; fallback: the s32
constant in the condition computation).

Costs per device:
  flops        — 2·numel(result)·K for dot (K = lhs contracting extent);
                 numel for elementwise arithmetic; fusions recursed.
  bytes        — operands + results of *top-level* ops (fusion = its
                 boundary, matching XLA "bytes accessed" semantics).
  collectives  — ring-model wire bytes (same formulas as utils.hlo), trip-
                 count multiplied.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:condition|body|calls|to_apply)=%?([\w.\-]+)")
_BRANCH_ATTR_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "compare", "select", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign", "remainder", "atan2",
}
_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "logistic",
                   "sine", "cosine", "erf", "exponential-minus-one",
                   "log-plus-one", "cbrt"}
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _shapes_in(text: str) -> list[tuple[str, tuple[int, ...]]]:
    return [(d, tuple(int(x) for x in dims.split(",") if x))
            for d, dims in _SHAPE_RE.findall(text)]


def _nbytes(shapes) -> int:
    total = 0
    for d, dims in shapes:
        n = 1
        for x in dims:
            n *= x
        total += n * _DTYPE_BYTES.get(d, 4)
    return total


def _numel(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for x in dims:
            n *= x
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_shapes: list
    operands: list[str]
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    collective_bytes_by_op: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes += other.bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult
        for k, v in other.collective_bytes_by_op.items():
            self.collective_bytes_by_op[k] = (
                self.collective_bytes_by_op.get(k, 0) + v * mult)


_FRAME_FN_RE = re.compile(r"^(\d+)\s+\{file_location_id=(\d+)")
_FLOC_RE = re.compile(r"^(\d+)\s+\{file_name_id=\d+ function_name_id=(\d+)")
_FNAME_RE = re.compile(r'^(\d+)\s+"(.*)"$')
_STACK_ID_RE = re.compile(r"stack_frame_id=(\d+)")


def parse_stack_tables(hlo_text: str):
    """FunctionNames / FileLocations / StackFrames header tables →
    frame_id -> tuple of function names up the call chain."""
    section = None
    fn_names: dict[int, str] = {}
    floc_fn: dict[int, int] = {}
    frames: dict[int, tuple[int, int]] = {}   # frame -> (floc, parent)
    for line in hlo_text.splitlines():
        s = line.strip()
        if s in ("FunctionNames", "FileLocations", "StackFrames", "FileNames"):
            section = s
            continue
        if not s or s.startswith(("HloModule", "%", "ENTRY")):
            if s.startswith(("%", "ENTRY")):
                break
            continue
        if section == "FunctionNames":
            m = _FNAME_RE.match(s)
            if m:
                fn_names[int(m.group(1))] = m.group(2)
        elif section == "FileLocations":
            m = _FLOC_RE.match(s)
            if m:
                floc_fn[int(m.group(1))] = int(m.group(2))
        elif section == "StackFrames":
            m = re.match(r"^(\d+)\s+\{file_location_id=(\d+)"
                         r"(?:\s+parent_frame_id=(\d+))?", s)
            if m:
                frames[int(m.group(1))] = (int(m.group(2)),
                                           int(m.group(3) or 0))
    chains: dict[int, tuple[str, ...]] = {}

    def chain(fid: int, depth: int = 0) -> tuple[str, ...]:
        if fid in chains:
            return chains[fid]
        if fid not in frames or depth > 64:
            return ()
        floc, parent = frames[fid]
        name = fn_names.get(floc_fn.get(floc, -1), "")
        out = ((name,) if name else ())
        if parent and parent != fid:
            out = chain(parent, depth + 1) + out
        chains[fid] = out
        return out

    return {fid: chain(fid) for fid in frames}


class HloCostModel:
    def __init__(self, hlo_text: str, num_devices: int,
                 fused_functions: tuple[str, ...] = ()):
        """``fused_functions``: python function names whose HLO (resolved
        via stack-frame metadata) is treated as a fused kernel for BYTE
        accounting — interior tensors are VMEM-resident (e.g. a Pallas
        flash-attention kernel keeps scores on chip), so only the region's
        external inputs are charged HBM traffic. FLOPs are unaffected."""
        self.num_devices = num_devices
        self.computations: dict[str, list[Instr]] = {}
        self.instr_shape: dict[tuple[str, str], list] = {}
        self.fused_functions = fused_functions
        self._frame_chains = (parse_stack_tables(hlo_text)
                              if fused_functions else {})
        self.instr_by: dict[tuple[str, str], Instr] = {}
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}
        self._fused_mark: set[tuple[str, str]] = set()
        if fused_functions:
            self._compute_fused_marks()

    def _compute_fused_marks(self) -> None:
        """Direct marks from metadata + closure: an op that LOST its
        metadata (XLA rewrites strip it from some dots/copies) is interior
        when every consumer inside its computation is interior."""
        consumers: dict[tuple[str, str], list[Instr]] = {}
        for comp, instrs in self.computations.items():
            for i in instrs:
                if self._is_fused_direct(i):
                    self._fused_mark.add((comp, i.name))
                for o in i.operands:
                    consumers.setdefault((comp, o), []).append(i)
        for _ in range(3):   # closure to fixpoint (shallow chains)
            changed = False
            for comp, instrs in self.computations.items():
                for i in instrs:
                    key = (comp, i.name)
                    if key in self._fused_mark or "metadata=" in i.line:
                        continue
                    cons = consumers.get(key, [])
                    if cons and all((comp, c.name) in self._fused_mark
                                    for c in cons):
                        self._fused_mark.add(key)
                        changed = True
            if not changed:
                break

    def _is_fused_direct(self, instr: Instr) -> bool:
        for f in self.fused_functions:
            if f in instr.line:
                return True
        m = _STACK_ID_RE.search(instr.line)
        if not m:
            return False
        chain = self._frame_chains.get(int(m.group(1)), ())
        return any(any(f in name for f in self.fused_functions)
                   for name in chain)

    def _is_fused_interior(self, instr: Instr, comp: str | None = None) -> bool:
        """An instruction belongs to a VMEM-fused region when its op_name
        metadata path contains a fused-region named_scope (named scopes
        survive jvp/transpose, unlike stack-frame chains), via the
        stack-frame fallback, or via consumer closure (metadata-stripped
        dots feeding only interior ops)."""
        if not self.fused_functions:
            return False
        if comp is not None and (comp, instr.name) in self._fused_mark:
            return True
        return self._is_fused_direct(instr)

    # -- parsing ----------------------------------------------------------
    def _parse(self, text: str) -> None:
        current: str | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if line.endswith("{") and ("(" in line or line.startswith("ENTRY")):
                m = _COMP_HDR_RE.match(line.strip())
                if m and ("->" in line or line.strip().startswith(("ENTRY", "%"))):
                    current = m.group(1)
                    self.computations[current] = []
                    continue
            if line.strip() == "}":
                # keep current until next header; nested braces don't occur
                current = None
                continue
            if current is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, result_blob, op, rest = m.groups()
            shapes = _shapes_in(result_blob)
            # operands: up to the closing paren of the op call
            depth, end = 1, 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_blob = rest[:end]
            operands = _OPERAND_RE.findall(operand_blob)
            instr = Instr(name, op, shapes, operands, line)
            self.computations[current].append(instr)
            self.instr_shape[(current, name)] = shapes
            self.instr_by[(current, name)] = instr

    # -- helpers ----------------------------------------------------------
    def _operand_shapes(self, comp: str, operand: str):
        return self.instr_shape.get((comp, operand), [])

    def _group_size(self, line: str) -> int:
        m = _GROUPS_ITOTA_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(line)
        if m:
            return len(m.group(1).split(","))
        return self.num_devices

    def _trip_count(self, instr: Instr) -> int:
        m = _TRIP_RE.search(instr.line)
        if m:
            return int(m.group(1))
        # fallback: max s32 constant in the condition computation
        m2 = re.search(r"condition=%?([\w.\-]+)", instr.line)
        if m2 and m2.group(1) in self.computations:
            consts = []
            for i in self.computations[m2.group(1)]:
                c = re.search(r"constant\((\d+)\)", i.line)
                if c:
                    consts.append(int(c.group(1)))
            if consts:
                return max(consts)
        return 1

    def _called(self, instr: Instr) -> list[str]:
        out = []
        for m in _CALL_ATTR_RE.finditer(instr.line):
            name = m.group(1)
            if name in self.computations:
                out.append(name)
        for m in _BRANCH_ATTR_RE.finditer(instr.line):
            for name in m.group(1).split(","):
                name = name.strip().lstrip("%")
                if name in self.computations:
                    out.append(name)
        return out

    # -- cost -------------------------------------------------------------
    def computation_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # guard cycles
        for instr in self.computations.get(comp, []):
            total.add(self._instr_cost(comp, instr))
        return total

    def _instr_cost(self, comp: str, instr: Instr) -> Cost:
        c = Cost()
        op = instr.op
        if op in _FREE:
            return c
        if op == "while":
            trip = self._trip_count(instr)
            for sub in self._called(instr):
                c.add(self.computation_cost(sub), mult=trip)
            return c
        if op in ("conditional",):
            subs = self._called(instr)
            if subs:  # charge the max branch
                costs = [self.computation_cost(s) for s in subs]
                c.add(max(costs, key=lambda x: x.flops + x.bytes))
            return c
        if op in ("call", "fusion", "async-start", "custom-call"):
            for sub in self._called(instr):
                body = self.computation_cost(sub)
                # flops/transcendentals/collectives flow up; bytes stay at
                # the fusion boundary (operands+result below), matching XLA
                # "bytes accessed" semantics for fused computations.
                c.flops += body.flops
                c.transcendentals += body.transcendentals
                c.collective_wire_bytes += body.collective_wire_bytes
                for k, v in body.collective_counts.items():
                    c.collective_counts[k] = c.collective_counts.get(k, 0) + v
                for k, v in body.collective_bytes_by_op.items():
                    c.collective_bytes_by_op[k] = (
                        c.collective_bytes_by_op.get(k, 0) + v)
        elif op in ("reduce", "reduce-window", "map", "scatter", "select-and-scatter"):
            # body applied ~once per input element
            subs = self._called(instr)
            if subs and instr.operands:
                body = self.computation_cost(subs[0])
                in_numel = _numel(self._operand_shapes(comp, instr.operands[0]))
                c.flops += body.flops * max(in_numel, 1)
        if op in _COLLECTIVES:
            base = op.replace("-start", "")
            nbytes = _nbytes(instr.result_shapes)
            g = self._group_size(instr.line)
            if g > 1:
                wire = {"all-gather": (g - 1) / g * nbytes,
                        "reduce-scatter": (g - 1) * nbytes,
                        "all-reduce": 2 * (g - 1) / g * nbytes,
                        "all-to-all": (g - 1) / g * nbytes,
                        "collective-permute": float(nbytes)}[base]
                c.collective_wire_bytes += wire
                c.collective_counts[base] = c.collective_counts.get(base, 0) + 1
                c.collective_bytes_by_op[base] = (
                    c.collective_bytes_by_op.get(base, 0) + wire)
        if op == "dot":
            m = _CONTRACT_RE.search(instr.line)
            k = 1
            if m and instr.operands:
                lhs = self._operand_shapes(comp, instr.operands[0])
                if lhs:
                    dims = lhs[0][1]
                    for d in (int(x) for x in m.group(1).split(",") if x):
                        if d < len(dims):
                            k *= dims[d]
            c.flops += 2.0 * _numel(instr.result_shapes) * k
        elif op == "convolution":
            # approx: 2 * out_numel * (in_features * kernel_spatial)
            c.flops += 2.0 * _numel(instr.result_shapes)
        elif op in _ELEMENTWISE:
            c.flops += _numel(instr.result_shapes)
        elif op in _TRANSCENDENTAL:
            c.transcendentals += _numel(instr.result_shapes)

        # fused-region interior (e.g. flash-attention modeled as a Pallas
        # kernel): only reads of EXTERNAL tensors hit HBM; interior tensors
        # are VMEM-resident. Outputs are charged at their external consumer.
        if self._is_fused_interior(instr, comp):
            for o in instr.operands:
                prod = self.instr_by.get((comp, o))
                if prod is None or not self._is_fused_interior(prod, comp):
                    c.bytes += _nbytes(self._operand_shapes(comp, o))
            return c

        # bytes: actual traffic, slice-aware. dynamic-slice reads only the
        # slice (not the whole stacked operand — critical for scan-over-
        # layers weight indexing); DUS/scatter write only the update region.
        res = _nbytes(instr.result_shapes)
        if op == "fusion":
            c.bytes += self._fusion_bytes(comp, instr)
        elif op in ("dynamic-slice", "slice"):
            c.bytes += 2 * res
        elif op == "dynamic-update-slice":
            upd = (_nbytes(self._operand_shapes(comp, instr.operands[1]))
                   if len(instr.operands) > 1 else res)
            c.bytes += 2 * upd
        elif op == "gather":
            idx = (_nbytes(self._operand_shapes(comp, instr.operands[1]))
                   if len(instr.operands) > 1 else 0)
            c.bytes += 2 * res + idx
        elif op == "scatter":
            upd = (_nbytes(self._operand_shapes(comp, instr.operands[2]))
                   if len(instr.operands) > 2 else res)
            c.bytes += 2 * upd + res
        elif op == "broadcast":
            c.bytes += res + sum(_nbytes(self._operand_shapes(comp, o))
                                 for o in instr.operands)
        else:
            in_bytes = sum(_nbytes(self._operand_shapes(comp, o))
                           for o in instr.operands)
            c.bytes += in_bytes + res
        return c

    def _fusion_bytes(self, comp: str, instr: Instr) -> float:
        """Traffic of a fused computation: root output + per-parameter reads,
        where a parameter consumed ONLY via dynamic-slice/gather counts the
        sliced bytes, not the full (possibly layer-stacked) array. A fusion
        whose ROOT is dynamic-update-slice writes only the update region
        (in-place aliasing), so the full-buffer result is not charged."""
        total = float(_nbytes(instr.result_shapes))
        for sub in self._called(instr):
            instrs = self.computations.get(sub, [])
            root = next((i for i in instrs if "ROOT" in i.line), None)
            if root is not None and root.op == "dynamic-update-slice":
                total -= float(_nbytes(instr.result_shapes))
            params = {}
            by_name = {}
            for i in instrs:
                by_name[i.name] = i
                if i.op == "parameter":
                    params[i.name] = []
            for i in instrs:
                for o in i.operands:
                    if o in params:
                        params[o].append(i)
            # map fusion operands (outer) to parameters (inner, positional)
            outer = instr.operands
            inner = [i for i in instrs if i.op == "parameter"]
            inner.sort(key=lambda i: int(
                re.search(r"parameter\((\d+)\)", i.line).group(1)))
            for pos, p in enumerate(inner):
                uses = params.get(p.name, [])
                if uses and all(u.op in ("dynamic-slice", "gather", "slice")
                                for u in uses):
                    total += sum(_nbytes(u.result_shapes) for u in uses)
                elif pos < len(outer):
                    total += _nbytes(self._operand_shapes(comp, outer[pos]))
                else:
                    total += _nbytes(p.result_shapes)
            # interior dynamic-update-slice: count update-sized write
            for i in instrs:
                if i.op == "dynamic-update-slice" and len(i.operands) > 1:
                    upd = by_name.get(i.operands[1])
                    if upd is not None:
                        total += _nbytes(upd.result_shapes)
        return total

    def entry_cost(self) -> Cost:
        entry = None
        for name in self.computations:
            if "main" in name or name.startswith("main"):
                entry = name
        if entry is None:  # last computation is ENTRY by convention
            entry = list(self.computations)[-1]
        return self.computation_cost(entry)


def analyze(hlo_text: str, num_devices: int,
            fused_functions: tuple[str, ...] = ()) -> Cost:
    return HloCostModel(hlo_text, num_devices, fused_functions).entry_cost()


# regions implemented as Pallas kernels on real TPU (kernels/attention) —
# their interior tensors are VMEM-resident, see HloCostModel docstring.
# "vmem_fused_attention" is the jax.named_scope marker set in models/layers
# and models/mamba2.
FUSED_ATTENTION_FNS = ("vmem_fused_attention",)
