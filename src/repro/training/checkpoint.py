"""Columnar checkpointing — the paper's transport applied to train state.

A checkpoint IS a record batch: one row per pytree leaf with columns
(path utf8, dtype utf8, shape utf8-json, data binary). Saving uses the
Thallus convention — buffers are exposed in place and written segment-wise
(no staging concat of the whole checkpoint); restoring is zero-copy view
assembly, then ``device_put`` against whatever mesh the *restoring* job has
(elastic: mesh shape at save time is irrelevant).

Fault-tolerance posture:
* atomic writes (tmp file + rename), manifest with step/config hash,
* ``keep_last`` GC, ``latest`` discovery for restarts,
* data-pipeline cursor positions ride in the manifest so a restarted job
  resumes its scan leases (protocol.init_scan(start_batch=...)).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import jax
import numpy as np

from ..core.recordbatch import RecordBatch, batch_from_pydict
from ..core.schema import Schema, schema as make_schema
from ..core import serialize

Pytree = Any

_SCHEMA = make_schema(("path", "utf8"), ("dtype", "utf8"),
                      ("shape", "utf8"), ("data", "binary"))


def _flatten_with_paths(tree: Pytree) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                        for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def state_to_batch(tree: Pytree) -> RecordBatch:
    rows = _flatten_with_paths(tree)
    data = {
        "path": [r[0] for r in rows],
        "dtype": [str(r[1].dtype) for r in rows],
        "shape": [json.dumps(list(r[1].shape)) for r in rows],
        "data": [r[1].tobytes() for r in rows],
    }
    return batch_from_pydict(_SCHEMA, data)


def batch_to_state(batch: RecordBatch, like: Pytree | None = None,
                   mesh=None, specs: Pytree | None = None) -> Pytree:
    """Rebuild the pytree. With (mesh, specs): device_put each leaf with its
    NamedSharding — this is the elastic-resharding path."""
    from jax.sharding import NamedSharding

    rows = {}
    d = batch.to_pydict()
    for p, dt, sh, raw in zip(d["path"], d["dtype"], d["shape"], d["data"]):
        arr = np.frombuffer(raw, dtype=np.dtype(dt)).reshape(json.loads(sh))
        rows[p] = arr
    if like is None:
        return rows

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    spec_leaves = (jax.tree.leaves(specs) if specs is not None
                   else [None] * len(flat_like[0]))
    for (path, leaf), spec in zip(flat_like[0], spec_leaves):
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                        for p in path)
        if name not in rows:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = rows[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        if mesh is not None and spec is not None:
            leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree.unflatten(flat_like[1], leaves)


@dataclasses.dataclass
class Manifest:
    step: int
    file: str
    wall_time: float
    cursors: dict[str, int] = dataclasses.field(default_factory=dict)
    extra: dict = dataclasses.field(default_factory=dict)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def _paths(self, step: int) -> tuple[str, str]:
        return (os.path.join(self.dir, f"ckpt_{step:08d}.thallus"),
                os.path.join(self.dir, f"ckpt_{step:08d}.json"))

    def save(self, step: int, state: Pytree,
             cursors: dict[str, int] | None = None,
             extra: dict | None = None) -> str:
        data_path, man_path = self._paths(step)
        batch = state_to_batch(state)
        wire = serialize.pack(batch)          # columnar wire image
        tmp = data_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(wire.tobytes())
        os.replace(tmp, data_path)            # atomic
        man = Manifest(step=step, file=os.path.basename(data_path),
                       wall_time=time.time(), cursors=cursors or {},
                       extra=extra or {})
        tmp = man_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dataclasses.asdict(man), f)
        os.replace(tmp, man_path)
        self._gc()
        return data_path

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            for p in self._paths(s):
                try:
                    os.remove(p)
                except FileNotFoundError:
                    pass

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".json"):
                out.append(int(f[5:13]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_manifest(self, step: int) -> Manifest:
        with open(self._paths(step)[1]) as f:
            return Manifest(**json.load(f))

    def restore(self, step: int, like: Pytree | None = None, mesh=None,
                specs: Pytree | None = None) -> tuple[Pytree, Manifest]:
        data_path, _ = self._paths(step)
        wire = np.fromfile(data_path, dtype=np.uint8)
        batch = serialize.unpack(wire, zero_copy=True)   # views, no copies
        state = batch_to_state(batch, like=like, mesh=mesh, specs=specs)
        return state, self.load_manifest(step)

    def restore_latest(self, **kw) -> tuple[Pytree, Manifest] | None:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, **kw)
