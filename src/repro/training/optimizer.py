"""Hand-rolled AdamW (+ schedule + global-norm clip), optax-free.

Optimizer state leaves inherit the parameter sharding (ZeRO-1 for free under
pjit: m/v/master live fully sharded next to their param shards). Mixed
precision: params may live in bf16 while ``master`` keeps an fp32 copy used
for the update.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master_fp32: bool = True        # keep fp32 master when params are low-prec


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def init_opt_state(cfg: OptimizerConfig, params: Pytree) -> Pytree:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(cfg: OptimizerConfig, grads: Pytree, opt_state: Pytree,
                 params: Pytree, step: jax.Array) -> tuple[Pytree, Pytree, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t

    masters = opt_state.get("master", params)

    def upd(g, m, v, master):
        g32 = g.astype(jnp.float32) * clip
        m = cfg.beta1 * m + (1 - cfg.beta1) * g32
        v = cfg.beta2 * v + (1 - cfg.beta2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        master32 = master.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master32
        return m, v, master32 - lr * delta

    flat = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], masters)
    new_m = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree.map(lambda x: x[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v}
    if "master" in opt_state:
        new_state["master"] = new_master
        new_params = jax.tree.map(lambda p, w: w.astype(p.dtype), params,
                                  new_master)
    else:
        new_params = jax.tree.map(lambda p, w: w.astype(p.dtype), params,
                                  new_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
