"""Train step factory: loss → grads (accumulated) → AdamW, pjit-ready.

TrainState pytree: {"params", "opt": {m, v, master?}, "ef"?, "step"}.
Sharding: params/opt/ef follow :func:`repro.models.param_specs`; step is
replicated. Gradient accumulation scans over microbatches so peak activation
memory is one microbatch. Optional int8+error-feedback compression applies to
the cross-pod gradient reduce (see :mod:`repro.training.compression`).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import loss_fn
from .compression import init_error_feedback
from .optimizer import OptimizerConfig, adamw_update, init_opt_state

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    remat: str = "full"                 # none | dots | full
    microbatches: int = 1               # gradient accumulation
    compress_dp_grads: bool = False     # int8 EF compression across pods
    param_dtype: str = "float32"        # float32 (smoke) / bfloat16 (scale)


def init_train_state(cfg: ArchConfig, tcfg: TrainConfig, key: jax.Array) -> Pytree:
    from ..models import init_params

    dtype = jnp.dtype(tcfg.param_dtype)
    params = init_params(cfg, key, dtype)
    state = {
        "params": params,
        "opt": init_opt_state(tcfg.optimizer, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.compress_dp_grads:
        state["ef"] = init_error_feedback(params)
    return state


def train_state_shapes(cfg: ArchConfig, tcfg: TrainConfig) -> Pytree:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_train_state(cfg, tcfg, k), key)


def _split_microbatches(batch: dict, k: int) -> dict:
    def split(x):
        b = x.shape[0]
        if b % k:
            raise ValueError(f"batch {b} not divisible by microbatches {k}")
        return x.reshape((k, b // k) + x.shape[1:])
    return {key: split(v) for key, v in batch.items()}


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def compute_grads(params, batch):
        def loss_of(p):
            return loss_fn(cfg, p, batch, remat=tcfg.remat)
        return jax.value_and_grad(loss_of)(params)

    def train_step(state: Pytree, batch: dict) -> tuple[Pytree, dict]:
        params = state["params"]
        if tcfg.microbatches > 1:
            micro = _split_microbatches(batch, tcfg.microbatches)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = compute_grads(params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(accum, (0.0, zero), micro)
            loss = loss_sum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        else:
            loss, grads = compute_grads(params, batch)

        new_state = dict(state)
        if tcfg.compress_dp_grads and "ef" in state:
            # NOTE: under plain pjit the DP reduce is implicit; the explicit
            # compressed cross-pod reduce is applied by the shard_map wrapper
            # in launch/train.py. Here we apply the *local* quantize/EF pass
            # so the numerics (and the HLO bytes) are in the lowered graph.
            from .compression import compress_decompress
            pairs = jax.tree.map(compress_decompress, grads, state["ef"])
            grads = jax.tree.map(lambda t: t[0], pairs,
                                 is_leaf=lambda t: isinstance(t, tuple))
            new_state["ef"] = jax.tree.map(lambda t: t[1], pairs,
                                           is_leaf=lambda t: isinstance(t, tuple))

        new_params, new_opt, opt_metrics = adamw_update(
            tcfg.optimizer, grads, state["opt"], params, state["step"])
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        metrics = {"loss": loss, **opt_metrics,
                   "tokens": jnp.asarray(batch["tokens"].size, jnp.float32)}
        return new_state, metrics

    return train_step
