from .optimizer import OptimizerConfig, adamw_update, global_norm, init_opt_state, lr_at  # noqa: F401
from .train_step import TrainConfig, init_train_state, make_train_step, train_state_shapes  # noqa: F401
from .checkpoint import CheckpointManager, batch_to_state, state_to_batch  # noqa: F401
from .compression import (compress_decompress, compressed_psum_pod,  # noqa: F401
                          compression_wire_bytes, dequantize_int8,
                          init_error_feedback, quantize_int8)
