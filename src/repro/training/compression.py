"""int8 gradient compression with error feedback for the cross-pod reduce.

At 1000+ node scale the DP all-reduce that crosses the pod boundary rides
DCN, not ICI — 4-16× less bandwidth. The classic fix: quantize the cross-pod
contribution to int8 with a per-tensor scale and keep the quantization
residual in an *error-feedback* buffer added back before the next step
(Seide et al.; 1-bit Adam lineage). Intra-pod reductions stay full precision.

Implemented as explicit collectives inside ``shard_map`` over the ``pod``
axis (`compressed_psum_pod`): quantize → psum(int32 accumulate) → dequant.
The error-feedback state lives in the train state, sharded like the grads.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jax.Array, ef: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize (x + error_feedback); return (dequantized, new_ef)."""
    target = x.astype(jnp.float32) + ef
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    return deq, target - deq


def compressed_psum_pod(grads: Pytree, ef: Pytree,
                        axis_name: str = "pod") -> tuple[Pytree, Pytree]:
    """Inside shard_map over the pod axis: int8-compress the local
    contribution (with error feedback), all-reduce the int8 payload as int32
    (wire bytes = 1/4 of fp32), share scales via a tiny fp32 psum, dequant.

    Returns (pod-mean gradients fp32, new error-feedback state).
    """
    npods = jax.lax.axis_size(axis_name)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq_local = dequantize_int8(q, scale)
        new_e = target - deq_local
        # wire: int8 payload (accumulated in i32) + per-tensor scale
        acc = jax.lax.psum(q.astype(jnp.int32) * 0 + q.astype(jnp.int32),
                           axis_name)
        scales = jax.lax.all_gather(scale, axis_name)      # (npods,)
        # scales differ per pod: reconstruct as sum of per-pod dequants.
        # acc alone is only exact when scales match; correct by the gathered
        # per-pod scale spread: psum(q_i * s_i) = sum_i q_i * s_i. We send
        # q_i * s_mean over the wire and fold the ratio into error feedback.
        s_mean = jnp.mean(scales)
        mean_g = acc.astype(jnp.float32) * s_mean / npods
        return mean_g, new_e + (deq_local - q.astype(jnp.float32) * s_mean)

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tree, [o[1] for o in outs])
    return new_g, new_e


def init_error_feedback(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_wire_bytes(params: Pytree) -> tuple[int, int]:
    """(fp32 bytes, int8 bytes) the cross-pod reduce would move per step."""
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    return 4 * n, n + 4 * len(jax.tree.leaves(params))
