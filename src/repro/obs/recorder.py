"""Flight recorder: a bounded ring of structured cross-layer events.

Every layer of the stack already *decides* things in modeled time — the
gateway sheds a deadline-missed scan, the stealing puller declines a thief
shard at quota, a stream faults and resumes, a shard borrows a slot from a
peer, the pool evicts a cold slab. Those decisions are exactly what a
postmortem needs, and exactly what a cumulative ``*Stats`` counter erases:
the counter says *how many*, the recorder says *which, when, and in what
order*.

``FlightRecorder`` is deliberately dumb: a ``deque(maxlen=...)`` of frozen
:class:`FlightEvent` records. Producers call :meth:`FlightRecorder.record`
(usually via ``ClusterCoordinator.notify`` — see ``cluster/coordinator.py``
— so plain deployments pay a single attribute check). When an SLO alert
fires (``obs/slo.py``), :meth:`FlightRecorder.postmortem` assembles the
bundle: the last-N causal events, the full metrics-registry snapshot, the
per-server health states, and the Chrome trace export — everything needed
to answer "why was this scan slow" without re-running anything.

Like the rest of ``repro.obs`` this module imports nothing from the layers
it observes; ``registry``/``health``/``tracer`` arguments are duck-typed.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class FlightEvent:
    """One structured decision, in modeled time.

    ``kind`` is a dotted verb (``steal.decline``, ``qos.shed``,
    ``stream.fault``, ``admission.borrow``, ``pool.eviction``, ...);
    ``server_id`` is the server the decision is *about* (empty when the
    event is cluster-wide); ``attrs`` carries the kind-specific detail
    (victim, batches, nbytes, ...).
    """

    seq: int
    kind: str
    now_s: float
    server_id: str = ""
    attrs: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"seq": self.seq, "kind": self.kind, "now_s": self.now_s,
                "server_id": self.server_id, "attrs": dict(self.attrs)}

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        extra = " ".join(f"{k}={v}" for k, v in sorted(self.attrs.items()))
        sid = f" [{self.server_id}]" if self.server_id else ""
        return f"#{self.seq} {self.now_s * 1e3:9.3f}ms {self.kind}{sid} {extra}"


class FlightRecorder:
    """Bounded ring of :class:`FlightEvent` records.

    The ring holds the most recent ``capacity`` events; older events fall
    off the front (``dropped`` counts them) so a long-lived recorder stays
    O(capacity) no matter how chatty the cluster gets.
    """

    def __init__(self, capacity: int = 512) -> None:
        self.capacity = capacity
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._seq = 0
        self.dropped = 0

    def record(self, kind: str, now_s: float = 0.0, server_id: str = "",
               **attrs) -> FlightEvent:
        """Append one event; returns it (handy in tests)."""
        event = FlightEvent(seq=self._seq, kind=kind, now_s=now_s,
                            server_id=server_id or "", attrs=attrs)
        self._seq += 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(event)
        return event

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def next_seq(self) -> int:
        """The seq the next recorded event will get — a cursor for
        incremental consumers (every event with ``seq < next_seq`` has
        been recorded, even if the ring has since dropped it)."""
        return self._seq

    def events(self, last_n: int | None = None,
               kinds: Iterable[str] | None = None) -> list[FlightEvent]:
        """The recorded events, oldest first; optionally only the last
        ``last_n`` and/or only the listed ``kinds``."""
        out = list(self._ring)
        if kinds is not None:
            wanted = set(kinds)
            out = [e for e in out if e.kind in wanted]
        if last_n is not None:
            out = out[-last_n:]
        return out

    def counts(self) -> dict[str, int]:
        """Events currently in the ring, tallied by kind."""
        tally: dict[str, int] = {}
        for event in self._ring:
            tally[event.kind] = tally.get(event.kind, 0) + 1
        return dict(sorted(tally.items()))

    # -- postmortems ------------------------------------------------------

    def postmortem(self, trigger=None, registry=None, health=None,
                   tracer=None, membership=None, last_n: int = 64) -> dict:
        """Assemble the diagnosis bundle for one alert.

        ``trigger`` is whatever fired (an ``SloAlert``, a ``PerfEvent``, a
        plain dict/string); ``registry``/``health``/``tracer`` are the
        session's ``MetricsRegistry`` / ``HealthMonitor`` / ``Tracer`` if
        present — all duck-typed, all optional, so the recorder stays
        importable anywhere. ``membership`` (a
        ``cluster.MembershipController``) adds the currently-evicted set
        and the evict/re-admit transition log, so a nemesis postmortem
        shows *who was out* when the page fired.
        """
        bundle: dict = {
            "trigger": _as_plain(trigger),
            "events": [e.to_dict() for e in self.events(last_n=last_n)],
            "event_counts": self.counts(),
            "events_dropped": self.dropped,
        }
        if registry is not None and hasattr(registry, "snapshot"):
            bundle["registry"] = registry.snapshot()
        if health is not None:
            if hasattr(health, "snapshot"):
                bundle["health"] = health.snapshot()
            transitions = getattr(health, "transitions", None)
            if transitions is not None:
                bundle["health_transitions"] = [_as_plain(t)
                                               for t in transitions]
        if tracer is not None and hasattr(tracer, "to_chrome"):
            bundle["trace"] = tracer.to_chrome()
        if membership is not None:
            bundle["membership"] = {
                "evicted": list(getattr(membership, "evicted", ()) or ()),
                "events": [_as_plain(e)
                           for e in getattr(membership, "events", [])],
            }
        return bundle

    def dump(self, path: str, trigger=None, registry=None, health=None,
             tracer=None, membership=None, last_n: int = 64) -> str:
        """Write :meth:`postmortem` as JSON; returns the path written."""
        bundle = self.postmortem(trigger=trigger, registry=registry,
                                 health=health, tracer=tracer,
                                 membership=membership, last_n=last_n)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(bundle, fh, indent=2, sort_keys=True, default=str)
        return path


def _as_plain(obj):
    """Best-effort plain-data view of a trigger/transition object."""
    if obj is None or isinstance(obj, (str, int, float, bool)):
        return obj
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    if isinstance(obj, dict):
        return {str(k): _as_plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_as_plain(v) for v in obj]
    return str(obj)
