"""The unified telemetry registry.

A :class:`MetricsRegistry` holds counters, gauges and histograms under a
stable dotted namespace; every ``*Stats`` dataclass in the stack snapshots
into it via the duck-typed ``record_*`` helpers below (this module imports
nothing from the rest of :mod:`repro`, so any layer can import it).

Namespace conventions:

* durations are recorded in **microseconds** under ``.us``-suffixed names
  (``cluster.pull.us``, ``qos.makespan.us``);
* per-event latencies go into histograms, whose snapshot expands to
  ``.count`` / ``.p50`` / ``.p95`` / ``.p99`` / ``.max`` / ``.sum``
  (``qos.grant_latency.p50`` is the p50 of the grant-latency histogram);
* discrete events are counters (``sched.steals.decline``,
  ``pool.evictions``), sizes/levels are gauges.

``registry.snapshot()`` flattens everything to one ``{name: float}`` dict —
the single surface CI, reports and the loader roll-up read from.
"""
from __future__ import annotations

import dataclasses


def _quantile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[idx]


class MetricsRegistry:
    """Counters, gauges and histograms under dotted names."""

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, list[float]] = {}

    # ------------------------------------------------------------- writers
    def counter(self, name: str, inc: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def histogram(self, name: str, value) -> None:
        """Record one observation, or extend with an iterable of them.
        Non-numeric observations are skipped (the registry is a telemetry
        sink — it must never take the caller down)."""
        bucket = self.histograms.setdefault(name, [])
        if isinstance(value, (str, bytes)) or not hasattr(value, "__iter__"):
            value = (value,)
        for v in value:
            try:
                bucket.append(float(v))
            except (TypeError, ValueError):
                continue

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` into self: counters add, gauges take the latest,
        histograms concatenate. Returns self for chaining."""
        for name, v in other.counters.items():
            self.counter(name, v)
        self.gauges.update(other.gauges)
        for name, vals in other.histograms.items():
            self.histogram(name, vals)
        return self

    # ------------------------------------------------------------- readers
    def snapshot(self) -> dict[str, float]:
        """One flat ``{dotted.name: value}`` view; histograms expand to
        ``.count/.p50/.p95/.p99/.max/.sum``."""
        out: dict[str, float] = {}
        out.update(self.counters)
        out.update(self.gauges)
        for name, vals in self.histograms.items():
            vs = sorted(vals)
            out[f"{name}.count"] = float(len(vs))
            out[f"{name}.p50"] = _quantile(vs, 0.50)
            out[f"{name}.p95"] = _quantile(vs, 0.95)
            out[f"{name}.p99"] = _quantile(vs, 0.99)
            out[f"{name}.max"] = vs[-1] if vs else 0.0
            out[f"{name}.sum"] = sum(vs)
        return out

    def get(self, name: str, default: float = 0.0) -> float:
        return self.snapshot().get(name, default)


# --------------------------------------------------------------------------
# Duck-typed recorders: one per *Stats* family, each writing its stable
# namespace. All tolerate missing attributes (older snapshots) via getattr.
# --------------------------------------------------------------------------

def _us(reg: MetricsRegistry, name: str, seconds: float) -> None:
    reg.gauge(name, seconds * 1e6)


def record_pool(reg: MetricsRegistry, pool_stats, prefix: str = "pool") -> None:
    """``repro.cluster.PoolStats`` → ``pool.*``."""
    s = pool_stats
    reg.counter(f"{prefix}.hits", s.hits)
    reg.counter(f"{prefix}.misses", s.misses)
    reg.counter(f"{prefix}.slabs_created", s.slabs_created)
    reg.counter(f"{prefix}.evictions", s.evictions)
    reg.counter(f"{prefix}.bytes_evicted", s.bytes_evicted)
    reg.gauge(f"{prefix}.bytes_pooled", s.bytes_pooled)
    reg.gauge(f"{prefix}.bytes_resident", s.bytes_resident)
    reg.gauge(f"{prefix}.registered_segments", s.registered_segments)
    reg.gauge(f"{prefix}.hit_rate", s.hit_rate)
    _us(reg, f"{prefix}.register.us", s.modeled_register_s)
    _us(reg, f"{prefix}.acquire.us", s.acquire_s)


def record_repair(reg: MetricsRegistry, repair_stats,
                  prefix: str = "repair") -> None:
    """``repro.cluster.RepairStats`` → ``repair.*``: the peer-to-peer
    re-placement traffic (pulls/reuse), the durability fallbacks
    (``table_copies``), and the background-class QoS charges."""
    s = repair_stats
    reg.counter(f"{prefix}.repairs", s.repairs)
    reg.counter(f"{prefix}.batches_pulled", s.batches_pulled)
    reg.counter(f"{prefix}.bytes_pulled", s.bytes_pulled)
    reg.counter(f"{prefix}.segments_pulled", s.segments_pulled)
    reg.counter(f"{prefix}.batches_reused", s.batches_reused)
    reg.counter(f"{prefix}.table_copies", s.table_copies)
    reg.counter(f"{prefix}.bytes_copied", s.bytes_copied)
    reg.counter(f"{prefix}.yields", s.yields)
    _us(reg, f"{prefix}.wire.us", s.modeled_wire_s)
    _us(reg, f"{prefix}.copy.us", s.modeled_copy_s)
    _us(reg, f"{prefix}.throttle_wait.us", s.throttle_wait_s)
    _us(reg, f"{prefix}.yield.us", s.yield_s)
    _us(reg, f"{prefix}.clock.us", s.clock_s)


def record_stream(reg: MetricsRegistry, stream_stats,
                  prefix: str = "cluster.stream") -> None:
    """One ``repro.cluster.StreamStats`` → counters + per-stream clock
    histogram under ``cluster.stream.*``."""
    s = stream_stats
    reg.counter(f"{prefix}.batches", s.batches)
    reg.counter(f"{prefix}.bytes", s.bytes)
    reg.counter(f"{prefix}.segments", s.segments)
    reg.counter(f"{prefix}.rdma_ops", s.rdma_ops)
    reg.counter(f"{prefix}.control_rpcs", s.control_rpcs)
    reg.counter(f"{prefix}.resumes", s.resumes)
    reg.counter(f"{prefix}.parks", getattr(s, "parks", 0))
    reg.histogram(f"{prefix}.clock.us", s.clock_s * 1e6)


def record_cluster(reg: MetricsRegistry, cluster_stats,
                   prefix: str = "cluster") -> None:
    """``repro.cluster.ClusterStats`` → ``cluster.*`` + ``sched.steals.*``.
    ``cluster.pull.us`` is the fan-out's modeled wire time."""
    c = cluster_stats
    _us(reg, f"{prefix}.pull.us", c.modeled_wire_s)
    _us(reg, f"{prefix}.critical_path.us", c.critical_path_s)
    _us(reg, f"{prefix}.modeled_critical_path.us", c.modeled_critical_path_s)
    _us(reg, f"{prefix}.register.us", c.modeled_register_s)
    _us(reg, f"{prefix}.control_rpc.us", c.control_rpc_s)
    _us(reg, f"{prefix}.prefetch_overlap.us", c.prefetch_overlap_s)
    _us(reg, f"{prefix}.throttle_wait.us", c.throttle_wait_s)
    reg.counter(f"{prefix}.batches", c.batches)
    reg.counter(f"{prefix}.bytes", c.bytes)
    reg.counter(f"{prefix}.segments", sum(s.segments for s in c.streams))
    reg.counter(f"{prefix}.rdma_ops", sum(s.rdma_ops for s in c.streams))
    reg.counter(f"{prefix}.control_rpcs",
                sum(s.control_rpcs for s in c.streams))
    reg.counter(f"{prefix}.resumes", c.resumes)
    reg.counter(f"{prefix}.streams", len(c.streams))
    reg.counter("sched.steals.total", c.steals)
    reg.counter("sched.steals.decline", c.declines)
    reg.counter("sched.steals.re_steal", c.re_steals)
    for s in c.streams:
        record_stream(reg, s, prefix=f"{prefix}.stream")
    if getattr(c, "pool", None) is not None:
        record_pool(reg, c.pool)


def record_tickets(reg: MetricsRegistry, ticket_stats,
                   prefix: str = "sched.tickets") -> None:
    """``repro.sched.TicketStats`` → ``sched.tickets.*``."""
    t = ticket_stats
    reg.counter(f"{prefix}.hits", t.hits)
    reg.counter(f"{prefix}.misses", t.misses)
    reg.counter(f"{prefix}.cancels", t.cancels)
    reg.counter(f"{prefix}.bytes_multicast", t.bytes_multicast)
    reg.gauge(f"{prefix}.hit_rate", t.hit_rate)
    reg.gauge(f"{prefix}.fanouts_saved", t.fanouts_saved)


def record_admission(reg: MetricsRegistry, adm_stats,
                     prefix: str = "qos.admission") -> None:
    """``AdmissionStats`` / ``ShardStats`` / ``DistributedStats`` →
    ``qos.admission.*`` (per-shard stats recurse under ``.shard.<id>``)."""
    a = adm_stats
    reg.counter(f"{prefix}.stream_grants", a.stream_grants)
    reg.counter(f"{prefix}.stream_denials", a.stream_denials)
    reg.counter(f"{prefix}.total_denials", a.total_denials)
    reg.counter(f"{prefix}.memory_denials", a.memory_denials)
    reg.counter(f"{prefix}.lease_grants", a.lease_grants)
    reg.gauge(f"{prefix}.peak_active", a.peak_active)
    _us(reg, f"{prefix}.throttle_wait.us", a.throttle_wait_s)
    for field in ("borrows", "lends", "reconciles"):
        if hasattr(a, field):
            reg.counter(f"{prefix}.{field}", getattr(a, field))
    for field, kind in (("tokens_in", "g"), ("tokens_out", "g"),
                        ("tokens_rebalanced", "g"), ("peak_total", "g")):
        if hasattr(a, field):
            reg.gauge(f"{prefix}.{field}", getattr(a, field))
    for sid, shard in (getattr(a, "shards", None) or {}).items():
        record_admission(reg, shard, prefix=f"{prefix}.shard.{sid}")


def record_qos(reg: MetricsRegistry, qos_stats,
               prefix: str = "qos") -> None:
    """``repro.qos.QosStats`` → ``qos.*`` + per-class ``qos.class.<name>.*``,
    plus the cluster / admission / sched roll-ups it carries.
    ``qos.grant_latency.p50`` is the p50 of the all-class grant-latency
    histogram in µs."""
    q = qos_stats
    reg.counter(f"{prefix}.submitted", q.submitted)
    reg.counter(f"{prefix}.granted", q.granted)
    reg.counter(f"{prefix}.shed", q.shed)
    reg.counter(f"{prefix}.failed", q.failed)
    reg.counter(f"{prefix}.replans", q.replans)
    reg.counter(f"{prefix}.bytes", q.bytes)
    reg.counter(f"{prefix}.batches",
                sum(c.batches for c in q.classes.values()))
    reg.counter(f"{prefix}.ticket_hits", q.ticket_hits)
    reg.counter(f"{prefix}.preemptions", q.preemptions)
    reg.counter(f"{prefix}.alerts", getattr(q, "alerts", 0))
    reg.gauge(f"{prefix}.queue_depth.max", q.queue_depth_max)
    _us(reg, f"{prefix}.makespan.us", q.makespan_s)
    _us(reg, f"{prefix}.throttle_wait.us", q.throttle_wait_s)
    _us(reg, f"{prefix}.service.us",
        sum(c.service_s for c in q.classes.values()))
    for name, c in q.classes.items():
        cp = f"{prefix}.class.{name}"
        reg.counter(f"{cp}.submitted", c.submitted)
        reg.counter(f"{cp}.granted", c.granted)
        reg.counter(f"{cp}.shed", c.shed)
        reg.counter(f"{cp}.failed", c.failed)
        reg.counter(f"{cp}.bytes", c.bytes)
        reg.counter(f"{cp}.batches", c.batches)
        reg.counter(f"{cp}.ticket_hits", c.ticket_hits)
        reg.counter(f"{cp}.preemptions", c.preemptions)
        _us(reg, f"{cp}.service.us", c.service_s)
        reg.histogram(f"{cp}.grant_latency",
                      [v * 1e6 for v in c.grant_latency_s])
        reg.histogram(f"{prefix}.grant_latency",
                      [v * 1e6 for v in c.grant_latency_s])
    if not q.classes:
        reg.histogram(f"{prefix}.grant_latency", [])
    for c in q.cluster:
        record_cluster(reg, c)
    if q.admission is not None:
        record_admission(reg, q.admission, prefix=f"{prefix}.admission")


def record_fabric(reg: MetricsRegistry, fabric,
                  prefix: str = "fabric") -> None:
    """``repro.core.Fabric`` counters → ``fabric.*``."""
    reg.counter(f"{prefix}.rpc_count", fabric.rpc_count)
    reg.counter(f"{prefix}.rdma_count", fabric.rdma_count)
    reg.counter(f"{prefix}.bytes_over_rpc", fabric.bytes_over_rpc)
    reg.counter(f"{prefix}.bytes_over_rdma", fabric.bytes_over_rdma)
    reg.counter(f"{prefix}.registrations", fabric.registrations)
    _us(reg, f"{prefix}.modeled_wire.us",
        getattr(fabric, "modeled_wire_s", 0.0))


def record_loader(reg: MetricsRegistry, loader_stats,
                  prefix: str = "loader") -> None:
    """``repro.data.LoaderStats`` → ``loader.*``."""
    s = loader_stats
    reg.counter(f"{prefix}.batches", s.batches)
    reg.counter(f"{prefix}.backup_requests", s.backup_requests)
    reg.counter(f"{prefix}.stream_resumes", s.stream_resumes)
    reg.counter(f"{prefix}.shared_scans", getattr(s, "shared_scans", 0))
    reg.counter(f"{prefix}.preemptions", getattr(s, "preemptions", 0))
    reg.counter(f"{prefix}.backpressures", getattr(s, "backpressures", 0))
    _us(reg, f"{prefix}.transport.us", s.transport_s)


def record_health(reg: MetricsRegistry, monitor,
                  prefix: str = "health") -> None:
    """``repro.obs.HealthMonitor`` → ``health.*``: per-server state level
    (0=healthy .. 3=quarantined) as a gauge, transition totals as
    counters, plus the cluster-wide pool-pressure gauge."""
    snap = monitor.snapshot()
    reg.gauge(f"{prefix}.heartbeats", snap.get("heartbeats", 0))
    reg.gauge(f"{prefix}.pool_pressure", snap.get("pool_pressure", 0.0))
    levels = {"healthy": 0, "degraded": 1, "suspect": 2, "quarantined": 3}
    for sid, h in snap.get("servers", {}).items():
        sp = f"{prefix}.server.{sid}"
        reg.gauge(f"{sp}.level", levels.get(h.get("state"), 0))
        reg.counter(f"{sp}.transitions", h.get("transitions", 0))
        reg.counter(f"{sp}.faults", h.get("faults", 0))
        reg.counter(f"{sp}.declines", h.get("declines", 0))


def record_gateway(reg: MetricsRegistry, gateway) -> None:
    """Everything a ``ScanGateway`` can see: its ``QosStats`` roll-up plus
    the shared-ticket table and buffer pool when attached."""
    record_qos(reg, gateway.stats)
    scheduler = getattr(gateway, "scheduler", None)
    tickets = getattr(scheduler, "tickets", None)
    if tickets is not None:
        record_tickets(reg, tickets.stats)
    if getattr(gateway, "pool", None) is not None:
        record_pool(reg, gateway.pool.stats)


#: recursion ceiling for :func:`record_any` — deep enough for any real
#: ``*Stats`` nesting, shallow enough to stop self-referential objects
#: (an ndarray's ``.T`` is a fresh ndarray, forever).
_ANY_MAX_DEPTH = 8


def record_any(reg: MetricsRegistry, prefix: str, obj,
               _depth: int = 0) -> None:
    """Generic fallback: walk any ``*Stats`` dataclass (or dict / list of
    them) and record every numeric leaf as a gauge under ``prefix`` —
    proves the whole stats surface round-trips through the registry even
    for classes without a bespoke recorder. Non-numeric / ``None`` leaves
    and unrecognizably exotic objects are skipped, never raised on."""
    if obj is None or isinstance(obj, (str, bytes)):
        return
    if isinstance(obj, bool):
        reg.gauge(prefix, float(obj))
        return
    if isinstance(obj, (int, float)):
        reg.gauge(prefix, float(obj))
        return
    if _depth >= _ANY_MAX_DEPTH:
        return
    if isinstance(obj, dict):
        for k, v in obj.items():
            record_any(reg, f"{prefix}.{k}", v, _depth + 1)
        return
    if isinstance(obj, (list, tuple)):
        if obj and all(isinstance(v, (int, float)) and
                       not isinstance(v, bool) for v in obj):
            reg.histogram(prefix, obj)
        else:
            for i, v in enumerate(obj):
                record_any(reg, f"{prefix}.{i}", v, _depth + 1)
        return
    if dataclasses.is_dataclass(obj):
        for f in dataclasses.fields(obj):
            try:
                v = getattr(obj, f.name)
            except Exception:
                continue
            record_any(reg, f"{prefix}.{f.name}", v, _depth + 1)
        return
    # numeric-like scalar (numpy scalar, Decimal, ...): gauge if it converts
    try:
        reg.gauge(prefix, float(obj))
        return
    except (TypeError, ValueError):
        pass
    # non-dataclass object (e.g. AdmissionStats-like): walk public attrs,
    # but only for plain attribute-bag objects — property-heavy extension
    # types (ndarrays et al.) synthesize fresh objects per access and
    # would recurse without converging.
    if not hasattr(obj, "__dict__"):
        return
    for name in dir(obj):
        if name.startswith("_"):
            continue
        try:
            v = getattr(obj, name)
        except Exception:
            continue
        if callable(v):
            continue
        record_any(reg, f"{prefix}.{name}", v, _depth + 1)
