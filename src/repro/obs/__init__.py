"""repro.obs: the measurement substrate — tracing, telemetry, baselining.

Three coupled layers, all dependency-free (obs imports nothing from the
rest of :mod:`repro`, so every other layer may import obs without cycles):

* **distributed scan tracing** (:mod:`.trace`) — a :class:`TraceContext`
  created at ``ScanGateway.submit`` rides the scan down through the
  scheduler, the stream pullers and the coordinator, recording spans in
  **modeled time** (admission wait, WFQ queueing, lease RPC, RDMA pull,
  prefetch overlap, steal/decline/re-steal, park/unpark, reassembly);
  :class:`Tracer` collects committed scans and exports Chrome
  ``trace_event`` JSON (``utils/report.export_trace``);
* **telemetry registry** (:mod:`.registry`) — a :class:`MetricsRegistry`
  of counters/gauges/histograms that every ``*Stats`` class snapshots
  into under a stable dotted namespace (``cluster.pull.us``,
  ``qos.grant_latency.p50``, ``sched.steals.decline``,
  ``pool.evictions``, …), with one ``registry.snapshot()`` replacing the
  ad-hoc per-layer ``summary()`` plumbing;
* **continuous perf baselining** (:mod:`.baseline` + :mod:`.events`) —
  every ``transport_bench`` scenario emits a structured
  ``BENCH_<scenario>.json`` run record appended to a trajectory;
  rolling baselines (median + MAD window) replace hand-tuned CI
  constants, which remain only as bootstrap floors while the trajectory
  holds fewer than :data:`~repro.obs.baseline.MIN_RUNS` runs;
* **health / SLO / postmortems** (:mod:`.health` + :mod:`.slo` +
  :mod:`.recorder`) — a heartbeat-driven per-server
  ``healthy → degraded → suspect → quarantined`` state machine over the
  signals the stack already produces (:class:`HealthMonitor`),
  declarative objectives over registry names with multi-window
  burn-rate alerting in modeled time (:class:`SloEngine`), and a bounded
  flight-recorder ring of structured cross-layer events
  (:class:`FlightRecorder`) that dumps a postmortem bundle — causal
  events + registry snapshot + health states + trace — when an alert
  fires;
* **stress workload driver** (:mod:`.workload`) — deterministic, seeded
  client populations (:class:`ClientPopulation`) run as side workloads
  (:class:`SideWorkload` / :class:`PopulationSideWorkload`) or as a full
  mix through one gateway (:class:`StressDriver`), with per-population
  telemetry under ``workload.*`` (grant-latency percentiles, throughput,
  shed/decline attribution) and cross-population fairness
  (:func:`jain_index`, latency inflation) judged by ``SloObjective``\\ s.
"""
from __future__ import annotations

from .baseline import (  # noqa: F401
    MIN_RUNS, Baseline, RunRecord, append_run, current_git_sha,
    load_trajectory, rolling_baseline,
)
from .events import MetricPolicy, PerfEvent, detect_events  # noqa: F401
from .health import (  # noqa: F401
    DEGRADED, HEALTHY, QUARANTINED, STATES, SUSPECT, HealthConfig,
    HealthMonitor, HealthTransition, ServerHealth,
)
from .recorder import FlightEvent, FlightRecorder  # noqa: F401
from .registry import (  # noqa: F401
    MetricsRegistry, record_admission, record_any, record_cluster,
    record_fabric, record_gateway, record_health, record_loader,
    record_pool, record_qos, record_repair, record_tickets,
)
from .slo import SloAlert, SloEngine, SloObjective  # noqa: F401
from .trace import Span, StreamTrace, TraceContext, Tracer  # noqa: F401
from .workload import (  # noqa: F401
    BeatReport, ClientPopulation, InteractiveSideLoad,
    PopulationSideWorkload, SideWorkload, StressDriver, jain_index,
    population_classes, record_workload,
)
