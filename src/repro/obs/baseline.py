"""Continuous perf baselining: run records, trajectories, rolling baselines.

Every ``transport_bench`` scenario emits a :class:`RunRecord` — git sha,
host/config fingerprint, per-metric values, per-metric policies — written
as ``BENCH_<scenario>.json`` (the latest run) and appended to
``trajectory.jsonl`` (the full history). :func:`rolling_baseline` reduces a
metric's recent history to a median + MAD :class:`Baseline`, whose
``envelope()`` is the pass band CI checks instead of hand-tuned constants.

Run as a module to re-judge the latest record of every scenario in a
trajectory directory (this is what the CI ``bench-trajectory`` job calls)::

    python -m repro.obs.baseline artifacts/bench

Exit status 1 when any regression event fires.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import subprocess

# Envelope activates once a metric has this many *prior* runs (i.e. from
# the third run of a trajectory); before that only bootstrap constants
# apply. ISSUE: "constants remain only as bootstrap floors while the
# trajectory has <3 runs".
MIN_RUNS = 2

TRAJECTORY = "trajectory.jsonl"


def current_git_sha(cwd: str | None = None) -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
    except Exception:
        return ""


@dataclasses.dataclass
class RunRecord:
    """One benchmark scenario execution, self-describing enough to be
    re-judged later: values plus the policies they were judged under."""

    scenario: str
    metrics: dict = dataclasses.field(default_factory=dict)
    policies: dict = dataclasses.field(default_factory=dict)  # name -> dict
    git_sha: str = ""
    config: dict = dataclasses.field(default_factory=dict)
    timestamp: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunRecord":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass(frozen=True)
class Baseline:
    """Robust location/scale of one metric over a trajectory window."""

    metric: str
    median: float
    mad: float                          # median absolute deviation
    n: int                              # runs in the window

    def envelope(self, rel_slack: float = 0.10,
                 k: float = 3.0) -> tuple[float, float]:
        """``median ± max(k·1.4826·MAD, rel_slack·|median|)``. The MAD term
        scales with observed run-to-run noise (1.4826 makes it a sigma
        estimate under normality); the relative term keeps a deterministic
        metric (MAD 0) from flagging sub-percent wiggle."""
        spread = max(k * 1.4826 * self.mad, rel_slack * abs(self.median))
        return self.median - spread, self.median + spread


def rolling_baseline(records: list["RunRecord"], metric: str,
                     window: int = 10) -> Baseline:
    """Median + MAD of ``metric`` over the most recent ``window`` records
    that carry it (records are oldest-first, as loaded)."""
    vals = [r.metrics[metric] for r in records if metric in r.metrics]
    vals = vals[-window:]
    if not vals:
        return Baseline(metric, 0.0, 0.0, 0)
    med = statistics.median(vals)
    mad = statistics.median([abs(v - med) for v in vals])
    return Baseline(metric, med, mad, len(vals))


# ---------------------------------------------------------------- storage
def append_run(out_dir: str, record: RunRecord) -> str:
    """Write ``BENCH_<scenario>.json`` (latest run, human-inspectable) and
    append the record to ``trajectory.jsonl``. Returns the JSON path."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{record.scenario}.json")
    with open(path, "w") as f:
        json.dump(record.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    with open(os.path.join(out_dir, TRAJECTORY), "a") as f:
        f.write(json.dumps(record.to_dict(), sort_keys=True) + "\n")
    return path


def load_trajectory(out_dir: str,
                    scenario: str | None = None) -> list[RunRecord]:
    """All recorded runs, oldest first; optionally one scenario's."""
    path = os.path.join(out_dir, TRAJECTORY)
    if not os.path.exists(path):
        return []
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = RunRecord.from_dict(json.loads(line))
            if scenario is None or rec.scenario == scenario:
                records.append(rec)
    return records


# -------------------------------------------------------------------- CLI
def check_dir(out_dir: str) -> tuple[list, int]:
    """Re-judge the newest record of every scenario in ``out_dir`` against
    its predecessors, under the policies persisted in the record itself.
    Returns (events, n_scenarios_checked)."""
    from .events import MetricPolicy, detect_events   # lazy: events imports us
    trajectory = load_trajectory(out_dir)
    events, checked = [], 0
    for scenario in sorted({r.scenario for r in trajectory}):
        runs = [r for r in trajectory if r.scenario == scenario]
        latest, history = runs[-1], runs[:-1]
        policies = {name: MetricPolicy.from_dict(d)
                    for name, d in latest.policies.items()}
        if not policies:
            continue
        checked += 1
        events.extend(detect_events(latest, history, policies))
    return events, checked


def main(argv: list[str] | None = None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="judge the latest benchmark runs against their "
                    "rolling baselines")
    parser.add_argument("out_dir", help="trajectory directory "
                        "(holds trajectory.jsonl + BENCH_*.json)")
    args = parser.parse_args(argv)
    events, checked = check_dir(args.out_dir)
    regressions = [e for e in events if e.is_regression]
    for e in events:
        print(e)
    runs = len(load_trajectory(args.out_dir))
    print(f"baseline: {checked} scenario(s) checked over {runs} recorded "
          f"run(s); {len(regressions)} regression(s), "
          f"{len(events) - len(regressions)} improvement(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
