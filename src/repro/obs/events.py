"""Typed perf events: regression / improvement detection against baselines.

Each benchmark metric declares a :class:`MetricPolicy` (direction, bootstrap
floor/ceiling, envelope slack). :func:`detect_events` compares the latest
run against the rolling baseline of its predecessors:

* with fewer than :data:`~.baseline.MIN_RUNS` prior runs the trajectory is
  still bootstrapping — only the hand-tuned ``floor`` / ``ceiling``
  constants apply (exactly the constants CI asserted before this module
  existed);
* once enough history exists, the envelope takes over:
  ``median ± max(k·1.4826·MAD, rel_slack·|median|)`` — a robust band that
  adapts as the system (or the host) drifts, instead of rotting constants.

Events are plain data so CI can render them, count regressions for the
exit code, and archive them next to the trajectory.
"""
from __future__ import annotations

import dataclasses

from .baseline import MIN_RUNS, RunRecord, rolling_baseline


@dataclasses.dataclass(frozen=True)
class MetricPolicy:
    """How one benchmark metric is judged.

    ``better`` gives the improvement direction ("higher" for speedups,
    "lower" for latencies); ``floor``/``ceiling`` are the bootstrap
    constants asserted while the trajectory is short (and kept as absolute
    backstops afterwards); ``rel_slack`` widens the envelope to at least
    that fraction of the median so a near-zero MAD (deterministic metric)
    doesn't flag noise-level wiggle.
    """

    metric: str
    better: str = "higher"              # "higher" | "lower"
    floor: float | None = None          # bootstrap: fail if value < floor
    ceiling: float | None = None        # bootstrap: fail if value > ceiling
    rel_slack: float = 0.10
    window: int = 10

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MetricPolicy":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})


@dataclasses.dataclass(frozen=True)
class PerfEvent:
    """One detected excursion: a regression, an improvement, or a bootstrap
    floor/ceiling violation."""

    kind: str                           # "regression" | "improvement"
    scenario: str
    metric: str
    value: float
    baseline_median: float
    lo: float
    hi: float
    n_runs: int                         # prior runs the baseline used
    detail: str = ""

    @property
    def is_regression(self) -> bool:
        return self.kind == "regression"

    def __str__(self) -> str:
        band = (f"baseline={self.baseline_median:.4g} "
                f"[{self.lo:.4g}, {self.hi:.4g}] n={self.n_runs}")
        return (f"[{self.kind.upper()}] {self.scenario}.{self.metric} = "
                f"{self.value:.4g} ({band}){' — ' + self.detail if self.detail else ''}")


def _bootstrap_events(record: RunRecord, policy: MetricPolicy,
                      value: float, n: int) -> list[PerfEvent]:
    events = []
    if policy.floor is not None and value < policy.floor:
        events.append(PerfEvent(
            "regression", record.scenario, policy.metric, value,
            policy.floor, policy.floor, float("inf"), n,
            detail="bootstrap floor"))
    if policy.ceiling is not None and value > policy.ceiling:
        events.append(PerfEvent(
            "regression", record.scenario, policy.metric, value,
            policy.ceiling, float("-inf"), policy.ceiling, n,
            detail="bootstrap ceiling"))
    return events


def detect_events(record: RunRecord, history: list[RunRecord],
                  policies: dict[str, MetricPolicy]) -> list[PerfEvent]:
    """Judge ``record`` against its predecessors (``history`` excludes the
    record itself). Returns every excursion, regressions and improvements
    both; callers gate CI on ``[e for e in events if e.is_regression]``."""
    events: list[PerfEvent] = []
    for name, policy in policies.items():
        if name not in record.metrics:
            continue
        value = record.metrics[name]
        prior = [r for r in history if name in r.metrics]
        n = len(prior)
        # absolute backstops always apply (and are all that applies while
        # the trajectory is bootstrapping)
        events.extend(_bootstrap_events(record, policy, value, n))
        if n < MIN_RUNS:
            continue
        base = rolling_baseline(prior, name, window=policy.window)
        lo, hi = base.envelope(rel_slack=policy.rel_slack)
        if policy.better == "higher":
            if value < lo:
                events.append(PerfEvent("regression", record.scenario, name,
                                        value, base.median, lo, hi, n))
            elif value > hi:
                events.append(PerfEvent("improvement", record.scenario, name,
                                        value, base.median, lo, hi, n))
        else:
            if value > hi:
                events.append(PerfEvent("regression", record.scenario, name,
                                        value, base.median, lo, hi, n))
            elif value < lo:
                events.append(PerfEvent("improvement", record.scenario, name,
                                        value, base.median, lo, hi, n))
    return events
