"""Stress workload driver: modeled client populations + fairness telemetry.

Every scenario in the BENCH trajectory exercises one scripted shape per
feature; production traffic is a *mix* — interactive lookups riding under
batch analytics while a scan storm bursts and a quota squatter sits on
admission slots. This module generates that mix deterministically:

* :class:`ClientPopulation` — a declarative spec for one client class
  (arrival process, per-beat rate, cost distribution, fan-out width,
  deadline, activation window, optional admission-slot squatting);
* :class:`SideWorkload` — the protocol (after YDB's ``side_workloads.py``)
  for anything that submits background requests *alongside* a measured
  scenario, on the measured scenario's own modeled clock.
  :class:`InteractiveSideLoad` is the reference implementation (the PR 7
  ``transport_bench.submit_side_load`` shape); :class:`PopulationSideWorkload`
  runs one :class:`ClientPopulation` as a side workload;
* :class:`StressDriver` — submits a whole population mix through one
  ``ScanGateway`` heartbeat by heartbeat, snapshots per-population
  telemetry into the ``workload.*`` registry namespace
  (``workload.<pop>.grant_latency.p50/p99``, per-population throughput,
  shed/decline attribution from the flight recorder) plus cross-population
  fairness (:func:`jain_index` over per-class throughput,
  interactive-vs-batch latency inflation), and feeds every beat's snapshot
  to an optional ``SloEngine`` so burn-rate pages are the pass/fail signal.

Arrivals for beat *b* are stamped inside the modeled window
``(prev_beat_clock, this_beat_clock]`` — "arrived while the previous beat
was draining, submitted at the boundary" — so queue waits are non-negative
by construction and a long overloaded beat genuinely inflates the next
beat's grant latencies. Randomness comes from a per-population
``numpy.random.default_rng`` seeded from ``(seed, crc32(name))``: the same
seed replays the identical submit schedule and registry snapshot.

Like the rest of :mod:`repro.obs` this module imports nothing from the
layers it drives at import time — the gateway/admission objects are
duck-typed, and ``ScanRequest``/``ClientClass`` are imported lazily inside
the factories that build them.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from .registry import MetricsRegistry


def jain_index(values) -> float:
    """Jain's fairness index ``(Σx)² / (n·Σx²)`` over per-class throughput.

    Bounds: ``1/n`` (one class hogs everything) to ``1.0`` (perfect
    equality). Degenerate inputs are *fair by definition*: an empty set,
    a single class, or an all-zero allocation all return 1.0 — nobody is
    being starved relative to anybody else.
    """
    vals = [max(0.0, float(v)) for v in values]
    total = sum(vals)
    if not vals or total <= 0.0:
        return 1.0
    return (total * total) / (len(vals) * sum(v * v for v in vals))


def _request(**kw):
    # lazy: obs stays an import-leaf; the qos layer is only touched when a
    # workload actually builds a request
    from ..qos import ScanRequest
    return ScanRequest(**kw)


@dataclasses.dataclass(frozen=True)
class ClientPopulation:
    """One client class's traffic spec, in modeled time.

    ``arrival`` picks the process stamping offsets inside each beat window:

    * ``"burst"`` — all ``rate_per_beat`` requests at the window end (the
      submit instant; what scripted scenarios and side-loads do);
    * ``"uniform"`` — evenly spaced across the window, rng-free (so a
      population without cost jitter is schedule-identical across seeds);
    * ``"poisson"`` — a Poisson-drawn count at uniform-random offsets.

    ``squat_servers`` names admission shards on which the population holds
    one stream slot each while active (the adversarial quota-squatter: it
    submits nothing, it just makes *other* tenants' fan-outs decline).
    A server listed twice squats two of its slots.
    """

    name: str                          # gateway class name (WFQ weight key)
    weight: float = 1.0                # WFQ weight for the class
    arrival: str = "burst"             # "burst" | "uniform" | "poisson"
    rate_per_beat: float = 1.0         # mean submissions per heartbeat
    sql: str = "SELECT c0 FROM t"
    dataset: str = "/d"
    cost_hint: float = 1.0
    cost_jitter: float = 0.0           # lognormal sigma on cost_hint
    num_streams: int | None = None
    deadline_s: float | None = None
    client_id: str | None = None       # defaults to the population name
    start_beat: int = 0                # first active beat (inclusive)
    stop_beat: int | None = None       # first inactive beat (exclusive)
    squat_servers: tuple = ()

    def __post_init__(self):
        if self.arrival not in ("burst", "uniform", "poisson"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")
        if self.rate_per_beat < 0:
            raise ValueError("rate_per_beat must be >= 0")

    def active(self, beat: int) -> bool:
        return (beat >= self.start_beat
                and (self.stop_beat is None or beat < self.stop_beat))

    def draw(self, rng: np.random.Generator, window_lo_s: float,
             window_hi_s: float) -> list[dict]:
        """One beat's submissions as ``ScanRequest`` kwargs, arrival-sorted.

        Deterministic per rng state; ``"burst"``/``"uniform"`` with zero
        cost jitter never touch the rng at all.
        """
        if self.arrival == "poisson":
            count = int(rng.poisson(self.rate_per_beat))
        else:
            count = int(round(self.rate_per_beat))
        if count <= 0:
            return []
        span = max(0.0, window_hi_s - window_lo_s)
        if self.arrival == "burst":
            offsets = [window_hi_s] * count
        elif self.arrival == "uniform":
            offsets = [window_lo_s + span * (i + 1) / count
                       for i in range(count)]
        else:
            offsets = sorted(window_lo_s + span * float(u)
                             for u in rng.uniform(0.0, 1.0, size=count))
        cid = self.client_id if self.client_id is not None else self.name
        out = []
        for at_s in offsets:
            cost = self.cost_hint
            if self.cost_jitter > 0.0:
                cost *= float(np.exp(
                    self.cost_jitter * rng.standard_normal()))
            out.append(dict(client_id=cid, klass=self.name, sql=self.sql,
                            dataset=self.dataset, cost_hint=cost,
                            deadline_s=self.deadline_s, arrival_s=at_s,
                            num_streams=self.num_streams))
        return out


def population_classes(populations):
    """The ``ClientClass`` list a gateway needs to queue these populations
    (one class per population, weight carried over)."""
    from ..qos import ClientClass
    return [ClientClass(p.name, p.weight) for p in populations]


class SideWorkload:
    """Protocol for background traffic riding a measured scenario.

    A side workload owns *what* to submit; the caller owns *when*: each
    ``submit(gateway)`` call stamps one beat's worth of requests onto the
    gateway's current modeled clock and returns the accepted requests
    (``None`` entries were shed at submit). Implementations must not drain
    the gateway — the measured scenario decides when ``run()`` happens.
    """

    name = "side"

    def submit(self, gateway, now_s: float | None = None) -> list:
        raise NotImplementedError


class InteractiveSideLoad(SideWorkload):
    """The reference side workload: ``count`` light interactive lookups at
    the current modeled instant — exactly the PR 7
    ``transport_bench.submit_side_load`` shape, now behind the protocol."""

    def __init__(self, sql: str, dataset: str = "/d", *, count: int = 2,
                 client_id: str = "side", klass: str = "interactive",
                 cost_hint: float = 1.0, num_streams: int | None = 2):
        self.name = client_id
        self.sql = sql
        self.dataset = dataset
        self.count = count
        self.client_id = client_id
        self.klass = klass
        self.cost_hint = cost_hint
        self.num_streams = num_streams

    def submit(self, gateway, now_s: float | None = None) -> list:
        now = gateway.clock_s if now_s is None else now_s
        reqs = []
        for _ in range(self.count):
            reqs.append(gateway.submit(_request(
                client_id=self.client_id, klass=self.klass, sql=self.sql,
                dataset=self.dataset, cost_hint=self.cost_hint,
                arrival_s=now, num_streams=self.num_streams)))
        return reqs


class PopulationSideWorkload(SideWorkload):
    """One :class:`ClientPopulation` run as a side workload.

    Keeps a window cursor: each ``submit`` stamps the arrivals that landed
    in ``(last_submit_clock, now]``, so back-to-back beats tile modeled
    time with no gaps and no overlap. ``schedule`` accumulates every
    submitted request's kwargs — the determinism test's witness.
    """

    def __init__(self, population: ClientPopulation, seed: int = 0):
        self.population = population
        self.name = population.name
        self.rng = np.random.default_rng(
            [seed & 0xFFFFFFFF, zlib.crc32(population.name.encode())])
        self.beat = 0
        self.schedule: list[dict] = []
        self._last_s: float | None = None

    def submit(self, gateway, now_s: float | None = None) -> list:
        now = gateway.clock_s if now_s is None else now_s
        # min(): a fresh gateway's clock restarts at 0 (the slo scenario
        # swaps gateways between phases) — never stamp arrivals after `now`
        lo = now if self._last_s is None else min(self._last_s, now)
        reqs = []
        if self.population.active(self.beat):
            for kw in self.population.draw(self.rng, lo, now):
                self.schedule.append(dict(kw))
                reqs.append(gateway.submit(_request(**kw)))
        self._last_s = now
        self.beat += 1
        return reqs


@dataclasses.dataclass
class BeatReport:
    """One driver heartbeat's outcome."""

    index: int
    now_s: float
    submitted: int
    granted: int
    shed: int
    declined: int
    alerts: list = dataclasses.field(default_factory=list)
    migrations: int = 0     # leases failed over to a replica this beat
    membership: list = dataclasses.field(default_factory=list)


class StressDriver:
    """Submits a population mix through one gateway, beat by beat.

    Each :meth:`beat` stamps every active population's arrivals into the
    window since the previous beat, drains the gateway, heartbeats the
    coordinator, rebuilds :attr:`registry` (the ``workload.*`` namespace
    via :func:`record_workload`) and — when an ``SloEngine`` is attached —
    feeds it the snapshot so burn-rate objectives judge the mix.

    Shed/decline attribution rides the coordinator's flight recorder:
    ``qos.shed`` events (deadline sheds) and ``qos.backpressure`` events
    (admission declines) carry ``klass=`` attrs, so the driver splits each
    population's ``ClassStats.shed`` total causally. Squatting populations
    seize/release their admission slots at their activation edges.

    Everything here is modeled time on the gateway's own clock; with the
    same seed and the same fabric the whole run — schedule, telemetry,
    alerts — replays identically.
    """

    def __init__(self, gateway, populations, *, seed: int = 0, slo=None,
                 recorder=None, nemesis=None, membership=None,
                 inflation_pair: tuple[str, str] = ("interactive", "batch")):
        self.gateway = gateway
        self.populations = list(populations)
        self.loads = [PopulationSideWorkload(p, seed=seed)
                      for p in self.populations]
        self.slo = slo
        # optional chaos loop (both duck-typed): the nemesis injects its
        # scheduled faults at the top of each beat, the membership
        # controller acts on health verdicts right after the heartbeat
        self.nemesis = nemesis
        self.membership = membership
        self.migrations = 0      # cumulative stream.migrate events observed
        self.beat_migrations = 0
        self.recorder = (recorder if recorder is not None else
                         getattr(getattr(gateway, "coordinator", None),
                                 "recorder", None))
        self.inflation_pair = inflation_pair
        self.registry = MetricsRegistry()
        self.alerts: list = []
        self.reports: list[BeatReport] = []
        self.beats = 0
        self.sheds: dict[str, int] = {p.name: 0 for p in self.populations}
        self.declines: dict[str, int] = {p.name: 0
                                         for p in self.populations}
        self.beat_stats: dict[str, dict] = {}
        self._start_clock_s = gateway.clock_s
        self._event_seq = (-1 if self.recorder is None
                           else self.recorder.next_seq - 1)
        self._held: dict[str, list] = {}

    # ------------------------------------------------------------- windows
    @property
    def window_s(self) -> float:
        """The modeled span the driver has been submitting over."""
        return self.gateway.clock_s - self._start_clock_s

    # ---------------------------------------------------------------- beat
    def beat(self) -> BeatReport:
        gw = self.gateway
        index = self.beats
        if self.nemesis is not None:
            self.nemesis.beat(index, gw.clock_s)
        self._squat(index)
        before = {p.name: self._class_counts(p.name)
                  for p in self.populations}
        migrate_seq = (self.recorder.next_seq
                       if self.recorder is not None else 0)
        submitted = []
        for load in self.loads:
            submitted.extend(load.submit(gw, now_s=gw.clock_s))
        gw.run()
        now = gw.clock_s
        heartbeat = getattr(getattr(gw, "coordinator", None),
                            "heartbeat", None)
        if callable(heartbeat):
            heartbeat(now)
        transitions = (self.membership.heartbeat(now)
                       if self.membership is not None else [])
        migrations = 0
        if self.recorder is not None:
            migrations = sum(
                1 for ev in self.recorder.events(kinds=("stream.migrate",))
                if ev.seq >= migrate_seq)
            self.migrations += migrations
        self.beat_migrations = migrations
        shed_d, decl_d = self._attribute_events()
        self.beat_stats = {}
        for p in self.populations:
            b0 = before[p.name]
            b1 = self._class_counts(p.name)
            fresh = self._class_latencies(p.name)[b0["latencies"]:]
            self.beat_stats[p.name] = {
                "submitted": b1["submitted"] - b0["submitted"],
                "granted": b1["granted"] - b0["granted"],
                "shed": b1["shed"] - b0["shed"],
                "declines": decl_d.get(p.name, 0),
                "deadline_sheds": shed_d.get(p.name, 0),
                "p50_grant_us": _p50(fresh) * 1e6,
            }
        reg = MetricsRegistry()
        record_workload(reg, self)
        self.registry = reg
        fired = (list(self.slo.observe(now, reg.snapshot()))
                 if self.slo is not None else [])
        self.alerts.extend(fired)
        gw.stats.alerts += len(fired)
        report = BeatReport(
            index=index, now_s=now, submitted=len(submitted),
            granted=sum(s["granted"] for s in self.beat_stats.values()),
            shed=sum(s["shed"] for s in self.beat_stats.values()),
            declined=sum(s["declines"] for s in self.beat_stats.values()),
            alerts=fired, migrations=migrations,
            membership=list(transitions))
        self.reports.append(report)
        self.beats += 1
        return report

    # ------------------------------------------------------------ fairness
    def fairness(self) -> dict:
        """Cross-population fairness over the driver's modeled window:
        Jain's index over per-class throughput (populations that have
        submitted at least once), and the latency-inflation ratio between
        ``inflation_pair`` (p50 grant latency of the first over the
        second; 1.0 when either side has no samples)."""
        window = self.window_s
        tputs: dict[str, float] = {}
        for p in self.populations:
            c = self.gateway.stats.classes.get(p.name)
            if c is None or c.submitted == 0:
                continue
            tputs[p.name] = c.throughput_over(window)
        hi, lo = self.inflation_pair
        hi_p50 = _p50(self._class_latencies(hi))
        lo_p50 = _p50(self._class_latencies(lo))
        inflation = (hi_p50 / lo_p50) if hi_p50 > 0 and lo_p50 > 0 else 1.0
        return {"jain": jain_index(tputs.values()),
                "throughput_bps": tputs,
                "latency_inflation": inflation}

    # ------------------------------------------------------------- helpers
    def _class_counts(self, name: str) -> dict:
        c = self.gateway.stats.classes.get(name)
        if c is None:
            return {"submitted": 0, "granted": 0, "shed": 0, "latencies": 0}
        return {"submitted": c.submitted, "granted": c.granted,
                "shed": c.shed, "latencies": len(c.grant_latency_s)}

    def _class_latencies(self, name: str) -> list[float]:
        c = self.gateway.stats.classes.get(name)
        return [] if c is None else c.grant_latency_s

    def _attribute_events(self) -> tuple[dict, dict]:
        """Split this beat's recorder window into per-population deadline
        sheds (``qos.shed``) and admission declines (``qos.backpressure``),
        keyed by the event's ``klass`` attr."""
        shed_d: dict[str, int] = {}
        decl_d: dict[str, int] = {}
        if self.recorder is None:
            return shed_d, decl_d
        names = {p.name for p in self.populations}
        for ev in self.recorder.events(
                kinds=("qos.shed", "qos.backpressure")):
            if ev.seq <= self._event_seq:
                continue
            klass = ev.attrs.get("klass", "")
            if klass not in names:
                continue
            bucket = shed_d if ev.kind == "qos.shed" else decl_d
            bucket[klass] = bucket.get(klass, 0) + 1
        self._event_seq = self.recorder.next_seq - 1
        for name, n in shed_d.items():
            self.sheds[name] = self.sheds.get(name, 0) + n
        for name, n in decl_d.items():
            self.declines[name] = self.declines.get(name, 0) + n
        return shed_d, decl_d

    def _squat(self, beat: int) -> None:
        """Seize/release squatting populations' admission slots at their
        activation edges. A denied squat is counted as that population's
        own decline — the squatter lost, everyone else is safe."""
        admission = getattr(getattr(self.gateway, "coordinator", None),
                            "admission", None)
        if admission is None:
            return
        from ..qos import Backpressure
        for p in self.populations:
            if not p.squat_servers:
                continue
            cid = p.client_id if p.client_id is not None else p.name
            if p.active(beat) and p.name not in self._held:
                held = []
                for sid in p.squat_servers:
                    try:
                        admission.acquire_stream(cid, server_id=sid)
                        held.append(sid)
                    except Backpressure:
                        self.declines[p.name] = (
                            self.declines.get(p.name, 0) + 1)
                self._held[p.name] = held
            elif not p.active(beat) and p.name in self._held:
                for sid in self._held.pop(p.name):
                    admission.release_stream(cid, server_id=sid)


def _p50(values) -> float:
    if not values:
        return 0.0
    ordered = sorted(values)
    return ordered[min(len(ordered) - 1,
                       max(0, int(round(0.5 * (len(ordered) - 1)))))]


def record_workload(reg: MetricsRegistry, driver,
                    prefix: str = "workload") -> None:
    """A :class:`StressDriver` → the ``workload.*`` namespace.

    Per population: cumulative counters (submitted/granted/shed plus the
    recorder-attributed deadline-shed vs admission-decline split), the
    grant-latency histogram in µs (snapshot expands to ``.p50/.p95/.p99``),
    window throughput, and per-beat gauges (``.beat.*``) the SLO engine's
    burn-rate objectives watch. Cross-population: Jain's fairness index and
    the latency-inflation ratio. Everything recorded is modeled — two runs
    with the same seed and fabric snapshot identically.
    """
    classes = driver.gateway.stats.classes
    window_s = driver.window_s
    for p in driver.populations:
        pp = f"{prefix}.{p.name}"
        c = classes.get(p.name)
        if c is not None:
            reg.counter(f"{pp}.submitted", c.submitted)
            reg.counter(f"{pp}.granted", c.granted)
            reg.counter(f"{pp}.shed", c.shed)
            reg.counter(f"{pp}.bytes", c.bytes)
            reg.histogram(f"{pp}.grant_latency",
                          [v * 1e6 for v in c.grant_latency_s])
            reg.gauge(f"{pp}.throughput_bps", c.throughput_over(window_s))
        reg.counter(f"{pp}.shed.deadline", driver.sheds.get(p.name, 0))
        reg.counter(f"{pp}.declines", driver.declines.get(p.name, 0))
        beat = driver.beat_stats.get(p.name, {})
        reg.gauge(f"{pp}.beat.submitted", beat.get("submitted", 0))
        reg.gauge(f"{pp}.beat.granted", beat.get("granted", 0))
        reg.gauge(f"{pp}.beat.shed", beat.get("shed", 0))
        reg.gauge(f"{pp}.beat.declines", beat.get("declines", 0))
        reg.gauge(f"{pp}.beat.p50_grant_us", beat.get("p50_grant_us", 0.0))
    reg.counter(f"{prefix}.migrations", getattr(driver, "migrations", 0))
    reg.gauge(f"{prefix}.beat.migrations",
              float(getattr(driver, "beat_migrations", 0)))
    fair = driver.fairness()
    reg.gauge(f"{prefix}.fairness.jain", fair["jain"])
    reg.gauge(f"{prefix}.fairness.latency_inflation",
              fair["latency_inflation"])
    reg.gauge(f"{prefix}.window.us", window_s * 1e6)
    reg.gauge(f"{prefix}.populations", float(len(driver.populations)))
