"""SLO objectives with multi-window burn-rate alerting, in modeled time.

An :class:`SloObjective` is declarative: a ``MetricsRegistry`` snapshot
name (``qos.grant_latency.p50``, ``cluster.modeled_critical_path.us``,
``scan.delivered``, ...), a threshold that makes one sample *good* or
*bad*, and a goal fraction of good samples. The :class:`SloEngine` is fed
one snapshot per heartbeat (:meth:`SloEngine.observe`) and evaluates the
classic multi-window burn rate over the samples' modeled timestamps:

    ``burn(window) = bad_fraction(window) / (1 - goal)``

i.e. burn 1.0 consumes the error budget exactly at the rate the goal
allows; an alert fires only when **every** configured window's burn
exceeds its threshold — the long window proves the burn is sustained (no
paging on one bad scan), the short window proves it is *current* (no
paging an hour after the incident ended). Deduplication is stateful: a
firing objective stays latched until every window drops back under its
threshold, so a sustained breach produces one alert, not one per
heartbeat.

Alerts are frozen :class:`SloAlert` events — same discipline as
``obs.events.PerfEvent`` — appended to ``SloEngine.alerts`` and pushed to
subscribers (typically a ``FlightRecorder.postmortem`` dump). The module
imports nothing outside ``repro.obs``; snapshots are plain dicts.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class SloObjective:
    """One declarative objective over a registry snapshot name.

    ``windows`` is a tuple of ``(window_s, max_burn)`` pairs in modeled
    seconds, longest first by convention; ``min_samples`` applies to the
    longest window (shorter windows only need one sample — they exist to
    prove the burn is current, not to establish it).
    """

    name: str
    metric: str
    target: float
    better: str = "lower"             # good when value <= target ("lower")
    goal: float = 0.99                # required good-sample fraction
    windows: tuple = ((1.0, 1.0), (0.25, 1.0))
    min_samples: int = 3

    def bad(self, value: float) -> bool:
        if self.better == "lower":
            return value > self.target
        return value < self.target

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SloAlert:
    """Typed burn-rate alert (the ``PerfEvent`` discipline)."""

    kind: str                         # "burn_rate"
    objective: str
    metric: str
    value: float                      # the sample that tipped it
    target: float
    goal: float
    burns: tuple                      # burn per window, objective order
    windows: tuple                    # the (window_s, max_burn) pairs
    now_s: float
    n_samples: int
    detail: str = ""

    @property
    def is_page(self) -> bool:
        """Every window over threshold — by construction, always true for
        emitted alerts; kept as a property for symmetry with
        ``PerfEvent.is_regression``."""
        return all(b >= max_burn for b, (_, max_burn)
                   in zip(self.burns, self.windows))

    def __str__(self) -> str:
        wins = ", ".join(
            f"{w * 1e3:g}ms burn {b:.2f}/{mb:g}"
            for b, (w, mb) in zip(self.burns, self.windows))
        return (f"[slo:{self.kind}] {self.objective} ({self.metric}) "
                f"value {self.value:g} vs target {self.target:g} at "
                f"{self.now_s * 1e3:.3f}ms [{wins}] "
                f"n={self.n_samples}{' ' + self.detail if self.detail else ''}")


class SloEngine:
    """Evaluates objectives against per-heartbeat registry snapshots."""

    def __init__(self, objectives=()):
        self.objectives: list[SloObjective] = list(objectives)
        self.alerts: list[SloAlert] = []
        self.resolved = 0              # latched alerts that cleared
        self._samples: dict[str, collections.deque] = {}
        self._firing: dict[str, bool] = {}
        self._subs: list[Callable[[SloAlert], None]] = []

    def add(self, objective: SloObjective) -> "SloEngine":
        self.objectives.append(objective)
        return self

    def subscribe(self, callback: Callable[[SloAlert], None]) -> None:
        """``callback(alert)`` runs synchronously when an alert fires —
        the postmortem hook."""
        self._subs.append(callback)

    def firing(self, name: str) -> bool:
        return self._firing.get(name, False)

    # -- evaluation -------------------------------------------------------

    def observe(self, now_s: float, snapshot: dict) -> list[SloAlert]:
        """Feed one heartbeat's registry snapshot; returns alerts fired by
        this observation. Objectives whose metric is absent from the
        snapshot simply record no sample this beat."""
        fired: list[SloAlert] = []
        for obj in self.objectives:
            value = snapshot.get(obj.metric)
            if value is None or isinstance(value, bool):
                continue
            try:
                value = float(value)
            except (TypeError, ValueError):
                continue
            samples = self._samples.setdefault(obj.name, collections.deque())
            samples.append((now_s, obj.bad(value), value))
            self._trim(obj, samples, now_s)
            alert = self._evaluate(obj, samples, now_s, value)
            if alert is not None:
                fired.append(alert)
        return fired

    def _evaluate(self, obj: SloObjective, samples, now_s: float,
                  value: float) -> SloAlert | None:
        burns: list[float] = []
        total_long = 0
        over_all = bool(obj.windows)
        budget = max(1.0 - obj.goal, 1e-9)
        for i, (window_s, max_burn) in enumerate(obj.windows):
            inside = [bad for (t, bad, _) in samples
                      if t > now_s - window_s]
            n = len(inside)
            if i == 0:
                total_long = n
            if n == 0:
                burns.append(0.0)
                over_all = False
                continue
            burn = (sum(inside) / n) / budget
            burns.append(burn)
            if burn < max_burn:
                over_all = False
        if total_long < obj.min_samples:
            over_all = False

        if not over_all:
            if self._firing.get(obj.name):
                self._firing[obj.name] = False
                self.resolved += 1
            return None
        if self._firing.get(obj.name):
            return None                   # latched: dedup sustained breach
        self._firing[obj.name] = True
        alert = SloAlert(kind="burn_rate", objective=obj.name,
                         metric=obj.metric, value=value, target=obj.target,
                         goal=obj.goal, burns=tuple(burns),
                         windows=tuple(obj.windows), now_s=now_s,
                         n_samples=total_long)
        self.alerts.append(alert)
        for cb in list(self._subs):
            cb(alert)
        return alert

    @staticmethod
    def _trim(obj: SloObjective, samples, now_s: float) -> None:
        if not obj.windows:
            return
        horizon = now_s - 2.0 * max(w for w, _ in obj.windows)
        while samples and samples[0][0] <= horizon:
            samples.popleft()
