"""Cluster health monitor: a per-server state machine over existing signals.

The stack already produces everything a health verdict needs — it just
never reads it in one place. Per server, in modeled time:

* ``sched.RateHistory`` — EWMA transport rates, flap counts, and the
  authoritative quarantine decision (``quarantined(sid)``);
* ``qos.distributed`` shards — grant/denial/decline/borrow counters;
* ``cluster.BufferPool`` — registered-memory residency and evictions
  (cluster-wide pressure: registered memory is a shared resource);
* stream fault/resume and park counts, fed as events through
  ``ClusterCoordinator.notify``.

``HealthMonitor.heartbeat(now_s)`` samples those sources and drives each
server through ``healthy → degraded → suspect → quarantined``:

* **escalation is immediate** — the first heartbeat that sees a worse
  signal jumps straight to the matching state;
* **recovery is hysteretic** — a server must post ``recover_heartbeats``
  consecutive clean heartbeats to step *one* level back down, so a flapping
  signal cannot flap the health state at heartbeat rate;
* **quarantine is mirrored, not re-derived** — while the bound
  ``RateHistory`` quarantines a server the monitor reports ``quarantined``,
  and the heartbeat after the history lifts it the monitor steps it down to
  ``suspect`` (then recovers through hysteresis). The monitor's own
  fault-storm rule (``fault_quarantine`` stream faults inside one heartbeat
  window) is the only other path into ``quarantined``, so in fault-free
  runs the monitor's quarantine verdicts are exactly the history's.

Every transition is a frozen :class:`HealthTransition` (the ``PerfEvent``
discipline from ``obs/events.py``), appended to ``transitions`` and echoed
into an attached ``FlightRecorder``. Like the rest of ``repro.obs`` the
module imports nothing from the layers it watches — every source is bound
duck-typed via :meth:`HealthMonitor.bind`.
"""
from __future__ import annotations

import dataclasses

HEALTHY = "healthy"
DEGRADED = "degraded"
SUSPECT = "suspect"
QUARANTINED = "quarantined"

#: severity order, worst last
STATES = (HEALTHY, DEGRADED, SUSPECT, QUARANTINED)
_LEVEL = {s: i for i, s in enumerate(STATES)}


@dataclasses.dataclass
class HealthConfig:
    """Thresholds for one heartbeat's verdict (all in per-window deltas)."""

    rate_ratio_degraded: float = 2.0   # EWMA worse than fleet median by this
    flaps_suspect: int = 1             # new flap records in the window
    faults_suspect: int = 1            # stream fault-resumes in the window
    fault_quarantine: int = 3          # fault storm: monitor-own quarantine
    denials_degraded: int = 1          # new shard stream/total/memory denials
    declines_degraded: int = 1         # new thief-side steal declines
    pool_pressure_degraded: float = 0.9  # resident/max_bytes fraction
    recover_heartbeats: int = 2        # clean beats per one-level step-down


@dataclasses.dataclass
class ServerHealth:
    """One server's current verdict plus the window counters behind it."""

    server_id: str
    state: str = HEALTHY
    since_s: float = 0.0               # modeled time of the last transition
    clean_streak: int = 0              # consecutive clean heartbeats
    transitions: int = 0
    # window counters (reset every heartbeat)
    window_faults: int = 0
    window_parks: int = 0
    window_declines: int = 0
    # latest sampled signals (for reporting)
    rate_s: float | None = None
    flaps: int = 0
    faults: int = 0
    denials: int = 0
    declines: int = 0
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class HealthTransition:
    """Typed health-state-change event (same discipline as ``PerfEvent``)."""

    kind: str                          # "escalate" | "recover"
    server_id: str
    frm: str
    to: str
    now_s: float
    reason: str = ""
    detail: str = ""

    @property
    def is_escalation(self) -> bool:
        return _LEVEL[self.to] > _LEVEL[self.frm]

    def __str__(self) -> str:
        arrow = "^" if self.is_escalation else "v"
        why = f" ({self.reason})" if self.reason else ""
        return (f"[health:{self.kind}] {self.server_id} {self.frm} -> "
                f"{self.to} {arrow} at {self.now_s * 1e3:.3f}ms{why}")


class HealthMonitor:
    """Heartbeat-driven per-server health, sourced from bound subsystems."""

    def __init__(self, config: HealthConfig | None = None,
                 recorder=None) -> None:
        self.config = config or HealthConfig()
        self.recorder = recorder
        self.servers: dict[str, ServerHealth] = {}
        self.transitions: list[HealthTransition] = []
        self.heartbeats = 0
        self.pool_pressure = 0.0       # latest resident/max_bytes fraction
        # sources (all optional, duck-typed)
        self._history = None           # sched.RateHistory
        self._admission = None         # qos.distributed.ShardedAdmission
        self._pool = None              # cluster.BufferPool
        # last-seen cumulative counters, for per-window deltas
        self._seen_flaps: dict[str, int] = {}
        self._seen_denials: dict[str, int] = {}
        self._seen_evictions = 0

    def bind(self, history=None, admission=None, pool=None) -> "HealthMonitor":
        """Attach signal sources; returns self for chaining. Only the
        sources passed are (re)bound."""
        if history is not None:
            self._history = history
        if admission is not None:
            self._admission = admission
        if pool is not None:
            self._pool = pool
        return self

    # -- event feed (via ClusterCoordinator.notify) -----------------------

    def observe_event(self, kind: str, server_id: str | None,
                      now_s: float) -> None:
        """Count per-window occurrences of the event kinds health cares
        about. Unknown kinds are ignored — the recorder keeps them."""
        if not server_id:
            return
        h = self._server(server_id)
        if kind in ("stream.fault", "stream.resume", "stream.migrate"):
            # a migration is attributed to the server the lease *left* —
            # the strongest per-window evidence that server is gone
            h.window_faults += 1
            h.faults += 1
        elif kind in ("stream.park", "scan.park"):
            h.window_parks += 1
        elif kind == "steal.decline":
            h.window_declines += 1
            h.declines += 1

    # -- heartbeat --------------------------------------------------------

    def heartbeat(self, now_s: float) -> list[HealthTransition]:
        """Sample every bound source and advance each server's state.
        Returns the transitions this heartbeat produced."""
        self.heartbeats += 1
        self._sample_pool()
        fleet = self._fleet_rates()
        median_rate = _median([r for r in fleet.values() if r is not None])
        fired: list[HealthTransition] = []

        for sid in self._known_servers():
            h = self._server(sid)
            h.rate_s = fleet.get(sid)
            target, reason = self._verdict(h, median_rate)
            fired.extend(self._advance(h, target, reason, now_s))
            # close the window
            h.window_faults = 0
            h.window_parks = 0
            h.window_declines = 0
        return fired

    def _verdict(self, h: ServerHealth, median_rate: float | None):
        """(worst deserved state, reason) from this window's signals."""
        cfg = self.config
        sid = h.server_id
        if self._history is not None and self._history.quarantined(sid):
            return QUARANTINED, "rate-history quarantine"
        if h.window_faults >= cfg.fault_quarantine:
            return QUARANTINED, f"fault storm ({h.window_faults}/window)"

        flaps_delta = 0
        if self._history is not None:
            rec = self._history.servers.get(sid)
            flaps = rec.flaps if rec is not None else 0
            flaps_delta = flaps - self._seen_flaps.get(sid, 0)
            self._seen_flaps[sid] = flaps
            h.flaps = flaps
        if flaps_delta >= cfg.flaps_suspect:
            return SUSPECT, f"{flaps_delta} new flap(s)"
        if h.window_faults >= cfg.faults_suspect:
            return SUSPECT, f"{h.window_faults} stream fault(s)"

        denials_delta = self._denials_delta(sid, h)
        if denials_delta >= cfg.denials_degraded:
            return DEGRADED, f"{denials_delta} admission denial(s)"
        if h.window_declines >= cfg.declines_degraded:
            return DEGRADED, f"{h.window_declines} steal decline(s)"
        if (h.rate_s is not None and median_rate is not None
                and median_rate > 0.0
                and h.rate_s > cfg.rate_ratio_degraded * median_rate):
            return DEGRADED, (f"rate {h.rate_s * 1e6:.0f}us/batch > "
                              f"{cfg.rate_ratio_degraded:g}x fleet median")
        if self.pool_pressure > cfg.pool_pressure_degraded:
            return DEGRADED, (f"pool pressure "
                              f"{self.pool_pressure:.2f} resident/budget")
        return HEALTHY, ""

    def _advance(self, h: ServerHealth, target: str, reason: str,
                 now_s: float) -> list[HealthTransition]:
        cur, tgt = _LEVEL[h.state], _LEVEL[target]
        if tgt > cur:
            h.clean_streak = 0
            return [self._transition(h, target, reason, now_s, "escalate")]
        if tgt == cur:
            h.clean_streak = 0
            if reason:
                h.reason = reason
            return []
        # target is better than current: recover
        if h.state == QUARANTINED:
            # quarantine mirrors the source; the beat it lifts, drop to
            # suspect immediately (an ex-quarantined server is not trusted
            # yet) and let hysteresis take it the rest of the way down.
            h.clean_streak = 0
            down = STATES[max(tgt, _LEVEL[SUSPECT])]
            return [self._transition(h, down, "quarantine lifted", now_s,
                                     "recover")]
        h.clean_streak += 1
        if h.clean_streak < self.config.recover_heartbeats:
            return []
        h.clean_streak = 0
        down = STATES[cur - 1]
        return [self._transition(
            h, down, f"{self.config.recover_heartbeats} clean heartbeats",
            now_s, "recover")]

    def _transition(self, h: ServerHealth, to: str, reason: str,
                    now_s: float, kind: str) -> HealthTransition:
        tr = HealthTransition(kind=kind, server_id=h.server_id, frm=h.state,
                              to=to, now_s=now_s, reason=reason)
        h.state = to
        h.since_s = now_s
        h.reason = reason
        h.transitions += 1
        self.transitions.append(tr)
        if self.recorder is not None:
            self.recorder.record("health." + kind, now_s=now_s,
                                 server_id=h.server_id, frm=tr.frm, to=to,
                                 reason=reason)
        return tr

    # -- signal sampling --------------------------------------------------

    def _sample_pool(self) -> None:
        pool = self._pool
        if pool is None:
            return
        max_bytes = getattr(pool, "max_bytes", None)
        resident = getattr(getattr(pool, "stats", None), "bytes_resident", 0)
        self.pool_pressure = (resident / max_bytes
                              if max_bytes else 0.0)

    def _fleet_rates(self) -> dict[str, float | None]:
        if self._history is None:
            return {}
        return {sid: rec.rate_s
                for sid, rec in self._history.servers.items()}

    def _denials_delta(self, sid: str, h: ServerHealth) -> int:
        if self._admission is None:
            return 0
        shard = getattr(self._admission, "shards", {}).get(sid)
        if shard is None:
            return 0
        s = shard.stats
        total = (getattr(s, "stream_denials", 0)
                 + getattr(s, "total_denials", 0)
                 + getattr(s, "memory_denials", 0))
        delta = total - self._seen_denials.get(sid, 0)
        self._seen_denials[sid] = total
        h.denials = total
        return delta

    def _known_servers(self) -> list[str]:
        ids = set(self.servers)
        if self._history is not None:
            ids.update(self._history.servers)
        if self._admission is not None:
            ids.update(getattr(self._admission, "shards", {}))
        return sorted(ids)

    def _server(self, server_id: str) -> ServerHealth:
        if server_id not in self.servers:
            self.servers[server_id] = ServerHealth(server_id=server_id)
        return self.servers[server_id]

    # -- read side --------------------------------------------------------

    def state(self, server_id: str) -> str:
        h = self.servers.get(server_id)
        return h.state if h is not None else HEALTHY

    def states(self) -> dict[str, str]:
        return {sid: h.state for sid, h in sorted(self.servers.items())}

    def snapshot(self) -> dict:
        """Plain-data view for postmortems and ``report.health_table``."""
        return {
            "heartbeats": self.heartbeats,
            "pool_pressure": self.pool_pressure,
            "servers": {
                sid: {
                    "state": h.state,
                    "since_s": h.since_s,
                    "reason": h.reason,
                    "rate_us_per_batch": (h.rate_s * 1e6
                                          if h.rate_s is not None else None),
                    "flaps": h.flaps,
                    "faults": h.faults,
                    "denials": h.denials,
                    "declines": h.declines,
                    "transitions": h.transitions,
                }
                for sid, h in sorted(self.servers.items())
            },
        }


def _median(vals: list[float]) -> float | None:
    if not vals:
        return None
    vs = sorted(vals)
    mid = len(vs) // 2
    if len(vs) % 2:
        return vs[mid]
    return 0.5 * (vs[mid - 1] + vs[mid])
