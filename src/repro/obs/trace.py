"""Distributed scan tracing in modeled time.

A :class:`TraceContext` is created at ``ScanGateway.submit`` and rides the
scan down through the scheduler, the stream pullers and the coordinator.
Every layer records spans against it — admission wait, WFQ queueing, lease
RPC, RDMA pull, prefetch overlap, steal/decline/re-steal, park/unpark,
reassembly — all on the **modeled** clock (the same deterministic clock the
qos/sched/cluster layers advance), so a trace is exactly reproducible.

Clock domains. Per-stream pullers keep a local ``clock_s`` that starts at 0
and is later *placed* on the scan timeline via ``stats.start_s`` (thieves
spawn mid-scan) and on the gateway timeline via the request's grant clock.
Spans therefore carry a ``group`` label: spans in a group share a shift
(``set_shift``) applied on top of the context-wide ``base_s`` at commit
time; group-``None`` spans are already absolute. ``StreamTrace`` binds a
fresh group + track per stream so layers below never deal with shifts.

Export: :meth:`Tracer.to_chrome` emits Chrome ``trace_event`` JSON
("X" complete + "i" instant events, µs units) loadable in
``chrome://tracing`` / Perfetto; :meth:`Tracer.summary` aggregates per
(category, name) for ``utils.report.trace_table``.
"""
from __future__ import annotations

import dataclasses
import itertools
import typing


@dataclasses.dataclass
class Span:
    """One traced interval (or instant) in modeled seconds.

    ``start_s`` is group-relative until :meth:`TraceContext.commit`
    resolves it onto the scan timeline; ``phase`` follows the Chrome
    trace_event convention ("X" complete, "i" instant).
    """

    track: str                      # tid: which lane the span renders on
    name: str
    cat: str
    start_s: float
    dur_s: float = 0.0
    phase: str = "X"
    args: dict = dataclasses.field(default_factory=dict)
    group: str | None = None        # shift-group; None = already absolute


class StreamTrace:
    """A per-stream view of a :class:`TraceContext`: a bound track and a
    fresh shift-group, so stream-local code records spans on its local
    clock (starting at 0) and placement happens once, at commit."""

    def __init__(self, ctx: "TraceContext", track: str, group: str):
        self.ctx = ctx
        self.track = track
        self.group = group

    def span(self, name: str, start_s: float, dur_s: float, *,
             cat: str = "stream", track_suffix: str = "", **args) -> None:
        self.ctx.span(name, start_s, dur_s, track=self.track + track_suffix,
                      cat=cat, group=self.group, **args)

    def instant(self, name: str, at_s: float, *, cat: str = "stream",
                track_suffix: str = "", **args) -> None:
        self.ctx.instant(name, at_s, track=self.track + track_suffix,
                         cat=cat, group=self.group, **args)


class TraceContext:
    """The trace of one scan: spans collected across layers, plus the
    shift bookkeeping that places per-stream local clocks on the scan
    timeline. Committing is idempotent (shed/failed/multicast paths and
    the normal finalize may race to commit)."""

    def __init__(self, tracer: "Tracer", trace_id: int, name: str):
        self.tracer = tracer
        self.trace_id = trace_id
        self.name = name
        self.base_s = 0.0               # gateway grant clock, set at finalize
        self.spans: list[Span] = []
        self._shifts: dict[str, float] = {}
        self._groups = itertools.count()
        self._committed = False

    # ------------------------------------------------------------ recording
    def span(self, name: str, start_s: float, dur_s: float, *,
             track: str = "scan", cat: str = "scan",
             group: str | None = None, **args) -> None:
        self.spans.append(Span(track, name, cat, start_s, max(dur_s, 0.0),
                               "X", dict(args), group))

    def instant(self, name: str, at_s: float, *, track: str = "scan",
                cat: str = "scan", group: str | None = None, **args) -> None:
        self.spans.append(Span(track, name, cat, at_s, 0.0, "i",
                               dict(args), group))

    def stream(self, track: str) -> StreamTrace:
        """A child view with its own track + shift-group (one per
        stream-puller; thieves get their own at spawn time)."""
        return StreamTrace(self, track, f"g{next(self._groups)}")

    # ---------------------------------------------------------- placement
    def set_shift(self, group: str, offset_s: float) -> None:
        """Place a group's local clock at ``offset_s`` on the scan
        timeline (e.g. a thief stream spawned at its steal epoch)."""
        self._shifts[group] = offset_s

    def resolve_s(self, span: Span) -> float:
        """The span's absolute modeled start time."""
        if span.group is None:
            return span.start_s
        return span.start_s + self.base_s + self._shifts.get(span.group, 0.0)

    # ------------------------------------------------------------- commit
    def commit(self) -> None:
        """Resolve every span onto the scan timeline and hand the trace to
        the tracer. Safe to call more than once; later calls are no-ops."""
        if self._committed:
            return
        self._committed = True
        for span in self.spans:
            span.start_s = self.resolve_s(span)
            span.group = None
        self.tracer._collect(self)


class Tracer:
    """Collects committed scan traces and exports them.

    One ``Tracer`` spans many scans (attach it to a ``ScanGateway``); each
    scan becomes one Chrome *process* (pid = trace_id) with per-stream
    *threads*, so concurrent scans render as parallel process groups.
    """

    def __init__(self):
        self._ids = itertools.count(1)
        self.contexts: list[TraceContext] = []

    def begin(self, name: str) -> TraceContext:
        return TraceContext(self, next(self._ids), name)

    def _collect(self, ctx: TraceContext) -> None:
        self.contexts.append(ctx)

    # -------------------------------------------------------------- export
    def spans(self) -> typing.Iterator[tuple[TraceContext, Span]]:
        for ctx in self.contexts:
            for span in ctx.spans:
                yield ctx, span

    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON (the ``traceEvents`` array form),
        timestamps in microseconds of modeled time."""
        events: list[dict] = []
        for ctx in self.contexts:
            events.append({"ph": "M", "name": "process_name",
                           "pid": ctx.trace_id, "tid": 0,
                           "args": {"name": ctx.name}})
            tids: dict[str, int] = {}
            for span in ctx.spans:
                tid = tids.get(span.track)
                if tid is None:
                    tid = tids[span.track] = len(tids) + 1
                    events.append({"ph": "M", "name": "thread_name",
                                   "pid": ctx.trace_id, "tid": tid,
                                   "args": {"name": span.track}})
                ev = {"ph": span.phase, "name": span.name, "cat": span.cat,
                      "pid": ctx.trace_id, "tid": tid,
                      "ts": span.start_s * 1e6}
                if span.phase == "X":
                    ev["dur"] = span.dur_s * 1e6
                if span.phase == "i":
                    ev["s"] = "t"       # thread-scoped instant
                if span.args:
                    ev["args"] = span.args
                events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def summary(self) -> dict[tuple[str, str], dict]:
        """Aggregate spans per (category, name): count / total_s / max_s
        for complete spans, count only for instants. Feeds
        ``utils.report.trace_table``."""
        agg: dict[tuple[str, str], dict] = {}
        for _, span in self.spans():
            row = agg.setdefault((span.cat, span.name),
                                 {"count": 0, "total_s": 0.0, "max_s": 0.0})
            row["count"] += 1
            if span.phase == "X":
                row["total_s"] += span.dur_s
                row["max_s"] = max(row["max_s"], span.dur_s)
        return agg
