"""Network fabric: measured copies + modeled wire.

This container has no NIC/InfiniBand, so the *wire* is modeled while every
*memory operation* (serialization pack, per-segment DMA placement) is executed
for real and timed. The model constants come from the paper's hardware class
(InfiniBand, Thallium/Mercury on verbs):

* ``RPC_RTT_S``        — per-RPC round-trip software+fabric latency.
* ``RPC_BW``           — effective RPC *payload* bandwidth. The Mercury RPC
  data path stages payloads through bounce buffers / flow control, so its
  effective large-message throughput is well below line rate.
* ``RDMA_BW``          — RDMA READ throughput (near line rate).
* ``RDMA_SETUP_S``     — per-bulk-op constant (handle exchange + post).
* ``SEG_REGISTER_S``   — per-segment registration/pinning cost. This is the
  constant that makes *small* result sets lose the Thallus advantage, exactly
  the trend in the paper's Figures 2–3.

Every transfer returns a :class:`WireStats` so benchmarks can decompose
duration into serialize / wire / deserialize the way the paper's §2 does.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class FabricConfig:
    rpc_rtt_s: float = 2.0e-6          # 2 us RPC round trip
    rpc_bw: float = 2.2e9              # 2.2 GB/s effective RPC payload path
    rdma_bw: float = 12.0e9            # 12 GB/s RDMA READ (HDR-100 class)
    rdma_setup_s: float = 3.0e-6       # per bulk operation
    seg_register_s: float = 0.4e-6     # per segment registration/pinning
    execute_copies: bool = True        # actually perform DMA placement memcpys


@dataclasses.dataclass
class WireStats:
    """One transfer, decomposed.

    ``measured_copy_s`` is the wall-clock of the host memcpys this simulation
    executes to stand in for the NIC DMA engine — it keeps the data movement
    real (tests check the bytes), but it is NOT part of the transfer time:
    on real hardware the DMA engine does the placement, which is what
    ``modeled_wire_s`` accounts for. Host-CPU costs that are real in the
    actual system (the baseline's serialization pack) are measured and
    charged in TransportStats, not here.
    """

    bytes_moved: int = 0
    num_segments: int = 0
    measured_copy_s: float = 0.0      # diagnostic only
    modeled_wire_s: float = 0.0
    modeled_register_s: float = 0.0   # registration share of modeled_wire_s

    @property
    def total_s(self) -> float:
        return self.modeled_wire_s


class Fabric:
    """An in-process stand-in for the cluster fabric."""

    def __init__(self, config: FabricConfig | None = None):
        self.config = config or FabricConfig()
        self.rpc_count = 0
        self.rdma_count = 0
        self.bytes_over_rpc = 0
        self.bytes_over_rdma = 0
        self.registrations = 0         # segments pinned via register()
        self.modeled_wire_s = 0.0      # cumulative wire time this fabric modeled

    # ------------------------------------------------------------------ RPC
    def rpc(self, payload_bytes: int = 0) -> WireStats:
        """A control-plane RPC carrying ``payload_bytes`` of (meta)data."""
        self.rpc_count += 1
        self.bytes_over_rpc += payload_bytes
        wire = self.config.rpc_rtt_s + payload_bytes / self.config.rpc_bw
        self.modeled_wire_s += wire
        return WireStats(bytes_moved=payload_bytes, num_segments=1,
                         modeled_wire_s=wire)

    # ----------------------------------------------------------- registration
    def register(self, num_segments: int) -> float:
        """Pin ``num_segments`` memory regions up front (a buffer pool filling
        its registration cache). Returns the modeled one-time cost so callers
        can account for it; subsequent ``rdma_pull(..., registered=True)``
        calls skip the per-segment term those pins amortize."""
        self.registrations += num_segments
        return num_segments * self.config.seg_register_s

    def unregister(self, num_segments: int) -> None:
        """Unpin memory regions (pool eviction under a memory budget).
        Deregistration is a local verbs call — no wire time is modeled,
        only the registration census moves."""
        self.registrations -= num_segments

    # ----------------------------------------------------------------- RDMA
    def rdma_pull(self, src: Sequence[np.ndarray],
                  dst: Sequence[np.ndarray],
                  registered: bool = False) -> WireStats:
        """Scatter-gather RDMA READ: each remote segment lands in the matching
        local segment, one-to-one. The placement memcpy is executed for real
        (it stands in for the DMA engine write into client memory); the wire
        time is modeled at RDMA bandwidth + per-segment registration.

        ``registered=True`` is the registration-cache fast path: the local
        segments came from a pre-registered pool (and the remote table memory
        is pinned server-side), so the per-segment registration term — the
        constant that erodes the small-batch advantage — is not charged."""
        if len(src) != len(dst):
            raise ValueError("segment count mismatch")
        nbytes = 0
        t0 = time.perf_counter()
        if self.config.execute_copies:
            for s, d in zip(src, dst):
                if s.nbytes != d.nbytes:
                    raise ValueError(
                        f"segment size mismatch: {s.nbytes} != {d.nbytes}")
                if s.nbytes:
                    d.view(np.uint8).reshape(-1)[:] = s.view(np.uint8).reshape(-1)
                nbytes += s.nbytes
        else:
            nbytes = sum(int(s.nbytes) for s in src)
        copy_s = time.perf_counter() - t0
        self.rdma_count += 1
        self.bytes_over_rdma += nbytes
        register_s = 0.0 if registered else len(src) * self.config.seg_register_s
        wire = (self.config.rdma_setup_s
                + register_s
                + nbytes / self.config.rdma_bw)
        self.modeled_wire_s += wire
        return WireStats(bytes_moved=nbytes, num_segments=len(src),
                         measured_copy_s=copy_s, modeled_wire_s=wire,
                         modeled_register_s=register_s)

    # ------------------------------------------------------------ RPC bulk
    def rpc_payload(self, wire_buffer: np.ndarray) -> WireStats:
        """Data-over-RPC (the baseline): the contiguous serialized buffer is
        the RPC response payload. One message, RPC-path bandwidth."""
        self.rpc_count += 1
        self.bytes_over_rpc += wire_buffer.nbytes
        wire = self.config.rpc_rtt_s + wire_buffer.nbytes / self.config.rpc_bw
        self.modeled_wire_s += wire
        return WireStats(bytes_moved=int(wire_buffer.nbytes), num_segments=1,
                         modeled_wire_s=wire)

    def reset_counters(self) -> None:
        self.rpc_count = self.rdma_count = 0
        self.bytes_over_rpc = self.bytes_over_rdma = 0
        self.registrations = 0
        self.modeled_wire_s = 0.0


class FlappingFabric(Fabric):
    """A fabric whose RDMA link speed follows a per-pull slowdown schedule.

    The chaos/bench harness for time-varying replicas: each ``rdma_pull``
    consumes the next factor from ``schedule`` (cycling once exhausted) and
    models the wire at ``base_bw / factor`` for that pull only — a schedule
    of ``[4, 1]`` is a link oscillating 4×-slow ↔ full-speed every pull, a
    ramp ``[1, 2, 4, 8]`` is a degrading thief. Only the modeled RDMA data
    path flaps (the signal the steal scheduler's rate history watches);
    control RPCs stay at the base config. Swap ``schedule`` between scans to
    model persistent degradation (the repeat-straggler case)."""

    def __init__(self, config: FabricConfig | None = None,
                 schedule: Sequence[float] = (1.0,)):
        super().__init__(config)
        if not schedule or any(f <= 0 for f in schedule):
            raise ValueError("schedule must be non-empty positive factors")
        self.schedule = list(schedule)
        self.pulls = 0

    def rdma_pull(self, src: Sequence[np.ndarray],
                  dst: Sequence[np.ndarray],
                  registered: bool = False) -> WireStats:
        base = self.config
        factor = self.schedule[self.pulls % len(self.schedule)]
        self.pulls += 1
        if factor != 1.0:
            self.config = dataclasses.replace(base,
                                              rdma_bw=base.rdma_bw / factor)
        try:
            return super().rdma_pull(src, dst, registered=registered)
        finally:
            self.config = base
