"""Thallus core: zero-copy columnar transport (the paper's contribution)."""
from .schema import Field, Schema, schema  # noqa: F401
from .recordbatch import (  # noqa: F401
    Column, RecordBatch, batch_from_arrays, batch_from_pydict,
    column_from_pylist, concat_batches, pack_validity, unpack_validity,
)
from .bulk import (  # noqa: F401
    BulkHandle, SegmentDesc, allocate_like, assemble_batch, expose_batch,
    size_vectors,
)
from .serialize import pack, unpack, serialized_size  # noqa: F401
from .fabric import (  # noqa: F401
    Fabric, FabricConfig, FlappingFabric, WireStats,
)
from .transport import (  # noqa: F401
    RpcTransport, ThallusTransport, Transport, TransportStats, make_transport,
    rdma_pull_batch,
)
from .protocol import (  # noqa: F401
    QueryEngine, RecordBatchReader, RpcClient, ScanHandle, ServerCrashedError,
    ThallusClient, ThallusServer,
)
