"""Baseline serialization: the cost Thallus deletes.

TCP/IP-based transports need **one contiguous buffer**, so the baseline path
must copy every column buffer into a staging area ("numerous memory copies")
— the paper measures this at ~30 % of the whole RPC duration. Deserialization
on the receiver is ~free because Arrow reconstructs columns as *views* into
the received buffer.

Wire format (little-endian):

    [u64 header_len][header json utf-8][padding to 8][buffer 0][pad8][buffer 1]...

The header carries schema, num_rows, and per-buffer (dtype, nbytes) — i.e.
exactly the metadata a :class:`~repro.core.bulk.BulkHandle` would carry, but
here it is *in-band* with the data.
"""
from __future__ import annotations

import json

import numpy as np

from .bulk import _KINDS  # noqa: F401  (shared buffer-order convention)
from .recordbatch import Column, RecordBatch
from .schema import Schema

_ALIGN = 8
_EMPTY_U8 = np.zeros(0, dtype=np.uint8)


def _pad(n: int) -> int:
    return (-n) % _ALIGN


def _batch_buffers(batch: RecordBatch) -> list[np.ndarray]:
    bufs: list[np.ndarray] = []
    for col in batch.columns:
        bufs.append(col.values)
        bufs.append(col.offsets if col.offsets is not None else _EMPTY_U8)
        bufs.append(col.validity if col.validity is not None else _EMPTY_U8)
    return bufs


def serialized_size(batch: RecordBatch) -> int:
    header = _header_bytes(batch)
    n = 8 + len(header) + _pad(len(header))
    for buf in _batch_buffers(batch):
        n += buf.nbytes + _pad(buf.nbytes)
    return n


def _header_bytes(batch: RecordBatch) -> bytes:
    bufs = _batch_buffers(batch)
    header = {
        "schema": batch.schema.to_dict(),
        "num_rows": batch.num_rows,
        "buffers": [{"dtype": str(b.dtype), "nbytes": int(b.nbytes)} for b in bufs],
    }
    return json.dumps(header).encode("utf-8")


def pack(batch: RecordBatch) -> np.ndarray:
    """Serialize into ONE contiguous uint8 buffer. This performs a full copy
    of every column buffer — the serialization overhead under study."""
    header = _header_bytes(batch)
    bufs = _batch_buffers(batch)
    out = np.empty(serialized_size(batch), dtype=np.uint8)
    pos = 0
    out[pos : pos + 8] = np.frombuffer(np.uint64(len(header)).tobytes(), np.uint8)
    pos += 8
    out[pos : pos + len(header)] = np.frombuffer(header, np.uint8)
    pos += len(header) + _pad(len(header))
    for buf in bufs:
        raw = buf.view(np.uint8).reshape(-1) if buf.nbytes else _EMPTY_U8
        out[pos : pos + raw.nbytes] = raw      # <-- the memcpy being deleted
        pos += raw.nbytes + _pad(raw.nbytes)
    return out


def unpack(wire: np.ndarray, zero_copy: bool = True) -> RecordBatch:
    """Deserialize. With ``zero_copy=True`` (Arrow semantics) every column is
    a *view* into ``wire`` — this is the ~0.0004 %-of-duration operation the
    paper measures."""
    wire = wire.view(np.uint8)
    hlen = int(np.frombuffer(wire[:8].tobytes(), np.uint64)[0])
    pos = 8
    header = json.loads(wire[pos : pos + hlen].tobytes().decode("utf-8"))
    pos += hlen + _pad(hlen)
    schema = Schema.from_dict(header["schema"])
    segments: list[np.ndarray] = []
    for meta in header["buffers"]:
        nbytes = meta["nbytes"]
        raw = wire[pos : pos + nbytes]
        if not zero_copy:
            raw = raw.copy()
        segments.append(raw.view(np.dtype(meta["dtype"])))
        pos += nbytes + _pad(nbytes)
    cols = []
    it = iter(segments)
    for field in schema:
        values, offsets, validity = next(it), next(it), next(it)
        cols.append(Column(
            field,
            values,
            offsets=offsets if field.varlen else None,
            validity=validity if validity.nbytes else None,
        ))
    return RecordBatch(schema, tuple(cols))
