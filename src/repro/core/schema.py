"""Arrow-like schema model.

A :class:`Schema` is an ordered list of :class:`Field`\\ s. Types cover the
fixed-width numerics plus variable-length ``utf8``/``binary`` (which carry an
int32 offsets buffer, exactly like Arrow's layout). This is the metadata that
rides the *control plane* in Thallus — it is tiny and is shipped via RPC,
never via the bulk data path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

# Fixed-width value types -> numpy dtype.
_FIXED: dict[str, np.dtype] = {
    "int8": np.dtype(np.int8),
    "int16": np.dtype(np.int16),
    "int32": np.dtype(np.int32),
    "int64": np.dtype(np.int64),
    "uint8": np.dtype(np.uint8),
    "uint16": np.dtype(np.uint16),
    "uint32": np.dtype(np.uint32),
    "uint64": np.dtype(np.uint64),
    "float16": np.dtype(np.float16),
    "float32": np.dtype(np.float32),
    "float64": np.dtype(np.float64),
    "bool": np.dtype(np.bool_),
}
_VARLEN = ("utf8", "binary")


def is_varlen(type_name: str) -> bool:
    return type_name in _VARLEN


def numpy_dtype(type_name: str) -> np.dtype:
    """numpy dtype of the *values* buffer for a type."""
    if type_name in _FIXED:
        return _FIXED[type_name]
    if type_name in _VARLEN:
        return np.dtype(np.uint8)  # raw bytes
    raise ValueError(f"unknown type: {type_name!r}")


def valid_types() -> tuple[str, ...]:
    return tuple(_FIXED) + _VARLEN


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    type: str
    nullable: bool = True

    def __post_init__(self) -> None:
        if self.type not in _FIXED and self.type not in _VARLEN:
            raise ValueError(f"unknown field type: {self.type!r}")

    @property
    def varlen(self) -> bool:
        return is_varlen(self.type)

    @property
    def value_dtype(self) -> np.dtype:
        return numpy_dtype(self.type)

    def to_dict(self) -> dict:
        return {"name": self.name, "type": self.type, "nullable": self.nullable}

    @staticmethod
    def from_dict(d: dict) -> "Field":
        return Field(d["name"], d["type"], d.get("nullable", True))


@dataclasses.dataclass(frozen=True)
class Schema:
    fields: tuple[Field, ...]

    def __post_init__(self) -> None:
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names: {names}")

    def __len__(self) -> int:
        return len(self.fields)

    def __iter__(self) -> Iterator[Field]:
        return iter(self.fields)

    def __getitem__(self, key: int | str) -> Field:
        if isinstance(key, int):
            return self.fields[key]
        for f in self.fields:
            if f.name == key:
                return f
        raise KeyError(key)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(f.name for f in self.fields)

    def index(self, name: str) -> int:
        for i, f in enumerate(self.fields):
            if f.name == name:
                return i
        raise KeyError(name)

    def select(self, names: Sequence[str]) -> "Schema":
        return Schema(tuple(self[n] for n in names))

    def to_dict(self) -> dict:
        return {"fields": [f.to_dict() for f in self.fields]}

    @staticmethod
    def from_dict(d: dict) -> "Schema":
        return Schema(tuple(Field.from_dict(f) for f in d["fields"]))


def schema(*pairs: tuple[str, str]) -> Schema:
    """Convenience: ``schema(("a","int64"), ("b","utf8"))``."""
    return Schema(tuple(Field(n, t) for n, t in pairs))
