"""The Thallus client/server protocol state machine.

Mirrors the paper §3 exactly:

* ``init_scan(query, dataset)`` → server instantiates an engine session,
  wraps its cursor in a ``RecordBatchReader``, stores it in the **reader
  map** under a fresh UUID, returns ``(uuid, schema)``.
* ``iterate(uuid)`` → server walks the reader; for every batch it *exposes*
  the buffers and invokes the client's ``do_rdma`` callback with
  ``(num_rows, size_vectors, bulk_handle)``.
* client ``do_rdma`` → allocates a matching write-only local bulk, RDMA-pulls
  the remote bulk one-to-one, assembles an Arrow batch from views, hands it
  to the client's output sink.
* ``finalize(uuid)`` → frees buffers / evicts the reader-map entry.

Fault-tolerance extensions beyond the paper (needed at cluster scale):

* readers are *resumable*: ``init_scan(..., start_batch=k)`` fast-forwards a
  restarted client to where it died (positions are tracked in the reader
  map);
* ``iterate`` takes ``max_batches`` so a client can pull in bounded leases —
  a lease that is never finalized is reclaimable;
* multiple servers can serve the same dataset; the client-side
  :class:`repro.data.loader.ThallusLoader` issues backup requests to the
  first-ready replica (straggler mitigation), and :mod:`repro.cluster`
  builds partitioned multi-stream scans out of these resumable leases.
"""
from __future__ import annotations

import dataclasses
import time
import uuid as _uuid
from typing import Callable, Iterator, Protocol, Sequence

import numpy as np

from . import bulk as bulk_mod
from .fabric import Fabric
from .recordbatch import RecordBatch
from .schema import Schema
from .transport import TransportStats


class ServerCrashedError(ConnectionError):
    """The server process died mid-conversation — every in-flight lease on
    it is gone and the client must fail over to a replica (or give up).
    Subclasses ``ConnectionError`` so generic fault-handling loops that
    already catch connection trouble treat a crash the same way."""


class RecordBatchReader(Protocol):
    """Streaming access to result batches (Arrow's reader interface)."""

    schema: Schema

    def read_next(self) -> RecordBatch | None: ...


class QueryEngine(Protocol):
    """Anything that can turn (sql, dataset) into a RecordBatchReader —
    DuckDB in the paper, :mod:`repro.engine` here, Polars/Velox in spirit."""

    def execute(self, sql: str, dataset: str) -> RecordBatchReader: ...


@dataclasses.dataclass
class _ReaderEntry:
    reader: RecordBatchReader
    schema: Schema
    batches_sent: int = 0
    created_at: float = 0.0
    last_activity: float = 0.0
    finalized: bool = False

    def touch(self, now: float) -> None:
        self.last_activity = now


@dataclasses.dataclass
class ScanHandle:
    """What init_scan returns to the client (control-plane payload)."""

    uuid: str
    schema: Schema


class ThallusServer:
    """Server half: owns the engine and the reader map.

    ``clock`` is the lease-staleness timebase: a zero-arg callable returning
    seconds. Plain deployments leave it ``None`` and get ``time.monotonic``
    (wall clock); modeled-time stacks (QoS/sched/obs layers) plumb their
    modeled timeline in so :meth:`reclaim_stale` judges staleness on the
    same clock everything else runs on.
    """

    def __init__(self, engine: QueryEngine, fabric: Fabric | None = None,
                 clock: Callable[[], float] | None = None):
        self.engine = engine
        self.fabric = fabric or Fabric()
        self.clock = clock
        self.reader_map: dict[str, _ReaderEntry] = {}
        self._crashed = False
        self._crash_after: int | None = None

    def _now(self) -> float:
        return self.clock() if self.clock is not None else time.monotonic()

    # ----------------------------------------------------- crash semantics
    @property
    def crashed(self) -> bool:
        return self._crashed

    def crash(self, after_batches: int = 0) -> None:
        """Kill the server process (nemesis hook).

        ``after_batches=0`` dies immediately; ``after_batches=n`` dies after
        shipping ``n`` more batches across all leases — mid-``iterate``, the
        realistic failure a lease-migration path must survive. Either way
        the reader map is wiped: leases do not survive a process death."""
        if after_batches <= 0:
            self._die()
        else:
            self._crash_after = after_batches

    def restore(self) -> None:
        """Bring the process back up (empty reader map — leases are gone)."""
        self._crashed = False
        self._crash_after = None

    def _die(self) -> None:
        self._crashed = True
        self._crash_after = None
        self.reader_map.clear()

    def _check_alive(self) -> None:
        if self._crashed:
            raise ServerCrashedError("server is down")

    # ------------------------------------------------------------ init_scan
    def init_scan(self, sql: str, dataset: str, start_batch: int = 0) -> ScanHandle:
        self._check_alive()
        reader = self.engine.execute(sql, dataset)
        uid = str(_uuid.uuid4())
        now = self._now()
        entry = _ReaderEntry(reader=reader, schema=reader.schema,
                             created_at=now, last_activity=now)
        # resumability: fast-forward a restarted client
        for _ in range(start_batch):
            if reader.read_next() is None:
                break
            entry.batches_sent += 1
        self.reader_map[uid] = entry
        self.fabric.rpc(len(sql) + len(dataset) + 64)
        return ScanHandle(uid, entry.schema)

    # -------------------------------------------------------------- iterate
    def iterate(self, uid: str,
                do_rdma: Callable[[int, tuple[list[int], list[int], list[int]],
                                   bulk_mod.BulkHandle], TransportStats],
                max_batches: int | None = None) -> int:
        """Walk the reader; for each batch expose a read-only bulk and invoke
        the client's do_rdma. Returns number of batches shipped."""
        self._check_alive()
        entry = self._entry(uid)
        entry.touch(self._now())
        shipped = 0
        while max_batches is None or shipped < max_batches:
            batch = entry.reader.read_next()
            if batch is None:
                break
            handle = bulk_mod.expose_batch(batch, mode="read_only")
            sizes = bulk_mod.size_vectors(batch)
            self.fabric.rpc(64 + 8 * sum(len(v) for v in sizes))  # control msg
            do_rdma(batch.num_rows, sizes, handle)
            entry.batches_sent += 1
            entry.touch(self._now())
            shipped += 1
            if self._crash_after is not None:
                self._crash_after -= 1
                if self._crash_after <= 0:
                    self._die()
                    raise ServerCrashedError(
                        f"server died mid-iterate after shipping {shipped} "
                        "batch(es) of this lease")
        return shipped

    # ----------------------------------------------------------- next_batch
    def next_batch(self, uid: str) -> RecordBatch | None:
        """Public single-batch cursor advance (the ``iterate`` equivalent for
        clients that ship data some other way, e.g. the RPC baseline). Keeps
        the reader-map bookkeeping — cursor position, lease activity — in one
        place instead of clients reaching into server internals."""
        self._check_alive()
        entry = self._entry(uid)
        entry.touch(self._now())
        batch = entry.reader.read_next()
        if batch is not None:
            entry.batches_sent += 1
        return batch

    # ------------------------------------------------------------- finalize
    def finalize(self, uid: str) -> None:
        entry = self._entry(uid)
        entry.finalized = True
        del self.reader_map[uid]
        self.fabric.rpc(64)

    # ------------------------------------------------------------ utilities
    def _entry(self, uid: str) -> _ReaderEntry:
        if uid not in self.reader_map:
            raise KeyError(f"unknown reader uuid {uid!r} (finalized or bogus)")
        return self.reader_map[uid]

    def cursor_position(self, uid: str) -> int:
        """For checkpointing the data pipeline: batches already sent."""
        return self._entry(uid).batches_sent

    def reclaim_stale(self, older_than_s: float,
                      now_s: float | None = None) -> int:
        """Evict leases whose client died without finalize (fault tolerance).

        Staleness is judged by ``last_activity`` — refreshed on every
        ``iterate``/``next_batch`` — not ``created_at``, so a long-running
        but actively-pulling scan is never evicted out from under its client.

        ``now_s`` overrides the sweep's notion of *now* for one call;
        otherwise the server's ``clock`` (modeled timeline when plumbed,
        wall clock by default) supplies it. Passing modeled time matters:
        a whole modeled scan elapses in sub-ms of wall time, so a
        wall-clock sweep can never reclaim a modeled dead lease."""
        now = self._now() if now_s is None else now_s
        stale = [u for u, e in self.reader_map.items()
                 if now - e.last_activity > older_than_s]
        for u in stale:
            del self.reader_map[u]
        return len(stale)


class ThallusClient:
    """Client half: drives the scan and pulls batches via RDMA."""

    def __init__(self, server: ThallusServer, fabric: Fabric | None = None,
                 sink: Callable[[RecordBatch], None] | None = None):
        self.server = server
        self.fabric = fabric or server.fabric
        self.sink = sink
        self.batches: list[RecordBatch] = []
        self.stats: list[TransportStats] = []
        self._schema: Schema | None = None

    # ------------------------------------------------------------- do_rdma
    def do_rdma(self, num_rows: int,
                sizes: tuple[list[int], list[int], list[int]],
                remote: bulk_mod.BulkHandle) -> TransportStats:
        from .transport import rdma_pull_batch  # shared client data plane

        batch, _, stats = rdma_pull_batch(self.fabric, self._schema,
                                          num_rows, remote)
        self.batches.append(batch)
        self.stats.append(stats)
        if self.sink is not None:
            self.sink(batch)
        return stats

    # ------------------------------------------------------------ full run
    def run_query(self, sql: str, dataset: str, start_batch: int = 0,
                  max_batches: int | None = None) -> list[RecordBatch]:
        """init_scan → iterate(→do_rdma per batch) → finalize.

        ``start_batch``/``max_batches`` bound the scan to a batch range —
        a backup request for one batch pulls exactly one batch."""
        handle = self.server.init_scan(sql, dataset, start_batch=start_batch)
        self._schema = handle.schema
        self.server.iterate(handle.uuid, self.do_rdma,
                            max_batches=max_batches)
        self.server.finalize(handle.uuid)
        return self.batches

    def transport_seconds(self) -> float:
        return sum(s.total_s for s in self.stats)


class RpcClient:
    """Baseline client: identical protocol shape, but every batch rides an
    RPC payload after full serialization (see §2 of the paper)."""

    def __init__(self, server: ThallusServer, fabric: Fabric | None = None,
                 sink: Callable[[RecordBatch], None] | None = None):
        self.server = server
        self.fabric = fabric or server.fabric
        self.sink = sink
        self.batches: list[RecordBatch] = []
        self.stats: list[TransportStats] = []

    def run_query(self, sql: str, dataset: str, start_batch: int = 0,
                  max_batches: int | None = None) -> list[RecordBatch]:
        from . import serialize  # local import to keep module edges clean

        handle = self.server.init_scan(sql, dataset, start_batch=start_batch)
        pulled = 0
        while (max_batches is None or pulled < max_batches) and \
                (batch := self.server.next_batch(handle.uuid)) is not None:
            pulled += 1
            stats = TransportStats(control_rpcs=1)
            t0 = time.perf_counter()
            wire_buf = serialize.pack(batch)               # staging copy
            stats.serialize_s = time.perf_counter() - t0
            stats.wire = self.fabric.rpc_payload(wire_buf)
            t0 = time.perf_counter()
            out = serialize.unpack(wire_buf, zero_copy=True)
            stats.deserialize_s = time.perf_counter() - t0
            self.batches.append(out)
            self.stats.append(stats)
            if self.sink is not None:
                self.sink(out)
        self.server.finalize(handle.uuid)
        return self.batches

    def transport_seconds(self) -> float:
        return sum(s.total_s for s in self.stats)
