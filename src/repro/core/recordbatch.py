"""Arrow-like columnar record batches.

The memory layout mirrors Apache Arrow:

* every column owns up to three buffers — **values**, **offsets** (int32,
  var-length types only) and **validity** (LSB-packed bitmap, 1 bit/row,
  ``None`` when the column has no nulls);
* a :class:`RecordBatch` is a schema + a tuple of columns sharing a row count.

Buffers are plain ``np.ndarray``\\ s so that "zero-copy" is a checkable
property: functions in this package either return *views* (``arr.base is not
None``) or fresh copies, and the tests assert which one.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from .schema import Field, Schema, is_varlen, numpy_dtype

# ---------------------------------------------------------------------------
# validity bitmaps (Arrow LSB bit order)
# ---------------------------------------------------------------------------


def pack_validity(mask: np.ndarray) -> np.ndarray:
    """bool[n] -> LSB-packed uint8[ceil(n/8)] (Arrow bit order)."""
    mask = np.asarray(mask, dtype=np.bool_)
    return np.packbits(mask, bitorder="little")


def unpack_validity(bitmap: np.ndarray, num_rows: int) -> np.ndarray:
    """LSB-packed uint8 -> bool[num_rows]."""
    bits = np.unpackbits(np.asarray(bitmap, dtype=np.uint8), bitorder="little")
    return bits[:num_rows].astype(np.bool_)


# ---------------------------------------------------------------------------
# columns
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Column:
    """One Arrow-layout column.

    values:   fixed-width -> dtype[num_rows]; varlen -> uint8[total_bytes]
    offsets:  varlen only -> int32[num_rows + 1], offsets[0] == 0
    validity: uint8[ceil(num_rows/8)] LSB bitmap, or None (all valid)
    """

    field: Field
    values: np.ndarray
    offsets: np.ndarray | None = None
    validity: np.ndarray | None = None

    def __post_init__(self) -> None:
        if self.field.varlen:
            if self.offsets is None:
                raise ValueError(f"varlen column {self.field.name!r} needs offsets")
            if self.offsets.dtype != np.int32:
                self.offsets = self.offsets.astype(np.int32)
        elif self.offsets is not None:
            raise ValueError(f"fixed column {self.field.name!r} must not have offsets")

    @property
    def num_rows(self) -> int:
        if self.field.varlen:
            return int(len(self.offsets) - 1)
        return int(len(self.values))

    @property
    def nbytes(self) -> int:
        n = self.values.nbytes
        if self.offsets is not None:
            n += self.offsets.nbytes
        if self.validity is not None:
            n += self.validity.nbytes
        return n

    def valid_mask(self) -> np.ndarray:
        if self.validity is None:
            return np.ones(self.num_rows, dtype=np.bool_)
        return unpack_validity(self.validity, self.num_rows)

    def null_count(self) -> int:
        return int(self.num_rows - self.valid_mask().sum())

    # -- python-value access (slow path; engine uses buffers directly) ----
    def to_pylist(self) -> list:
        mask = self.valid_mask()
        out: list = []
        if self.field.varlen:
            raw = self.values.tobytes()
            for i in range(self.num_rows):
                if not mask[i]:
                    out.append(None)
                    continue
                lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
                b = raw[lo:hi]
                out.append(b.decode("utf-8") if self.field.type == "utf8" else b)
        else:
            for i in range(self.num_rows):
                out.append(self.values[i].item() if mask[i] else None)
        return out

    def take(self, indices: np.ndarray) -> "Column":
        """Gather rows by index (copies — this is the kernel hot spot)."""
        indices = np.asarray(indices, dtype=np.int64)
        mask = self.valid_mask()[indices]
        validity = pack_validity(mask) if not mask.all() else None
        if not self.field.varlen:
            return Column(self.field, self.values[indices], validity=validity)
        lens = (self.offsets[1:] - self.offsets[:-1])[indices]
        new_off = np.zeros(len(indices) + 1, dtype=np.int32)
        np.cumsum(lens, out=new_off[1:])
        new_vals = np.empty(int(new_off[-1]), dtype=np.uint8)
        for j, i in enumerate(indices):
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            new_vals[new_off[j] : new_off[j + 1]] = self.values[lo:hi]
        return Column(self.field, new_vals, offsets=new_off, validity=validity)


def column_from_pylist(field: Field, data: Sequence) -> Column:
    """Build a column from python values (None -> null)."""
    mask = np.array([v is not None for v in data], dtype=np.bool_)
    validity = None if mask.all() else pack_validity(mask)
    if field.varlen:
        chunks: list[bytes] = []
        offsets = np.zeros(len(data) + 1, dtype=np.int32)
        total = 0
        for i, v in enumerate(data):
            b = b"" if v is None else (v.encode("utf-8") if isinstance(v, str) else bytes(v))
            chunks.append(b)
            total += len(b)
            offsets[i + 1] = total
        values = np.frombuffer(b"".join(chunks), dtype=np.uint8).copy() if total else np.zeros(0, np.uint8)
        return Column(field, values, offsets=offsets, validity=validity)
    dtype = numpy_dtype(field.type)
    values = np.array([dtype.type(0) if v is None else v for v in data], dtype=dtype)
    return Column(field, values, validity=validity)


# ---------------------------------------------------------------------------
# record batches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RecordBatch:
    schema: Schema
    columns: tuple[Column, ...]

    def __post_init__(self) -> None:
        if len(self.schema) != len(self.columns):
            raise ValueError("schema/columns arity mismatch")
        rows = {c.num_rows for c in self.columns}
        if len(rows) > 1:
            raise ValueError(f"ragged columns: row counts {sorted(rows)}")

    @property
    def num_rows(self) -> int:
        return self.columns[0].num_rows if self.columns else 0

    @property
    def num_columns(self) -> int:
        return len(self.columns)

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns)

    def column(self, key: int | str) -> Column:
        if isinstance(key, str):
            key = self.schema.index(key)
        return self.columns[key]

    def select(self, names: Sequence[str]) -> "RecordBatch":
        """Column projection — zero-copy (shares buffers)."""
        idx = [self.schema.index(n) for n in names]
        return RecordBatch(self.schema.select(names), tuple(self.columns[i] for i in idx))

    def take(self, indices: np.ndarray) -> "RecordBatch":
        return RecordBatch(self.schema, tuple(c.take(indices) for c in self.columns))

    def slice(self, start: int, length: int) -> "RecordBatch":
        """Row slice. Fixed-width columns are zero-copy views; varlen values
        stay shared with re-based offsets."""
        stop = start + length
        cols = []
        for c in self.columns:
            mask = c.valid_mask()[start:stop]
            validity = None if mask.all() else pack_validity(mask)
            if c.field.varlen:
                off = c.offsets[start : stop + 1]
                cols.append(Column(c.field, c.values[int(off[0]) : int(off[-1])],
                                   offsets=(off - off[0]).astype(np.int32), validity=validity))
            else:
                cols.append(Column(c.field, c.values[start:stop], validity=validity))
        return RecordBatch(self.schema, tuple(cols))

    def to_pydict(self) -> dict[str, list]:
        return {f.name: c.to_pylist() for f, c in zip(self.schema, self.columns)}

    def __iter__(self) -> Iterator[Column]:
        return iter(self.columns)


def batch_from_pydict(sch: Schema, data: dict[str, Sequence]) -> RecordBatch:
    cols = tuple(column_from_pylist(f, data[f.name]) for f in sch)
    return RecordBatch(sch, cols)


def batch_from_arrays(sch: Schema, arrays: Sequence[np.ndarray]) -> RecordBatch:
    """Zero-copy wrap of numpy arrays as fixed-width columns."""
    cols = []
    for f, a in zip(sch, arrays):
        if f.varlen:
            raise ValueError("batch_from_arrays is for fixed-width columns")
        cols.append(Column(f, np.ascontiguousarray(a)))
    return RecordBatch(sch, tuple(cols))


def concat_batches(batches: Sequence[RecordBatch]) -> RecordBatch:
    """Concatenate batches row-wise (copies; used by eager collectors)."""
    if not batches:
        raise ValueError("no batches")
    sch = batches[0].schema
    cols = []
    for ci, f in enumerate(sch):
        parts = [b.columns[ci] for b in batches]
        masks = np.concatenate([c.valid_mask() for c in parts])
        validity = None if masks.all() else pack_validity(masks)
        if f.varlen:
            vals = np.concatenate([c.values for c in parts]) if parts else np.zeros(0, np.uint8)
            offs = [np.zeros(1, np.int32)]
            base = 0
            for c in parts:
                offs.append((c.offsets[1:] + base).astype(np.int32))
                base += int(c.offsets[-1])
            cols.append(Column(f, vals, offsets=np.concatenate(offs), validity=validity))
        else:
            cols.append(Column(f, np.concatenate([c.values for c in parts]), validity=validity))
    return RecordBatch(sch, tuple(cols))
