"""The two transports under study.

* :class:`RpcTransport` — the baseline: serialize the record batch into one
  contiguous buffer (full copy of every column buffer), ship it as an RPC
  payload, deserialize zero-copy on the receiver.
* :class:`ThallusTransport` — the paper's protocol: expose the batch's
  buffers as a scatter-gather bulk (no copies), ship only descriptors over
  RPC, RDMA-pull each segment one-to-one into freshly allocated client
  buffers, assemble the batch as views (no copies).

Both return ``(batch, TransportStats)`` so every benchmark decomposition in
the paper (§2 serialization fraction, Fig. 2 transport duration) is
reproducible from the same code path.
"""
from __future__ import annotations

import dataclasses
import time

from . import bulk as bulk_mod
from . import serialize
from .fabric import Fabric, WireStats
from .recordbatch import RecordBatch


@dataclasses.dataclass
class TransportStats:
    serialize_s: float = 0.0       # measured: pack copies (baseline only)
    expose_s: float = 0.0          # measured: bulk expose / descriptor build
    alloc_s: float = 0.0           # measured: client buffer allocation
    wire: WireStats = dataclasses.field(default_factory=WireStats)
    deserialize_s: float = 0.0     # measured: receiver batch assembly
    control_rpcs: int = 0

    @property
    def total_s(self) -> float:
        return (self.serialize_s + self.expose_s + self.alloc_s
                + self.wire.total_s + self.deserialize_s)

    @property
    def serialize_fraction(self) -> float:
        return self.serialize_s / self.total_s if self.total_s else 0.0


def rdma_pull_batch(fabric: Fabric, schema, num_rows: int,
                    remote: bulk_mod.BulkHandle, pool=None, pin: bool = False
                    ) -> tuple[RecordBatch, bulk_mod.BulkHandle, "TransportStats"]:
    """The client-side data plane every puller shares: allocate a matching
    local bulk (``pool.acquire`` checkout when a buffer pool is given, else a
    fresh allocation — ``pin=True`` faults the pages like registration must),
    RDMA-pull one-to-one, assemble the batch zero-copy. One implementation so
    the single-stream and cluster decompositions can never drift apart.

    Returns ``(batch, local_handle, stats)``; pooled callers release
    ``local_handle`` once the batch is consumed."""
    stats = TransportStats()
    t0 = time.perf_counter()
    if pool is not None:
        local = pool.acquire(remote.descs)
    else:
        local = bulk_mod.allocate_like(remote.descs, pin=pin)
    stats.alloc_s = time.perf_counter() - t0
    try:
        stats.wire = fabric.rdma_pull(remote.segments, local.segments,
                                      registered=local.registered)
        t0 = time.perf_counter()
        batch = bulk_mod.assemble_batch(schema, num_rows, local.segments)
        stats.deserialize_s = time.perf_counter() - t0
    except BaseException:
        # a failed pull must hand its checkout back, or fault-resume loops
        # leak one slab set per fault
        if pool is not None:
            pool.release(local)
        raise
    return batch, local, stats


class Transport:
    name = "abstract"

    def __init__(self, fabric: Fabric | None = None):
        self.fabric = fabric or Fabric()

    def send_batch(self, batch: RecordBatch) -> tuple[RecordBatch, TransportStats]:
        raise NotImplementedError


class RpcTransport(Transport):
    """Baseline: data-over-RPC with mandatory serialization."""

    name = "rpc"

    def send_batch(self, batch: RecordBatch) -> tuple[RecordBatch, TransportStats]:
        stats = TransportStats(control_rpcs=1)
        t0 = time.perf_counter()
        wire_buf = serialize.pack(batch)               # full staging copy
        stats.serialize_s = time.perf_counter() - t0
        stats.wire = self.fabric.rpc_payload(wire_buf)  # one big RPC payload
        t0 = time.perf_counter()
        out = serialize.unpack(wire_buf, zero_copy=True)  # views: ~free
        stats.deserialize_s = time.perf_counter() - t0
        return out, stats


class ThallusTransport(Transport):
    """The paper's protocol: metadata over RPC, data over RDMA, zero copies."""

    name = "thallus"

    def send_batch(self, batch: RecordBatch) -> tuple[RecordBatch, TransportStats]:
        stats = TransportStats()
        # -- server: expose segments in place (no copies) ------------------
        t0 = time.perf_counter()
        remote = bulk_mod.expose_batch(batch, mode="read_only")
        sizes = bulk_mod.size_vectors(batch)
        stats.expose_s = time.perf_counter() - t0
        # -- control plane: handle + size vectors + num_rows over RPC ------
        meta_bytes = 64 + 8 * sum(len(v) for v in sizes)  # descriptor payload
        rpc = self.fabric.rpc(meta_bytes)
        stats.control_rpcs = 1
        # -- client: allocate matching layout, write-only local bulk -------
        t0 = time.perf_counter()
        local = bulk_mod.allocate_like(remote.descs)
        stats.alloc_s = time.perf_counter() - t0
        # -- data plane: scatter-gather pull, one-to-one --------------------
        stats.wire = self.fabric.rdma_pull(remote.segments, local.segments)
        stats.wire.modeled_wire_s += rpc.modeled_wire_s  # control rides along
        # -- client: zero-copy assembly (buffers+sizes+dtypes -> batch) -----
        t0 = time.perf_counter()
        out = bulk_mod.assemble_batch(batch.schema, batch.num_rows, local.segments)
        stats.deserialize_s = time.perf_counter() - t0
        return out, stats


def make_transport(name: str, fabric: Fabric | None = None) -> Transport:
    if name == "rpc":
        return RpcTransport(fabric)
    if name == "thallus":
        return ThallusTransport(fabric)
    raise ValueError(f"unknown transport {name!r} (want 'rpc' or 'thallus')")
