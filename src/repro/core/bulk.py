"""Bulk handles: the scatter-gather descriptor core of Thallus.

In the paper, the server allocates ``3 * ncols`` *segments* — for the i-th
column its data, offset and null buffers map to segments ``3i``, ``3i+1``,
``3i+2`` — and *exposes* them as a read-only Thallium bulk. The bulk handle
is a small serializable descriptor for an RDMA-ready pinned region list; the
actual bytes never touch the RPC path.

Here a :class:`BulkHandle` holds the descriptor table (shapes/dtypes/sizes —
pure metadata) plus, on the *owning* side, references to the live numpy
buffers. ``expose()`` performs **no copies** — that is the whole point — and
the tests assert the exposed segments alias the batch's buffers.
"""
from __future__ import annotations

import dataclasses
import itertools
import uuid as _uuid
from typing import Sequence

import numpy as np

from .recordbatch import Column, RecordBatch
from .schema import Schema

_EMPTY_U8 = np.zeros(0, dtype=np.uint8)


@dataclasses.dataclass(frozen=True)
class SegmentDesc:
    """Metadata for one exposed memory segment (control-plane safe)."""

    nbytes: int
    dtype: str            # numpy dtype string of the underlying buffer
    kind: str             # "values" | "offsets" | "validity"
    column_index: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "SegmentDesc":
        return SegmentDesc(**d)


@dataclasses.dataclass
class BulkHandle:
    """Descriptor for an exposed scatter-gather region list.

    ``segments`` (the live buffers) is only populated on the side that owns
    the memory; what crosses the control plane is ``descs`` + ``handle_id``
    (see :meth:`remote_view`). This mirrors Thallium's bulk semantics where
    the handle is serializable but dereferencing it requires an RDMA op.
    """

    handle_id: str
    descs: tuple[SegmentDesc, ...]
    mode: str  # "read_only" | "write_only" | "read_write"
    segments: tuple[np.ndarray, ...] | None = None
    registered: bool = False  # segments live in a pre-registered (pinned) pool

    @property
    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self.descs)

    @property
    def num_segments(self) -> int:
        return len(self.descs)

    def remote_view(self) -> "BulkHandle":
        """The metadata-only form that is legal to ship over RPC."""
        return BulkHandle(self.handle_id, self.descs, self.mode, segments=None)

    def is_local(self) -> bool:
        return self.segments is not None


# kind layout order per column: 3i -> values, 3i+1 -> offsets, 3i+2 -> validity
_KINDS = ("values", "offsets", "validity")


def expose_batch(batch: RecordBatch, mode: str = "read_only") -> BulkHandle:
    """Expose a record batch's buffers as a bulk — ZERO copies.

    Missing buffers (no offsets on fixed-width columns, no validity bitmap)
    are exposed as 0-byte segments so the ``3*ncols`` indexing from the paper
    stays intact and the client can allocate one-to-one.
    """
    segs: list[np.ndarray] = []
    descs: list[SegmentDesc] = []
    for ci, col in enumerate(batch.columns):
        bufs = (col.values,
                col.offsets if col.offsets is not None else _EMPTY_U8,
                col.validity if col.validity is not None else _EMPTY_U8)
        for k, buf in zip(_KINDS, bufs):
            segs.append(buf)
            descs.append(SegmentDesc(int(buf.nbytes), str(buf.dtype), k, ci))
    return BulkHandle(str(_uuid.uuid4()), tuple(descs), mode, segments=tuple(segs))


def size_vectors(batch: RecordBatch) -> tuple[list[int], list[int], list[int]]:
    """The paper's three size vectors (data/offset/null bytes per column)."""
    data, offs, nulls = [], [], []
    for col in batch.columns:
        data.append(int(col.values.nbytes))
        offs.append(int(col.offsets.nbytes) if col.offsets is not None else 0)
        nulls.append(int(col.validity.nbytes) if col.validity is not None else 0)
    return data, offs, nulls


def allocate_like(descs: Sequence[SegmentDesc], pin: bool = False) -> BulkHandle:
    """Client side: allocate a write-only local bulk with the same layout as
    a remote handle ("allocate a similar layout of buffers as on the server").

    ``pin=True`` faults the pages in at allocation time (zero-fill), the way
    RDMA registration must before the NIC can target the buffer — the honest
    per-batch cost a registered buffer pool amortizes away."""
    alloc = np.zeros if pin else np.empty
    segs = tuple(alloc(d.nbytes // np.dtype(d.dtype).itemsize, dtype=d.dtype)
                 for d in descs)
    return BulkHandle(str(_uuid.uuid4()), tuple(descs), "write_only", segments=segs)


def assemble_batch(schema: Schema, num_rows: int,
                   segments: Sequence[np.ndarray]) -> RecordBatch:
    """Receiver-side zero-copy assembly: buffers + sizes + dtypes -> columns
    -> batch. No data movement — just view wiring (Arrow deserialization)."""
    cols = []
    it = iter(segments)
    for field in schema:
        values, offsets, validity = next(it), next(it), next(it)
        if not field.varlen:
            values = values.view(field.value_dtype)
            offsets = None
        else:
            offsets = offsets.view(np.int32)
        validity = validity if validity.nbytes else None
        cols.append(Column(field, values, offsets=offsets, validity=validity))
    leftover = list(itertools.islice(it, 1))
    if leftover:
        raise ValueError("segment count does not match schema")
    return RecordBatch(schema, tuple(cols))
