"""Device-side Thallus: host↔HBM and HBM↔HBM columnar movement.

The TPU-native translation of the paper's two paths:

* **thallus path** (`batch_to_device`): every column buffer goes host→device
  *individually* via ``jax.device_put`` with an explicit ``NamedSharding`` —
  the scatter-gather DMA analogue. No staging buffer ever exists; the batch
  on device is a *pytree* of per-column arrays (logical assembly, like
  Arrow's zero-copy deserialize).
* **rpc path** (`batch_to_device_packed`): serialize into ONE contiguous
  host buffer (full copy), ship that single buffer, then slice columns back
  out *on device* (more copies). This is the baseline whose cost the
  protocol deletes.

Both produce identical column arrays (tests assert allclose), so the rest of
the stack — the input pipeline feeding ``train_step`` — is transport-
agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import serialize
from .recordbatch import RecordBatch


@dataclasses.dataclass
class DeviceBatch:
    """A record batch on device: dict of column-name → array pytree."""

    columns: dict[str, jax.Array]
    num_rows: int

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]


def _col_array(col) -> np.ndarray:
    if col.field.varlen:
        raise ValueError(
            f"column {col.field.name!r} is variable-length; device transport "
            "carries fixed-width (tokenized/numeric) columns")
    return col.values


def batch_to_device(batch: RecordBatch, mesh: Mesh | None = None,
                    specs: Mapping[str, P] | P | None = None) -> DeviceBatch:
    """Zero-staging path: per-column device_put with explicit sharding."""
    cols: dict[str, jax.Array] = {}
    for field, col in zip(batch.schema, batch.columns):
        arr = _col_array(col)
        if mesh is not None:
            spec = specs[field.name] if isinstance(specs, Mapping) else (specs or P())
            cols[field.name] = jax.device_put(arr, NamedSharding(mesh, spec))
        else:
            cols[field.name] = jax.device_put(arr)
    return DeviceBatch(cols, batch.num_rows)


def batch_to_device_packed(batch: RecordBatch, mesh: Mesh | None = None,
                           specs: Mapping[str, P] | P | None = None) -> DeviceBatch:
    """Baseline path: pack → single transfer → on-device slice-out."""
    wire = serialize.pack(batch)  # host staging copy (the overhead)
    if mesh is not None:
        # the packed buffer is replicated (it cannot be column-sharded —
        # precisely why the baseline composes poorly with sharding)
        dev_wire = jax.device_put(wire, NamedSharding(mesh, P()))
    else:
        dev_wire = jax.device_put(wire)

    # Recover per-buffer extents on host from the header (metadata only).
    hlen = int(np.frombuffer(wire[:8].tobytes(), np.uint64)[0])
    import json
    header = json.loads(wire[8 : 8 + hlen].tobytes().decode("utf-8"))
    pos = 8 + hlen + (-hlen) % 8

    cols: dict[str, jax.Array] = {}
    bufs = header["buffers"]
    bi = 0
    for field, col in zip(batch.schema, batch.columns):
        meta = bufs[bi]  # values buffer for this column
        nbytes = meta["nbytes"]
        dtype = np.dtype(meta["dtype"])
        sliced = jax.lax.dynamic_slice(dev_wire, (pos,), (nbytes,))
        arr = jax.lax.bitcast_convert_type(
            sliced.reshape(-1, dtype.itemsize), jnp.dtype(dtype)).reshape(-1)
        if mesh is not None:
            spec = specs[field.name] if isinstance(specs, Mapping) else (specs or P())
            arr = jax.device_put(arr, NamedSharding(mesh, spec))
        cols[field.name] = arr
        # advance past values/offsets/validity (3 buffers per column)
        for _ in range(3):
            nb = bufs[bi]["nbytes"]
            pos += nb + (-nb) % 8
            bi += 1
    return DeviceBatch(cols, batch.num_rows)


def training_batch_specs(mesh: Mesh, batch_axes: tuple[str, ...] = ("pod", "data")) -> P:
    """Canonical sharding for token batches: rows split over the data axes."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))
