from .base import (ArchConfig, EncDecConfig, HybridConfig, MoEConfig,  # noqa: F401
                   SHAPES, SSMConfig, ShapeConfig, VLMConfig, shape_applicable)
from .registry import ARCH_IDS, all_cells, get_config, get_shape  # noqa: F401
