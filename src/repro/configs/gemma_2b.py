"""gemma-2b [dense] — GeGLU, MQA (kv=1), head_dim=256 [arXiv:2403.08295; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=256000, head_dim=256,
    activation="geglu", rope_theta=10000.0, norm_eps=1e-6,
    tie_embeddings=True, zero_centered_norm=True, embed_scale=True,
    pad_heads_to=16,                 # 8 -> 16 MQA queries for 16-way TP
    source="[arXiv:2403.08295; hf]",
)
