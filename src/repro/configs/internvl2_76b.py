"""internvl2-76b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + LLaMA-arch 80L language backbone [arXiv:2404.16821; unverified]."""
from .base import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    activation="swiglu", rope_theta=500000.0, norm_eps=1e-5,
    vlm=VLMConfig(num_patches=256),
    source="[arXiv:2404.16821; unverified]",
)
