"""mamba2-780m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    activation="silu", norm_eps=1e-5, tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, conv_width=4, expand=2, chunk=256),
    sub_quadratic=True,
    source="[arXiv:2405.21060; unverified]",
)
