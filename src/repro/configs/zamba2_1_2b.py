"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. Per-invocation LoRA deltas omitted (DESIGN.md §8)."""
from .base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    activation="geglu", rope_theta=10000.0, norm_eps=1e-5,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, expand=2, chunk=256),
    hybrid=HybridConfig(shared_every=6, num_shared_blocks=1),
    sub_quadratic=True,
    source="[arXiv:2411.15242; hf]",
)
