"""deepseek-67b [dense] — llama-arch, 95L GQA kv=8 [arXiv:2401.02954; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400, head_dim=128,
    activation="swiglu", rope_theta=10000.0, norm_eps=1e-6,
    source="[arXiv:2401.02954; hf]",
)
