"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeConfig, shape_applicable  # noqa: F401

_MODULES = {
    "gemma-2b": "gemma_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "granite-3-2b": "granite_3_2b",
    "deepseek-67b": "deepseek_67b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-780m": "mamba2_780m",
    "whisper-small": "whisper_small",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """Every (arch, shape) pair with its applicability verdict."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield cfg, shape, ok, why
