"""Architecture + shape configuration schema.

Every assigned architecture is an :class:`ArchConfig`; every input-shape set
entry is a :class:`ShapeConfig`. ``reduced()`` derives the small same-family
config used by the CPU smoke tests; the full config is only ever lowered
abstractly (dry-run) — never allocated on this container.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    shared_expert_ff: int = 0       # >0 adds a dense shared expert (llama4)
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int
    head_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    chunk: int = 256
    ngroups: int = 1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    shared_every: int = 6           # apply the shared attn block every N layers
    num_shared_blocks: int = 1      # distinct shared blocks cycled through


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int
    encoder_seq: int = 1500         # whisper: 30 s audio -> 1500 frames


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 256          # visual tokens prepended to the text


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    activation: str = "swiglu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    zero_centered_norm: bool = False
    qk_norm: bool = False
    embed_scale: bool = False       # gemma: embeddings * sqrt(d_model)
    logit_softcap: float | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    source: str = ""                # provenance note [arXiv/hf; tier]
    sub_quadratic: bool = False     # can run long_500k
    # TP-divisibility head padding (§Perf iteration): extra zero-init heads
    # so query/kv heads divide the 16-way model axis. Overhead is real
    # compute, visible in the useful-flops ratio; 0 = off.
    pad_heads_to: int = 0
    pad_kv_to: int = 0

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def eff_heads(self) -> int:
        return max(self.pad_heads_to, self.num_heads)

    @property
    def eff_kv(self) -> int:
        return max(self.pad_kv_to, self.num_kv_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a 256 multiple (Megatron-style) so
        the vocab dim shards across any mesh axis; padded logit rows are
        masked to -inf in logits_fn. num_params() stays at the true vocab."""
        return -(-self.vocab_size // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        small_moe = None
        if self.moe is not None:
            small_moe = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                shared_expert_ff=32 if self.moe.shared_expert_ff else 0)
        small_ssm = None
        if self.ssm is not None:
            small_ssm = dataclasses.replace(self.ssm, state_dim=16, head_dim=8,
                                            chunk=8)
        small_hybrid = self.hybrid
        small_encdec = None
        if self.encdec is not None:
            small_encdec = dataclasses.replace(self.encdec, num_encoder_layers=2,
                                               encoder_seq=24)
        small_vlm = None
        if self.vlm is not None:
            small_vlm = dataclasses.replace(self.vlm, num_patches=4)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads) if self.num_kv_heads else heads
        if heads and heads % kv:
            kv = 1
        return dataclasses.replace(
            self, num_layers=min(self.num_layers, 4) if self.hybrid is None
            else 7,  # hybrid: enough layers to hit a shared block
            d_model=64, num_heads=heads, num_kv_heads=kv, head_dim=16,
            d_ff=128, vocab_size=503, moe=small_moe, ssm=small_ssm,
            hybrid=small_hybrid, encdec=small_encdec, vlm=small_vlm,
            pad_heads_to=0, pad_kv_to=0)

    # -- parameter accounting (for MODEL_FLOPS = 6·N·D) --------------------
    def num_params(self, active_only: bool = False) -> int:
        D, hd = self.d_model, self.resolved_head_dim
        H, KV, L = self.num_heads, self.num_kv_heads, self.num_layers
        n = self.vocab_size * D                      # embed
        if not self.tie_embeddings:
            n += D * self.vocab_size                 # lm_head
        n += D                                       # final norm

        def attn_params() -> int:
            p = D * H * hd + 2 * D * KV * hd + H * hd * D
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params() -> int:
            if self.activation in ("geglu", "swiglu"):
                return 3 * D * self.d_ff
            return 2 * D * self.d_ff

        def moe_params(active: bool) -> int:
            m = self.moe
            e = m.top_k if active else m.num_experts
            p = D * m.num_experts  # router (always resident)
            p += e * 3 * D * m.d_ff_expert
            if m.shared_expert_ff:
                p += 3 * D * m.shared_expert_ff
            return p

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * D
            nheads = d_in // s.head_dim
            conv_ch = d_in + 2 * s.ngroups * s.state_dim
            p = D * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)  # in_proj
            p += conv_ch * s.conv_width                                # conv
            p += nheads * 2 + d_in                                     # A, D, norm
            p += d_in * D                                              # out_proj
            return p

        if self.family in ("dense", "vlm"):
            n += L * (attn_params() + mlp_params() + 2 * D)
        elif self.family == "moe":
            n += L * (attn_params() + moe_params(active_only) + 2 * D)
        elif self.family == "ssm":
            n += L * (ssm_params() + D)
        elif self.family == "hybrid":
            n += L * (ssm_params() + D)
            shared = attn_params() + mlp_params() + 2 * D
            # shared block input is concat(hidden, embed) -> 2D projection
            shared += 2 * D * H * hd - D * H * hd  # wq from 2D
            shared += 2 * D * 2 * KV * hd - 2 * D * KV * hd
            n += self.hybrid.num_shared_blocks * shared
        elif self.family == "audio":
            enc = self.encdec.num_encoder_layers
            n += enc * (attn_params() + 2 * D * self.d_ff + 2 * D)
            n += L * (attn_params() * 2 + 2 * D * self.d_ff + 3 * D)  # +cross
            n += self.encdec.encoder_seq * D                          # enc pos
            n += 4096 * D                                             # dec pos
        else:
            raise ValueError(self.family)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch           # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Family rules from the assignment: long_500k only for sub-quadratic
    archs; decode shapes skipped for encoder-only archs (none assigned)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention family: long_500k skipped per assignment"
    return True, ""
