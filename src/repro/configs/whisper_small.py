"""whisper-small [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings via input_specs()) [arXiv:2212.04356; unverified]."""
from .base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    activation="gelu", norm_eps=1e-5, tie_embeddings=True,
    encdec=EncDecConfig(num_encoder_layers=12, encoder_seq=1500),
    pad_heads_to=16, pad_kv_to=16,   # 12 -> 16 MHA for 16-way TP
    source="[arXiv:2212.04356; unverified]",
)
