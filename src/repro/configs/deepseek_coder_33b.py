"""deepseek-coder-33b [dense] — llama-arch, GQA kv=8 [arXiv:2401.14196; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    activation="swiglu", rope_theta=100000.0, norm_eps=1e-6,
    pad_heads_to=64,                 # 56 -> 64 for 16-way TP (+14% attn)
    source="[arXiv:2401.14196; hf]",
)
