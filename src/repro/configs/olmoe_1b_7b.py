"""olmoe-1b-7b [moe] — 64 experts top-8, qk-norm [arXiv:2409.02060; hf]."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    activation="swiglu", rope_theta=10000.0, norm_eps=1e-5,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                  capacity_factor=1.25),
    source="[arXiv:2409.02060; hf]",
)
