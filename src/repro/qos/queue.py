"""Weighted-fair queueing across client classes, with deadline shedding.

The gateway serves two very different traffic shapes from one reader map:
*interactive* requests (serving lookups — small, latency-sensitive) and
*batch* requests (training scans — huge, throughput-bound). A FIFO queue
lets one heavy client starve everyone; weighted-fair queueing gives each
class a share of service proportional to its weight, the way exchange
operators are scheduled in high-speed-network query engines
(arXiv:1502.07169).

The discipline is classic virtual-finish-time WFQ: request *i* of class *c*
gets ``finish_i = max(vtime, last_finish_c) + cost_i / weight_c`` and the
queue pops the smallest finish tag. ``cost`` is the request's service
estimate in abstract units (the gateway calibrates units → modeled seconds
as it serves). A class with weight 4 therefore drains 4× the service of a
weight-1 class under contention, while an idle class loses nothing (its
``last_finish`` lags ``vtime``).

:class:`FifoQueue` is the same interface with arrival-order tags — the
"quotas disabled" baseline the contention benchmark compares against.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class ClientClass:
    name: str
    weight: float = 1.0

    def __post_init__(self) -> None:
        if self.weight <= 0:
            raise ValueError(f"class {self.name!r} needs weight > 0")


#: Default two-class split: interactive traffic gets 4× the service share.
INTERACTIVE = ClientClass("interactive", 4.0)
BATCH = ClientClass("batch", 1.0)


class WeightedFairQueue:
    """Virtual-finish-time WFQ over client classes."""

    fair = True

    def __init__(self, classes: Iterable[ClientClass] | None = None):
        self.classes = {c.name: c for c in (classes or (INTERACTIVE, BATCH))}
        self._heap: list[tuple[float, int, float, object]] = []
        self._seq = 0
        self._vtime = 0.0
        self._last_finish: dict[str, float] = {}

    def weight(self, klass: str) -> float:
        cls = self.classes.get(klass)
        return cls.weight if cls is not None else 1.0

    # ----------------------------------------------------------- enqueueing
    def would_finish(self, klass: str, cost: float) -> float:
        """The finish tag a push would get — used for shed estimates."""
        start = max(self._vtime, self._last_finish.get(klass, 0.0))
        return start + max(cost, 1e-12) / self.weight(klass)

    def backlog_before(self, finish_tag: float) -> float:
        """Total queued cost that would be served before ``finish_tag`` —
        the modeled wait (in cost units) a new request with that tag faces."""
        return sum(cost for tag, _, cost, _ in self._heap if tag <= finish_tag)

    def push(self, item, klass: str, cost: float = 1.0) -> float:
        tag = self.would_finish(klass, cost)
        self._last_finish[klass] = tag
        heapq.heappush(self._heap, (tag, self._seq, max(cost, 1e-12), item))
        self._seq += 1
        return tag

    # ------------------------------------------------------------ dequeuing
    def pop(self, now_s: float | None = None):
        """Pop the smallest finish tag. With ``now_s`` (the preemption-aware
        gateway's modeled clock), only items that have *arrived*
        (``item.arrival_s <= now_s``) compete; when nothing has arrived yet
        the global minimum is returned and the caller advances its clock to
        that item's arrival. Without ``now_s`` arrival times are ignored
        (the pre-sched behavior)."""
        if now_s is None or not self._heap:
            tag, _, _, item = heapq.heappop(self._heap)
            self._vtime = max(self._vtime, tag)
            return item
        arrived = [e for e in self._heap
                   if getattr(e[3], "arrival_s", 0.0) <= now_s]
        if arrived:
            entry = min(arrived)
        else:
            # idle gateway: serve the EARLIEST arrival next (jumping to a
            # later-arriving item's tag would idle past — and spuriously
            # deadline-shed — requests that arrive in between)
            entry = min(self._heap,
                        key=lambda e: (getattr(e[3], "arrival_s", 0.0),
                                       e[0], e[1]))
        self._heap.remove(entry)
        heapq.heapify(self._heap)
        self._vtime = max(self._vtime, entry[0])
        return entry[3]

    def has_preemptor(self, klass: str, now_s: float) -> bool:
        """True when a strictly higher-weight request has arrived by
        ``now_s`` — the gateway's signal to park a running ``klass`` scan
        at its next lease boundary."""
        w = self.weight(klass)
        return any(
            self.weight(getattr(item, "klass", "?")) > w
            and getattr(item, "arrival_s", 0.0) <= now_s
            for _, _, _, item in self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def depth_by_class(self, key=lambda item: getattr(item, "klass", "?")
                       ) -> dict[str, int]:
        depths: dict[str, int] = {}
        for _, _, _, item in self._heap:
            k = key(item)
            depths[k] = depths.get(k, 0) + 1
        return depths


class FifoQueue(WeightedFairQueue):
    """Arrival-order queue: the no-QoS baseline (weights ignored)."""

    fair = False

    def would_finish(self, klass: str, cost: float) -> float:
        return float(self._seq)

    def push(self, item, klass: str, cost: float = 1.0) -> float:
        tag = float(self._seq)
        heapq.heappush(self._heap, (tag, self._seq, max(cost, 1e-12), item))
        self._seq += 1
        return tag
