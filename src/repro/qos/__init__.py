"""repro.qos: admission control + flow control for the cluster dataplane.

The layer between clients and the
:class:`~repro.cluster.coordinator.ClusterCoordinator`: per-client stream
quotas, a registered-memory budget, and token-bucket lease metering
(:mod:`.admission`); the same budget sharded per server with borrowing and
modeled-time reconciliation (:mod:`.distributed`); weighted-fair queueing
across client classes with deadline shedding (:mod:`.queue`); a
request-level scatter-gather gateway (:mod:`.gateway`); and per-class
metrics that compose with ``ClusterStats`` (:mod:`.metrics`).
"""
from __future__ import annotations

from .admission import (  # noqa: F401
    AdmissionConfig, AdmissionController, AdmissionStats, Backpressure,
)
from .distributed import (  # noqa: F401
    AdmissionShard, DistributedConfig, DistributedStats, ReconcileReport,
    ShardStats, ShardedAdmission,
)
from .gateway import (  # noqa: F401
    ScanGateway, ScanRequest, ScanResult, reassemble,
)
from .metrics import ClassStats, QosStats  # noqa: F401
from .queue import (  # noqa: F401
    BATCH, INTERACTIVE, ClientClass, FifoQueue, WeightedFairQueue,
)
