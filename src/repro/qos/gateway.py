"""The scan gateway: request-level scatter-gather behind admission control.

One logical request enters as a :class:`ScanRequest` and leaves as a
:class:`ScanResult` whose batches are in **global scan order** — the gateway
plans the query across shard/replica servers, pulls every endpoint
concurrently through :class:`~repro.cluster.streams.MultiStreamPuller`, and
reassembles the per-stream deliveries (scatter-gather at the request level,
not just the batch level). Between submit and grant sit the two QoS layers:

* the :class:`~.queue.WeightedFairQueue` orders grants across client
  classes (interactive > batch) and sheds requests whose modeled wait
  exceeds their deadline budget;
* the :class:`~.admission.AdmissionController` meters lease grants with a
  token bucket (one token per stream the fan-out opens) and caps each
  client's *effective parallelism* at its stream quota — a quota-capped
  request still sees every shard (nothing is silently dropped), its streams
  are just serialized onto ``quota`` modeled lanes.

With a :class:`repro.sched.AdaptiveScheduler` attached, execution itself
becomes adaptive:

* **work stealing** — fan-outs run on a
  :class:`~repro.sched.steal.StealingPuller`, so a lagging replica's
  remaining range migrates to the fastest idle replica mid-scan;
* **shared tickets** — identical queued requests (same
  ``(sql, dataset, start_batch)``) coalesce onto one fan-out; the first to
  reach the head of the queue executes and publishes, every later
  subscriber is served by multicast (copy-on-read) with its own per-class
  accounting but zero additional server-side service;
* **preemption** — batch-class requests execute in parkable lease rounds
  (:class:`~repro.sched.preempt.PreemptibleScan`); the moment an
  interactive request has *arrived* on the modeled clock, the batch scan
  parks at its lease boundary (leases and admission slots released), the
  remainder re-enters the weighted-fair queue at its residual cost, and the
  scan resumes where it stopped when the virtual clock readmits it.

Admission may be **distributed**: with a
:class:`~.distributed.ShardedAdmission`, lease tokens are metered against
each endpoint server's own bucket shard (concurrent grants — the charged
wait is the slowest shard's), and the gateway auto-subscribes to the
controller's freed-slot events: :meth:`ScanGateway.replan_on_release`
records the modeled instant another client's stream closed, and a
quota-capped in-flight fan-out whose service window covers that instant
packs its remaining streams onto the widened lane set instead of
serializing onto the grant-time lanes for its whole service
(``QosStats.replans`` counts the widenings).

Time is modeled: the gateway runs a deterministic clock that advances by
each request's modeled service time, so grant latency / shedding / fairness
comparisons reproduce exactly under any machine load. The coordinator handed
to a gateway should **not** carry its own admission controller — the gateway
already meters at request granularity, and per-stream metering underneath it
would double-charge the bucket.
"""
from __future__ import annotations

import dataclasses
import weakref

from ..cluster.mempool import BufferPool
from ..cluster.plan import Endpoint, ScanPlan
from ..cluster.coordinator import ClusterCoordinator
from ..cluster.streams import (ClusterStats, MultiStreamPuller,
                               notify_coordinator)
from ..core.recordbatch import RecordBatch
from ..sched import AdaptiveScheduler, PreemptibleScan, Ticket
from .admission import AdmissionController, Backpressure
from .metrics import QosStats
from .queue import ClientClass, FifoQueue, WeightedFairQueue


@dataclasses.dataclass
class ScanRequest:
    """One logical scan: what a client submits to the gateway."""

    client_id: str
    klass: str                      # client-class name (queue weight lookup)
    sql: str
    dataset: str
    request_id: int | None = None   # assigned by the gateway when None
    cost_hint: float = 1.0          # relative service estimate (WFQ units)
    deadline_s: float | None = None  # shed if modeled wait exceeds this
    arrival_s: float = 0.0          # modeled arrival time
    num_streams: int | None = None  # fan-out hint (replica placement)
    start_batch: int = 0            # resume offset in global scan order


@dataclasses.dataclass
class ScanResult:
    request: ScanRequest
    batches: list[RecordBatch]      # reassembled in global scan order
    cluster: ClusterStats
    grant_latency_s: float          # modeled submit -> grant
    service_s: float                # modeled execution (quota-capped makespan)
    shared: bool = False            # served by shared-ticket multicast
    preemptions: int = 0            # times this scan was parked mid-flight


def reassemble(plan: ScanPlan, per_stream: list[list[RecordBatch]],
               endpoints: tuple[Endpoint, ...] | None = None
               ) -> list[RecordBatch]:
    """Merge per-stream deliveries back into global scan order.

    * ``replica`` plans slice the batch range contiguously — concatenate
      streams by ``start_batch``. Work stealing splits ranges but keeps
      them contiguous and disjoint, so the same sort covers stolen tails;
      pass the *actual* endpoints driven (``puller.endpoints`` may have
      grown past ``plan.endpoints``).
    * ``shard`` plans come from :meth:`ClusterCoordinator.place_shards`,
      which deals ``batches[i::n]`` to the i-th sorted server, so stream
      *i*'s j-th batch is global batch ``j*n + i`` — re-interleave. After
      a membership change re-deals orphaned batches, shards are irregular
      and the interleave assumption breaks; such plans carry each shard's
      dataset-global indices on ``Endpoint.global_batches``, and the merge
      orders by those instead.
    """
    endpoints = plan.endpoints if endpoints is None else endpoints
    if plan.placement == "replica":
        order = sorted(range(len(endpoints)),
                       key=lambda i: endpoints[i].start_batch)
        return [b for i in order for b in per_stream[i]]
    if endpoints and all(e.global_batches is not None for e in endpoints):
        tagged = [(g, b)
                  for ep, stream in zip(endpoints, per_stream)
                  for g, b in zip(ep.global_batches, stream)]
        return [b for _, b in sorted(tagged, key=lambda t: t[0])]
    out: list[RecordBatch] = []
    j = 0
    while True:
        row = [s[j] for s in per_stream if j < len(s)]
        if not row:
            return out
        out.extend(row)
        j += 1


def _copy_batch(batch: RecordBatch) -> RecordBatch:
    """Deep copy out of pooled buffers (they recycle on the next pull)."""
    cols = tuple(dataclasses.replace(
        c, values=c.values.copy(),
        offsets=None if c.offsets is None else c.offsets.copy(),
        validity=None if c.validity is None else c.validity.copy())
        for c in batch.columns)
    return RecordBatch(batch.schema, cols)


def _makespan(clock_s: list[float], parallelism: int | None,
              extra_lanes: tuple[float, ...] = ()) -> float:
    """Modeled completion time of the fan-out under a concurrency cap:
    longest-processing-time greedy assignment of stream clocks onto
    ``parallelism`` lanes. With no cap this is the plain critical path.

    ``extra_lanes`` are lanes that *open mid-service* — freed-slot
    re-planning (another client's streams closed at that relative offset):
    each value is a lane whose earliest start is that offset, so the
    remaining work can widen onto it the moment it frees."""
    if parallelism is None or (parallelism >= len(clock_s)
                               and not extra_lanes):
        return max(clock_s, default=0.0)
    lanes = [0.0] * max(1, parallelism) + [max(0.0, t) for t in extra_lanes]
    makespan = 0.0
    for c in sorted(clock_s, reverse=True):
        idx = min(range(len(lanes)), key=lanes.__getitem__)
        lanes[idx] += c
        makespan = max(makespan, lanes[idx])
    return makespan


@dataclasses.dataclass
class _ParkedScan:
    """A preempted request's continuation, re-queued at residual cost."""

    request: ScanRequest
    scan: PreemptibleScan
    plan: ScanPlan
    grant_latency_s: float          # first grant — preserved across parks
    trim: int                       # leading batches to drop (start_batch)

    @property
    def klass(self) -> str:
        return self.request.klass

    @property
    def arrival_s(self) -> float:
        return self.request.arrival_s

    def residual_cost(self) -> float:
        total = self.scan.total_batches
        frac = (self.scan.delivered / total) if total else 0.5
        return max(self.request.cost_hint * (1.0 - frac), 1e-12)


class ScanGateway:
    """Admission-controlled front door for every scan against the cluster."""

    def __init__(self, coordinator: ClusterCoordinator,
                 classes: list[ClientClass] | None = None,
                 admission: AdmissionController | None = None,
                 pool: BufferPool | None = None, fair: bool = True,
                 lease_batches: int = 1, prefetch: bool = True,
                 est_service_s_per_cost: float = 1e-4,
                 scheduler: AdaptiveScheduler | None = None,
                 tracer=None, modeled_service: bool = False):
        self.coordinator = coordinator
        self.admission = admission
        self.pool = pool
        self.lease_batches = lease_batches
        self.prefetch = prefetch
        self.scheduler = scheduler
        self.tracer = tracer            # obs.Tracer; None = tracing off
        # modeled_service: advance the gateway clock by each stream's
        # fabric-modeled wire time instead of its measured transport clock.
        # The measured clock folds in host CPU (allocation, reassembly), so
        # grant latencies jitter run-to-run; the modeled clock is a pure
        # function of the fabric config and the scan shape, which is what a
        # determinism-asserting scenario (stress) needs. Off by default:
        # throughput scenarios deliberately measure the host.
        self.modeled_service = modeled_service
        self.queue = WeightedFairQueue(classes) if fair else FifoQueue()
        self.stats = QosStats()
        self.results: dict[int, ScanResult] = {}
        self.clock_s = 0.0
        self._next_id = 0
        self._traces: dict[int, object] = {}   # request_id -> TraceContext
        # calibration: WFQ cost units -> modeled seconds, refined as we serve
        self._service_s_per_cost = est_service_s_per_cost
        # freed-slot events (modeled time, slots) awaiting an in-flight
        # fan-out to widen onto; fed by replan_on_release
        self._replan_events: list[tuple[float, int]] = []
        if admission is not None and hasattr(admission, "subscribe_release"):
            # subscribe through a weakref: a long-lived controller sees
            # many gateways come and go, and a strong bound-method
            # subscription would pin each dead gateway (and its event
            # list) forever
            ref = weakref.ref(self)

            def _on_release(server_id=None, client_id=None, now_s=None,
                            _ref=ref):
                gateway = _ref()
                if gateway is not None:
                    gateway.replan_on_release(server_id, client_id, now_s)

            admission.subscribe_release(_on_release)

    # ------------------------------------------------------------- modeling
    def _quota(self) -> int | None:
        return (self.admission.config.max_streams_per_client
                if self.admission is not None else None)

    def _effective_parallelism(self, held_back: int = 0) -> int | None:
        """Lanes a fan-out may run on right now: the client's stream quota,
        further narrowed by what other admission clients currently hold
        against the global stream cap (``None`` == uncapped). ``held_back``
        re-adds slots whose freeing lies *ahead* on the modeled clock — the
        controller's occupancy is wall-clock-current, but a release event
        stamped mid-service means the slot was still held at grant time."""
        quota = self._quota()
        adm = self.admission
        cap = (getattr(adm.config, "max_streams_total", None)
               if adm is not None else None)
        if cap is None:
            return quota
        free = max(1, cap - adm.active_total() - held_back)
        return free if quota is None else min(quota, free)

    def replan_on_release(self, server_id: str | None = None,
                          client_id: str | None = None,
                          now_s: float | None = None) -> None:
        """A stream slot somewhere freed at modeled time ``now_s`` (another
        client closed a stream, a batch scan parked). Remember it: the next
        quota-capped fan-out whose service window covers that instant packs
        its remaining streams onto the widened lane set instead of
        serializing onto the grant-time lanes for its whole service.
        Auto-subscribed to the admission controller's freed-slot events
        when it exposes ``subscribe_release``.

        ``now_s`` must be on THIS gateway's modeled timeline; releases that
        carry none (e.g. a stream close whose only clock is scan-relative)
        are stamped with the current gateway clock, which folds them into
        the next grant's occupancy instead of a mid-service widening —
        conservative, never wrong."""
        t = self.clock_s if now_s is None else now_s
        self._replan_events.append((t, 1))

    def _service_time(self, streams, start_s: float | None = None) -> float:
        """Modeled service of a fan-out: the critical path of absolute
        stream finish times, floored by the quota-lane packing of stream
        *durations*. A stolen stream's ``start_s`` epoch is waiting, not
        work — it bounds the finish time but must not be packed into a
        lane as if the lane were busy.

        With ``start_s`` (the grant instant), freed-slot events after it
        open extra lanes mid-service (gateway re-planning): slots released
        before the grant are already reflected in the occupancy-derived
        lane count, so they are pruned rather than double-counted.

        Under ``modeled_service`` each stream contributes its
        fabric-modeled wire time rather than its measured transport clock,
        making the whole computation deterministic (see ``__init__``)."""
        durations = [s.modeled_wire_s if self.modeled_service else s.clock_s
                     for s in streams]
        finish = max((s.start_s + d for s, d in zip(streams, durations)),
                     default=0.0)
        if self._replan_events:
            # events at or before the service window's start are already
            # reflected in the controller's occupancy — drop them (the
            # preemptible path passes no start_s and widens conservatively,
            # but still drains the backlog against the current clock)
            cut = self.clock_s if start_s is None else start_s
            self._replan_events = [e for e in self._replan_events
                                   if e[0] > cut]
        if start_s is None or not self._replan_events:
            return max(finish,
                       _makespan(durations, self._effective_parallelism()))
        pending = sorted(self._replan_events)
        held_back = sum(k for _, k in pending)
        extra = tuple(t - start_s for t, k in pending for _ in range(k))
        service = max(finish,
                      _makespan(durations,
                                self._effective_parallelism(held_back),
                                extra))
        # only events inside the computed window widened this fan-out; a
        # release stamped beyond it stays queued for the next request
        # whose window actually covers that instant (_makespan never
        # assigns work to a lane opening at or past the final makespan,
        # so dropping those lanes cannot have changed the result)
        kept = [e for e in pending if e[0] - start_s >= service]
        self._replan_events = kept
        self.stats.replans += held_back - sum(k for _, k in kept)
        return service

    # ---------------------------------------------------------- sched hooks
    @property
    def _tickets(self):
        return self.scheduler.tickets if self.scheduler is not None else None

    @property
    def _preempt(self):
        return self.scheduler.preempt if self.scheduler is not None else None

    def _ticket_key(self, request: ScanRequest):
        return (request.sql, request.dataset, request.start_batch)

    def _make_puller(self, plan: ScanPlan, client_id: str,
                     trace=None) -> MultiStreamPuller:
        kwargs = dict(pool=self.pool, lease_batches=self.lease_batches,
                      prefetch=self.prefetch, client_id=client_id,
                      trace=trace)
        if self.scheduler is not None:
            return self.scheduler.make_puller(self.coordinator, plan,
                                              **kwargs)
        return MultiStreamPuller(self.coordinator, plan, **kwargs)

    # -------------------------------------------------------------- tracing
    def _trace(self, request: ScanRequest):
        return self._traces.get(request.request_id)

    def _trace_close(self, request: ScanRequest, event: str | None = None,
                     base_s: float | None = None) -> None:
        """Commit a request's trace (idempotent) and drop it from the live
        table. ``base_s`` places the scan-relative span groups (per-stream
        clocks, steal epochs) at the grant instant on the gateway clock."""
        ctx = self._traces.pop(request.request_id, None)
        if ctx is None:
            return
        if base_s is not None:
            ctx.base_s = base_s
        if event is not None:
            ctx.instant(event, self.clock_s, cat="gateway")
        ctx.commit()

    # --------------------------------------------------------------- submit
    def submit(self, request: ScanRequest) -> ScanRequest | None:
        """Enqueue a request. Returns the (id-assigned) request, or ``None``
        when it was shed at submit time: the modeled wait ahead of it —
        queued cost that WFQ will serve first, at the calibrated service
        rate — already exceeds its deadline budget."""
        if request.request_id is None:
            request = dataclasses.replace(request, request_id=self._next_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        cstats = self.stats.klass(request.klass)
        cstats.submitted += 1
        if request.deadline_s is not None:
            tag = self.queue.would_finish(request.klass, request.cost_hint)
            est_wait = (max(0.0, self.clock_s - request.arrival_s)
                        + self.queue.backlog_before(tag)
                        * self._service_s_per_cost)
            if est_wait > request.deadline_s:
                cstats.shed += 1
                notify_coordinator(self.coordinator, "qos.shed",
                                   now_s=self.clock_s, klass=request.klass,
                                   client=request.client_id,
                                   reason="deadline-at-submit")
                return None
        if self.tracer is not None:
            ctx = self.tracer.begin(f"scan-{request.request_id}")
            ctx.instant("submit", request.arrival_s, cat="gateway",
                        klass=request.klass, client=request.client_id)
            self._traces[request.request_id] = ctx
        self.queue.push(request, request.klass, request.cost_hint)
        if self._tickets is not None:
            self._tickets.subscribe(self._ticket_key(request),
                                    request.request_id)
        self.stats.queue_depth_max = max(self.stats.queue_depth_max,
                                         len(self.queue))
        return request

    # ------------------------------------------------------------------ run
    def run(self) -> list[ScanResult]:
        """Drain the queue in fair order; returns results in grant order."""
        granted: list[ScanResult] = []
        tickets, preempt = self._tickets, self._preempt
        if tickets is not None:
            tickets.begin_drain()
        while len(self.queue):
            item = (self.queue.pop(self.clock_s) if preempt is not None
                    else self.queue.pop())
            if isinstance(item, _ParkedScan):
                result = self._run_preemptible(item)
                if result is not None:
                    granted.append(result)
                    self.results[item.request.request_id] = result
                continue
            request = item
            if preempt is not None and request.arrival_s > self.clock_s:
                # nothing else had arrived: the gateway idles to the next
                # arrival. Only the arrival-aware pop path models time this
                # way — the plain pop ignores arrivals entirely, and jumping
                # its clock would shed co-queued requests spuriously.
                self.clock_s = request.arrival_s
            cstats = self.stats.klass(request.klass)
            waited = self.clock_s - request.arrival_s
            if request.deadline_s is not None and waited > request.deadline_s:
                cstats.shed += 1          # deadline expired while queued
                if tickets is not None:   # a subscriber cancel
                    tickets.cancel(self._ticket_key(request),
                                   request.request_id)
                self._trace_close(request, "shed")
                notify_coordinator(self.coordinator, "qos.shed",
                                   now_s=self.clock_s, klass=request.klass,
                                   client=request.client_id,
                                   reason="deadline-in-queue")
                continue
            if tickets is not None:
                ticket = tickets.redeem(self._ticket_key(request),
                                        request.request_id)
                if ticket is not None:    # coalesced: multicast, no fan-out
                    result = self._multicast(request, ticket)
                    granted.append(result)
                    self.results[request.request_id] = result
                    continue
            try:
                result = self._execute(request)
            except Backpressure:
                # a coordinator-level admission denial (a gateway-bypassing
                # config); treat as a shed rather than crashing the drain
                cstats.shed += 1
                if tickets is not None:
                    tickets.cancel(self._ticket_key(request),
                                   request.request_id)
                self._trace_close(request, "shed")
                notify_coordinator(self.coordinator, "qos.backpressure",
                                   now_s=self.clock_s, klass=request.klass,
                                   client=request.client_id)
                continue
            except Exception:
                # one malformed request (bad SQL, unknown dataset, an
                # impossible num_streams hint) must not abort the drain and
                # take every other client's queued work with it
                cstats.failed += 1
                if tickets is not None:
                    tickets.cancel(self._ticket_key(request),
                                   request.request_id)
                self._trace_close(request, "failed")
                notify_coordinator(self.coordinator, "qos.failed",
                                   now_s=self.clock_s, klass=request.klass,
                                   client=request.client_id)
                continue
            if result is None:            # parked mid-scan; re-queued
                continue
            granted.append(result)
            self.results[request.request_id] = result
        self.stats.makespan_s = self.clock_s
        if self.admission is not None:
            admission_stats = self.admission.stats
            self.stats.throttle_wait_s = admission_stats.throttle_wait_s
            self.stats.admission = admission_stats   # per-shard when sharded
        return granted

    def result(self, request_id: int) -> ScanResult | None:
        return self.results.get(request_id)

    # -------------------------------------------------------------- execute
    def _apply_start(self, plan: ScanPlan,
                     start_batch: int) -> tuple[ScanPlan, int]:
        """Push a global resume offset down into the plan when the layout
        allows it. Replica plans slice contiguous ranges, so the offset
        intersects exactly (no wasted transport); shard plans interleave, so
        the offset is applied by trimming the reassembled head instead."""
        if start_batch <= 0 or plan.placement != "replica":
            return plan, max(0, start_batch)
        endpoints = []
        for ep in plan.endpoints:
            if ep.max_batches is None:
                endpoints.append(ep)
                continue
            end = ep.start_batch + ep.max_batches
            lo = max(ep.start_batch, start_batch)
            if lo < end:
                endpoints.append(dataclasses.replace(
                    ep, start_batch=lo, max_batches=end - lo))
        return dataclasses.replace(plan, endpoints=tuple(endpoints)), 0

    def _plan(self, request: ScanRequest) -> tuple[ScanPlan, int]:
        quota = self._quota()
        num_streams = request.num_streams
        if (quota is not None and
                self.coordinator.placement_mode(request.dataset) == "replica"):
            # replica fan-out is elastic: plan no wider than the quota
            hosts = len(self.coordinator.hosts(request.dataset))
            num_streams = min(num_streams or hosts, quota)
        plan = self.coordinator.plan(request.sql, request.dataset,
                                     num_streams=num_streams)
        return self._apply_start(plan, request.start_batch)

    def _charge_leases(self, plan: ScanPlan) -> float:
        """Token-bucket wait for one lease per stream the fan-out opens.
        A sharded controller meters each endpoint against its own server's
        bucket (``lease_wait_for_counts``); the per-shard grants run
        concurrently, so the charged wait is the slowest shard's (two
        endpoints on one shard still serialize on that shard's bucket)."""
        adm = self.admission
        sharded = getattr(adm, "lease_wait_for_counts", None)
        if sharded is not None:
            counts: dict[str, int] = {}
            for ep in plan.endpoints:
                counts[ep.server_id] = counts.get(ep.server_id, 0) + 1
            return sharded(self.clock_s, counts)
        return adm.lease_wait_s(self.clock_s, len(plan.endpoints))

    def _execute(self, request: ScanRequest) -> ScanResult | None:
        ctx = self._trace(request)
        plan, trim = self._plan(request)
        queue_wait = self.clock_s - request.arrival_s
        if self.admission is not None:
            # one lease token per stream the fan-out opens
            lease_wait = self._charge_leases(plan)
            if ctx is not None and lease_wait > 0.0:
                ctx.span("admission.lease", self.clock_s, lease_wait,
                         cat="admission", streams=len(plan.endpoints))
            self.clock_s += lease_wait
        if ctx is not None and queue_wait > 0.0:
            ctx.span("queue.wait", request.arrival_s, queue_wait,
                     cat="queue", klass=request.klass)
        grant_latency = self.clock_s - request.arrival_s
        puller = self._make_puller(plan, request.client_id, trace=ctx)
        preempt = self._preempt
        if (preempt is not None and preempt.applies_to(request.klass)
                and self._outweighed(request.klass)):
            scan = PreemptibleScan(puller, copy_batch=_copy_batch)
            return self._run_preemptible(
                _ParkedScan(request, scan, plan, grant_latency, trim))
        per_stream: list[list[RecordBatch]] = [[] for _ in plan.endpoints]

        def sink(idx: int, batch: RecordBatch) -> None:
            while len(per_stream) <= idx:   # stolen streams grow the table
                per_stream.append([])
            per_stream[idx].append(
                _copy_batch(batch) if self.pool is not None else batch)

        grant_clock_s = self.clock_s
        cluster = puller.run(sink)
        service = self._service_time(cluster.streams, start_s=grant_clock_s)
        self.clock_s += service
        endpoints = tuple(p.endpoint for p in puller.pullers)
        batches = reassemble(plan, per_stream, endpoints)[trim:]
        if ctx is not None:
            ctx.span("reassemble", self.clock_s, 0.0, cat="gateway",
                     batches=len(batches))
            self._trace_close(request, base_s=grant_clock_s)
        return self._finalize(request, batches, cluster, grant_latency,
                              service)

    def _outweighed(self, klass: str) -> bool:
        """Someone configured above this class's weight might preempt it."""
        w = self.queue.weight(klass)
        return any(c.weight > w for c in self.queue.classes.values())

    # --------------------------------------------------------- sched paths
    def _run_preemptible(self, parked: _ParkedScan) -> ScanResult | None:
        """Drive (or resume) a parkable scan; returns ``None`` when it was
        parked again (its continuation is back in the queue) or shed."""
        request, scan = parked.request, parked.scan
        cstats = self.stats.klass(request.klass)
        preempt = self._preempt
        if scan.parked:
            try:
                scan.resume()
            except Backpressure:
                # the budget moved against us while parked; the scan cannot
                # hold half a result forever — shed it and free everything
                cstats.shed += 1
                scan.abandon()
                if self._tickets is not None:   # a subscriber cancel
                    self._tickets.cancel(self._ticket_key(request),
                                         request.request_id)
                self._trace_close(request, "shed",
                                  base_s=(request.arrival_s
                                          + parked.grant_latency_s))
                notify_coordinator(self.coordinator, "qos.backpressure",
                                   now_s=self.clock_s, klass=request.klass,
                                   client=request.client_id,
                                   reason="resume-denied")
                return None
        rounds = 0
        while not scan.done:
            self.clock_s += scan.run_round()
            scan.rebalance()             # stealing composes with preemption
            rounds += 1
            if (not scan.done
                    and rounds >= preempt.min_rounds_before_park
                    and self.queue.has_preemptor(request.klass,
                                                 self.clock_s)):
                scan.park()
                cstats.preemptions += 1
                self.queue.push(parked, request.klass,
                                parked.residual_cost())
                self.stats.queue_depth_max = max(self.stats.queue_depth_max,
                                                 len(self.queue))
                return None
        cluster = scan.stats()
        # the rounds advanced the clock by unconstrained critical-path
        # deltas (scan.elapsed_s telescopes to the critical path); a stream
        # quota serializes lanes exactly like the one-shot path, so charge
        # the serialization remainder now
        service = max(scan.elapsed_s, self._service_time(cluster.streams))
        self.clock_s += service - scan.elapsed_s
        endpoints = tuple(p.endpoint for p in scan.puller.pullers)
        batches = reassemble(parked.plan, scan.per_stream,
                             endpoints)[parked.trim:]
        ctx = self._trace(request)
        if ctx is not None:
            ctx.span("reassemble", self.clock_s, 0.0, cat="gateway",
                     batches=len(batches))
            self._trace_close(request,
                              base_s=(request.arrival_s
                                      + parked.grant_latency_s))
        return self._finalize(request, batches, cluster,
                              parked.grant_latency_s, service,
                              preemptions=scan.park_count)

    def _multicast(self, request: ScanRequest, ticket: Ticket) -> ScanResult:
        """Serve a coalesced subscriber from the published ticket: each
        subscriber reads its own deep copy (copy-on-read), is attributed
        granted batches/bytes in its own class, and consumes **zero**
        additional server-side service — the multicast copy is client-side,
        off the modeled critical path."""
        grant_latency = self.clock_s - request.arrival_s
        batches = [_copy_batch(b) for b in ticket.batches]
        cstats = self.stats.klass(request.klass)
        cstats.granted += 1
        cstats.ticket_hits += 1
        cstats.grant_latency_s.append(grant_latency)
        cstats.bytes += getattr(ticket.cluster, "bytes", 0)
        cstats.batches += len(batches)
        self._trace_close(request, "ticket.hit")
        return ScanResult(request, batches, ticket.cluster, grant_latency,
                          0.0, shared=True)

    # ------------------------------------------------------------- finalize
    def _finalize(self, request: ScanRequest, batches: list[RecordBatch],
                  cluster: ClusterStats, grant_latency: float,
                  service: float, preemptions: int = 0) -> ScanResult:
        cstats = self.stats.klass(request.klass)
        cstats.granted += 1
        cstats.grant_latency_s.append(grant_latency)
        cstats.service_s += service
        cstats.bytes += cluster.bytes
        cstats.batches += cluster.batches
        self.stats.cluster.append(cluster)
        # refine the cost->seconds calibration (EMA over served requests)
        observed = service / max(request.cost_hint, 1e-12)
        self._service_s_per_cost = (0.5 * self._service_s_per_cost
                                    + 0.5 * observed)
        if self._tickets is not None:
            self._tickets.publish(self._ticket_key(request),
                                  request.request_id, batches, cluster)
        return ScanResult(request, batches, cluster, grant_latency, service,
                          preemptions=preemptions)
