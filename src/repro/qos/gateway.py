"""The scan gateway: request-level scatter-gather behind admission control.

One logical request enters as a :class:`ScanRequest` and leaves as a
:class:`ScanResult` whose batches are in **global scan order** — the gateway
plans the query across shard/replica servers, pulls every endpoint
concurrently through :class:`~repro.cluster.streams.MultiStreamPuller`, and
reassembles the per-stream deliveries (scatter-gather at the request level,
not just the batch level). Between submit and grant sit the two QoS layers:

* the :class:`~.queue.WeightedFairQueue` orders grants across client
  classes (interactive > batch) and sheds requests whose modeled wait
  exceeds their deadline budget;
* the :class:`~.admission.AdmissionController` meters lease grants with a
  token bucket (one token per stream the fan-out opens) and caps each
  client's *effective parallelism* at its stream quota — a quota-capped
  request still sees every shard (nothing is silently dropped), its streams
  are just serialized onto ``quota`` modeled lanes.

Time is modeled: the gateway runs a deterministic clock that advances by
each request's modeled service time, so grant latency / shedding / fairness
comparisons reproduce exactly under any machine load. The coordinator handed
to a gateway should **not** carry its own admission controller — the gateway
already meters at request granularity, and per-stream metering underneath it
would double-charge the bucket.
"""
from __future__ import annotations

import dataclasses

from ..cluster.mempool import BufferPool
from ..cluster.plan import ScanPlan
from ..cluster.coordinator import ClusterCoordinator
from ..cluster.streams import ClusterStats, MultiStreamPuller
from ..core.recordbatch import RecordBatch
from .admission import AdmissionController, Backpressure
from .metrics import QosStats
from .queue import ClientClass, FifoQueue, WeightedFairQueue


@dataclasses.dataclass
class ScanRequest:
    """One logical scan: what a client submits to the gateway."""

    client_id: str
    klass: str                      # client-class name (queue weight lookup)
    sql: str
    dataset: str
    request_id: int | None = None   # assigned by the gateway when None
    cost_hint: float = 1.0          # relative service estimate (WFQ units)
    deadline_s: float | None = None  # shed if modeled wait exceeds this
    arrival_s: float = 0.0          # modeled arrival time
    num_streams: int | None = None  # fan-out hint (replica placement)


@dataclasses.dataclass
class ScanResult:
    request: ScanRequest
    batches: list[RecordBatch]      # reassembled in global scan order
    cluster: ClusterStats
    grant_latency_s: float          # modeled submit -> grant
    service_s: float                # modeled execution (quota-capped makespan)


def reassemble(plan: ScanPlan, per_stream: list[list[RecordBatch]]
               ) -> list[RecordBatch]:
    """Merge per-stream deliveries back into global scan order.

    * ``replica`` plans slice the batch range contiguously — concatenate
      streams by ``start_batch``.
    * ``shard`` plans come from :meth:`ClusterCoordinator.place_shards`,
      which deals ``batches[i::n]`` to the i-th sorted server, so stream
      *i*'s j-th batch is global batch ``j*n + i`` — re-interleave.
    """
    if plan.placement == "replica":
        order = sorted(range(len(plan.endpoints)),
                       key=lambda i: plan.endpoints[i].start_batch)
        return [b for i in order for b in per_stream[i]]
    out: list[RecordBatch] = []
    j = 0
    while True:
        row = [s[j] for s in per_stream if j < len(s)]
        if not row:
            return out
        out.extend(row)
        j += 1


def _copy_batch(batch: RecordBatch) -> RecordBatch:
    """Deep copy out of pooled buffers (they recycle on the next pull)."""
    cols = tuple(dataclasses.replace(
        c, values=c.values.copy(),
        offsets=None if c.offsets is None else c.offsets.copy(),
        validity=None if c.validity is None else c.validity.copy())
        for c in batch.columns)
    return RecordBatch(batch.schema, cols)


def _makespan(clock_s: list[float], parallelism: int | None) -> float:
    """Modeled completion time of the fan-out under a concurrency cap:
    longest-processing-time greedy assignment of stream clocks onto
    ``parallelism`` lanes. With no cap this is the plain critical path."""
    if parallelism is None or parallelism >= len(clock_s):
        return max(clock_s, default=0.0)
    lanes = [0.0] * max(1, parallelism)
    for c in sorted(clock_s, reverse=True):
        idx = min(range(len(lanes)), key=lanes.__getitem__)
        lanes[idx] += c
    return max(lanes)


class ScanGateway:
    """Admission-controlled front door for every scan against the cluster."""

    def __init__(self, coordinator: ClusterCoordinator,
                 classes: list[ClientClass] | None = None,
                 admission: AdmissionController | None = None,
                 pool: BufferPool | None = None, fair: bool = True,
                 lease_batches: int = 1, prefetch: bool = True,
                 est_service_s_per_cost: float = 1e-4):
        self.coordinator = coordinator
        self.admission = admission
        self.pool = pool
        self.lease_batches = lease_batches
        self.prefetch = prefetch
        self.queue = WeightedFairQueue(classes) if fair else FifoQueue()
        self.stats = QosStats()
        self.results: dict[int, ScanResult] = {}
        self.clock_s = 0.0
        self._next_id = 0
        # calibration: WFQ cost units -> modeled seconds, refined as we serve
        self._service_s_per_cost = est_service_s_per_cost

    # --------------------------------------------------------------- submit
    def submit(self, request: ScanRequest) -> ScanRequest | None:
        """Enqueue a request. Returns the (id-assigned) request, or ``None``
        when it was shed at submit time: the modeled wait ahead of it —
        queued cost that WFQ will serve first, at the calibrated service
        rate — already exceeds its deadline budget."""
        if request.request_id is None:
            request = dataclasses.replace(request, request_id=self._next_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        cstats = self.stats.klass(request.klass)
        cstats.submitted += 1
        if request.deadline_s is not None:
            tag = self.queue.would_finish(request.klass, request.cost_hint)
            est_wait = (max(0.0, self.clock_s - request.arrival_s)
                        + self.queue.backlog_before(tag)
                        * self._service_s_per_cost)
            if est_wait > request.deadline_s:
                cstats.shed += 1
                return None
        self.queue.push(request, request.klass, request.cost_hint)
        self.stats.queue_depth_max = max(self.stats.queue_depth_max,
                                         len(self.queue))
        return request

    # ------------------------------------------------------------------ run
    def run(self) -> list[ScanResult]:
        """Drain the queue in fair order; returns results in grant order."""
        granted: list[ScanResult] = []
        while len(self.queue):
            request = self.queue.pop()
            cstats = self.stats.klass(request.klass)
            waited = self.clock_s - request.arrival_s
            if request.deadline_s is not None and waited > request.deadline_s:
                cstats.shed += 1          # deadline expired while queued
                continue
            try:
                result = self._execute(request)
            except Backpressure:
                # a coordinator-level admission denial (a gateway-bypassing
                # config); treat as a shed rather than crashing the drain
                cstats.shed += 1
                continue
            except Exception:
                # one malformed request (bad SQL, unknown dataset, an
                # impossible num_streams hint) must not abort the drain and
                # take every other client's queued work with it
                cstats.failed += 1
                continue
            granted.append(result)
            self.results[request.request_id] = result
        self.stats.makespan_s = self.clock_s
        if self.admission is not None:
            self.stats.throttle_wait_s = self.admission.stats.throttle_wait_s
        return granted

    def result(self, request_id: int) -> ScanResult | None:
        return self.results.get(request_id)

    # -------------------------------------------------------------- execute
    def _execute(self, request: ScanRequest) -> ScanResult:
        quota = (self.admission.config.max_streams_per_client
                 if self.admission is not None else None)
        num_streams = request.num_streams
        if (quota is not None and
                self.coordinator.placement_mode(request.dataset) == "replica"):
            # replica fan-out is elastic: plan no wider than the quota
            hosts = len(self.coordinator.hosts(request.dataset))
            num_streams = min(num_streams or hosts, quota)
        plan = self.coordinator.plan(request.sql, request.dataset,
                                     num_streams=num_streams)
        if self.admission is not None:
            # one lease token per stream the fan-out opens
            self.clock_s += self.admission.lease_wait_s(
                self.clock_s, len(plan.endpoints))
        grant_latency = self.clock_s - request.arrival_s
        puller = MultiStreamPuller(
            self.coordinator, plan, pool=self.pool,
            lease_batches=self.lease_batches, prefetch=self.prefetch,
            client_id=request.client_id)
        per_stream: list[list[RecordBatch]] = [[] for _ in plan.endpoints]

        def sink(idx: int, batch: RecordBatch) -> None:
            per_stream[idx].append(
                _copy_batch(batch) if self.pool is not None else batch)

        cluster = puller.run(sink)
        service = _makespan([s.clock_s for s in cluster.streams], quota)
        self.clock_s += service
        cstats = self.stats.klass(request.klass)
        cstats.granted += 1
        cstats.grant_latency_s.append(grant_latency)
        cstats.service_s += service
        cstats.bytes += cluster.bytes
        cstats.batches += cluster.batches
        self.stats.cluster.append(cluster)
        # refine the cost->seconds calibration (EMA over served requests)
        observed = service / max(request.cost_hint, 1e-12)
        self._service_s_per_cost = (0.5 * self._service_s_per_cost
                                    + 0.5 * observed)
        return ScanResult(request, reassemble(plan, per_stream), cluster,
                          grant_latency, service)
