"""Admission control: who may open streams, and how fast leases are granted.

The cluster dataplane (PR 1) lets any client open unbounded streams against
the coordinator — exactly the regime where Flight-style servers add
admission control ("Benchmarking Apache Arrow Flight", arXiv:2204.03032)
and RDMA engines schedule exchange explicitly (arXiv:1502.07169): every
stream pins registered memory server-side and holds a reader-map lease, so
an unthrottled heavy client can exhaust both. This module is the gatekeeper:

* **per-client stream quotas** — :meth:`AdmissionController.acquire_stream`
  counts concurrently open streams per client and raises
  :class:`Backpressure` (with a ``retry_after_s`` hint) at the quota;
* **registered-memory budget** — derived from the
  :class:`~repro.cluster.mempool.BufferPool` budget when a pool is attached:
  a pool already over its slab budget denies new streams until releases or
  evictions bring it back under;
* **token-bucket lease rate** — :meth:`lease_wait_s` meters lease grants in
  *modeled* time (the repo's wire is modeled, so its flow control is too):
  a grant beyond the burst capacity returns the modeled wait the caller must
  charge to its clock, which is how pullers report backpressure upstream.

Everything here is duck-typed against the cluster layer (no imports from
:mod:`repro.cluster`), so the coordinator can hold an admission controller
without creating an import cycle.
"""
from __future__ import annotations

import dataclasses


class Backpressure(Exception):
    """The admission controller denied a grant; retry after ``retry_after_s``.

    Raised instead of queueing when the caller owns its own retry loop (the
    loader, an external client). The gateway never lets this escape — it
    queues or sheds instead.
    """

    def __init__(self, reason: str, retry_after_s: float = 0.0):
        super().__init__(f"{reason} (retry after {retry_after_s * 1e3:.3f} ms)")
        self.reason = reason
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class AdmissionConfig:
    max_streams_per_client: int | None = None   # None == unlimited
    memory_budget_bytes: int | None = None      # None == derive from pool
    lease_rate_per_s: float | None = None       # token refill; None == open
    lease_burst: int = 8                        # bucket capacity (tokens)
    retry_after_hint_s: float = 1e-3            # Backpressure retry hint


@dataclasses.dataclass
class AdmissionStats:
    stream_grants: int = 0
    stream_denials: int = 0          # quota Backpressure raised
    memory_denials: int = 0          # budget Backpressure raised
    lease_grants: int = 0            # token-bucket grants (incl. waited)
    throttle_wait_s: float = 0.0     # modeled wait charged by the bucket


class AdmissionController:
    """Stream quotas + memory budget + token-bucket lease metering.

    ``pool`` is the client-side :class:`~repro.cluster.mempool.BufferPool`
    whose registered-slab budget backs the memory check (duck-typed: anything
    with ``max_bytes`` and ``stats.bytes_resident`` works).
    """

    def __init__(self, config: AdmissionConfig | None = None, pool=None):
        self.config = config or AdmissionConfig()
        self.pool = pool
        self.stats = AdmissionStats()
        self._active: dict[str, int] = {}        # client_id -> open streams
        self._tokens = float(self.config.lease_burst)
        self._bucket_clock_s = 0.0               # modeled time of last refill

    # ------------------------------------------------------------- streams
    def active_streams(self, client_id: str = "default") -> int:
        return self._active.get(client_id, 0)

    def acquire_stream(self, client_id: str = "default") -> None:
        """Grant one concurrent stream to ``client_id`` or raise
        :class:`Backpressure`. Pairs with :meth:`release_stream`."""
        quota = self.config.max_streams_per_client
        if quota is not None and self.active_streams(client_id) >= quota:
            self.stats.stream_denials += 1
            raise Backpressure(
                f"client {client_id!r} at stream quota ({quota})",
                self.config.retry_after_hint_s)
        budget = self.memory_budget_bytes
        if (budget is not None and self.pool is not None
                and self.pool.stats.bytes_resident > budget):
            self.stats.memory_denials += 1
            raise Backpressure(
                f"registered-memory budget exhausted "
                f"({self.pool.stats.bytes_resident} > {budget} bytes)",
                self.config.retry_after_hint_s)
        self._active[client_id] = self.active_streams(client_id) + 1
        self.stats.stream_grants += 1

    def release_stream(self, client_id: str = "default") -> None:
        n = self.active_streams(client_id)
        if n > 0:
            self._active[client_id] = n - 1

    # -------------------------------------------------------------- memory
    @property
    def memory_budget_bytes(self) -> int | None:
        if self.config.memory_budget_bytes is not None:
            return self.config.memory_budget_bytes
        if self.pool is not None:
            return getattr(self.pool, "max_bytes", None)
        return None

    # --------------------------------------------------------- token bucket
    def lease_wait_s(self, now_s: float, n: int = 1) -> float:
        """Grant ``n`` lease tokens at modeled time ``now_s``; return the
        modeled wait before the grant fires (0.0 when the bucket covers it).

        Callers charge the wait to their own modeled clock — streams run on
        per-stream clocks, so ``now_s`` may jump backwards between callers;
        the bucket only refills on forward motion."""
        self.stats.lease_grants += n
        rate = self.config.lease_rate_per_s
        if rate is None or rate <= 0:
            return 0.0
        if now_s > self._bucket_clock_s:
            self._tokens = min(float(self.config.lease_burst),
                               self._tokens + (now_s - self._bucket_clock_s) * rate)
            self._bucket_clock_s = now_s
        if self._tokens >= n:
            self._tokens -= n
            return 0.0
        wait = (n - self._tokens) / rate
        self._tokens = 0.0
        self._bucket_clock_s = max(self._bucket_clock_s, now_s) + wait
        self.stats.throttle_wait_s += wait
        return wait
