"""Admission control: who may open streams, and how fast leases are granted.

The cluster dataplane (PR 1) lets any client open unbounded streams against
the coordinator — exactly the regime where Flight-style servers add
admission control ("Benchmarking Apache Arrow Flight", arXiv:2204.03032)
and RDMA engines schedule exchange explicitly (arXiv:1502.07169): every
stream pins registered memory server-side and holds a reader-map lease, so
an unthrottled heavy client can exhaust both. This module is the gatekeeper:

* **per-client stream quotas** — :meth:`AdmissionController.acquire_stream`
  counts concurrently open streams per client and raises
  :class:`Backpressure` (with a ``retry_after_s`` hint) at the quota;
* **registered-memory budget** — derived from the
  :class:`~repro.cluster.mempool.BufferPool` budget when a pool is attached:
  a pool already over its slab budget denies new streams until releases or
  evictions bring it back under;
* **token-bucket lease rate** — :meth:`lease_wait_s` meters lease grants in
  *modeled* time (the repo's wire is modeled, so its flow control is too):
  a grant beyond the burst capacity returns the modeled wait the caller must
  charge to its clock, which is how pullers report backpressure upstream.

A ``max_streams_total`` cap bounds concurrent streams across *all* clients
(the reader-map's physical ceiling); freed slots fire ``subscribe_release``
callbacks so the gateway can re-plan in-flight fan-outs. The quota checks
route through overridable ``_client_quota`` / ``_total_cap`` hooks — that is
the seam :mod:`repro.qos.distributed` shards the budget on without forking
the grant path.

Everything here is duck-typed against the cluster layer (no imports from
:mod:`repro.cluster`), so the coordinator can hold an admission controller
without creating an import cycle. The duck-typed contract (what the
coordinator and pullers call): ``acquire_stream(client_id, server_id=)``,
``release_stream(client_id, server_id=, now_s=)`` and
``lease_wait_s(now_s, n, server_id=)`` — a custom controller must accept
the routing keywords even if (like this one) it ignores them; only the
sharded controller routes on them.
"""
from __future__ import annotations

import dataclasses


class Backpressure(Exception):
    """The admission controller denied a grant; retry after ``retry_after_s``.

    Raised instead of queueing when the caller owns its own retry loop (the
    loader, an external client). The gateway never lets this escape — it
    queues or sheds instead.
    """

    def __init__(self, reason: str, retry_after_s: float = 0.0):
        super().__init__(f"{reason} (retry after {retry_after_s * 1e3:.3f} ms)")
        self.reason = reason
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class AdmissionConfig:
    max_streams_per_client: int | None = None   # None == unlimited
    max_streams_total: int | None = None        # global cap across clients
    memory_budget_bytes: int | None = None      # None == derive from pool
    lease_rate_per_s: float | None = None       # token refill; None == open
    lease_burst: int = 8                        # bucket capacity (tokens)
    retry_after_hint_s: float = 1e-3            # Backpressure retry hint


@dataclasses.dataclass
class AdmissionStats:
    stream_grants: int = 0
    stream_denials: int = 0          # quota Backpressure raised
    total_denials: int = 0           # global-cap Backpressure raised
    memory_denials: int = 0          # budget Backpressure raised
    lease_grants: int = 0            # token-bucket grants (incl. waited)
    throttle_wait_s: float = 0.0     # modeled wait charged by the bucket
    peak_active: int = 0             # high-water mark of concurrent streams


class AdmissionController:
    """Stream quotas + memory budget + token-bucket lease metering.

    ``pool`` is the client-side :class:`~repro.cluster.mempool.BufferPool`
    whose registered-slab budget backs the memory check (duck-typed: anything
    with ``max_bytes`` and ``stats.bytes_resident`` works).
    """

    def __init__(self, config: AdmissionConfig | None = None, pool=None):
        self.config = config or AdmissionConfig()
        self.pool = pool
        self.stats = AdmissionStats()
        self._active: dict[str, int] = {}        # client_id -> open streams
        self._tokens = float(self.config.lease_burst)
        self._bucket_clock_s = 0.0               # modeled time of last refill
        self._release_cbs: list = []             # freed-slot listeners

    # ------------------------------------------------------------- streams
    def active_streams(self, client_id: str = "default") -> int:
        return self._active.get(client_id, 0)

    def active_total(self) -> int:
        """Concurrently open streams across every client."""
        return sum(self._active.values())

    # ------------------------------------------------------------ telemetry
    def metrics(self) -> "MetricsRegistry":
        """This controller's counters plus live occupancy gauges, under
        the ``qos.admission.*`` namespace of a fresh registry."""
        from ..obs.registry import MetricsRegistry, record_admission
        reg = MetricsRegistry()
        record_admission(reg, self.stats)
        reg.gauge("qos.admission.active_total", self.active_total())
        for client_id, n in self._active.items():
            reg.gauge(f"qos.admission.active.{client_id}", n)
        return reg

    # Overridable limit hooks: the distributed layer's shards re-read these
    # from their borrow-adjusted local capacities; everything else in the
    # grant path is shared, so a one-shard deployment is grant-for-grant
    # identical to this controller (the conformance suite's invariant).
    def _client_quota(self, client_id: str) -> int | None:
        return self.config.max_streams_per_client

    def _total_cap(self) -> int | None:
        return self.config.max_streams_total

    def _deny_reason(self, client_id: str) -> str | None:
        """Would :meth:`acquire_stream` deny right now? Returns the denial
        kind (``"quota"`` / ``"total"`` / ``"memory"``) without touching any
        stats — the distributed layer peeks before deciding to borrow."""
        quota = self._client_quota(client_id)
        if quota is not None and self.active_streams(client_id) >= quota:
            return "quota"
        cap = self._total_cap()
        if cap is not None and self.active_total() >= cap:
            return "total"
        budget = self.memory_budget_bytes
        if (budget is not None and self.pool is not None
                and self.pool.stats.bytes_resident > budget):
            return "memory"
        return None

    def headroom(self, server_id: str | None = None,
                 client_id: str = "default") -> int | None:
        """Streams this controller could still grant ``client_id`` right
        now, or ``None`` when unlimited. ``server_id`` is interface parity
        with the sharded controller (which answers for that server's shard
        alone); a centralized budget has one answer for every server. The
        steal scheduler's thief-side check reads this through
        :meth:`ClusterCoordinator.admission_headroom`."""
        slacks = []
        quota = self._client_quota(client_id)
        if quota is not None:
            slacks.append(quota - self.active_streams(client_id))
        cap = self._total_cap()
        if cap is not None:
            slacks.append(cap - self.active_total())
        return min(slacks) if slacks else None

    def acquire_stream(self, client_id: str = "default",
                       server_id: str | None = None) -> None:
        """Grant one concurrent stream to ``client_id`` or raise
        :class:`Backpressure`. Pairs with :meth:`release_stream`.
        ``server_id`` is accepted for interface parity with the sharded
        controller (which routes the check to that server's shard) and
        ignored here — one process holds the whole budget.

        The verdict comes from :meth:`_deny_reason` — the ONE place the
        checks live, so the sharded borrow loop (which peeks the reason
        before borrowing, then calls this) can never disagree with the
        grant path."""
        reason = self._deny_reason(client_id)
        if reason == "quota":
            self.stats.stream_denials += 1
            raise Backpressure(
                f"client {client_id!r} at stream quota "
                f"({self._client_quota(client_id)})",
                self.config.retry_after_hint_s)
        if reason == "total":
            self.stats.total_denials += 1
            raise Backpressure(
                f"cluster at global stream cap ({self._total_cap()})",
                self.config.retry_after_hint_s)
        if reason == "memory":
            self.stats.memory_denials += 1
            raise Backpressure(
                f"registered-memory budget exhausted "
                f"({self.pool.stats.bytes_resident} > "
                f"{self.memory_budget_bytes} bytes)",
                self.config.retry_after_hint_s)
        self._active[client_id] = self.active_streams(client_id) + 1
        self.stats.stream_grants += 1
        self.stats.peak_active = max(self.stats.peak_active,
                                     self.active_total())

    def subscribe_release(self, callback) -> None:
        """Register ``callback(server_id, client_id, now_s)`` to fire on
        every freed stream slot — the signal the gateway's
        ``replan_on_release`` hook widens in-flight fan-outs on."""
        self._release_cbs.append(callback)

    def unsubscribe_release(self, callback) -> None:
        """Remove a freed-slot listener. Short-lived subscribers (one scan's
        steal scheduler) MUST unsubscribe when done — a long-lived
        controller outlives thousands of them, and the listener list is
        walked on every release."""
        try:
            self._release_cbs.remove(callback)
        except ValueError:
            pass                       # already removed: idempotent

    def release_stream(self, client_id: str = "default",
                       server_id: str | None = None,
                       now_s: float | None = None) -> None:
        n = self.active_streams(client_id)
        if n > 0:
            self._active[client_id] = n - 1
            for cb in self._release_cbs:
                cb(server_id, client_id, now_s)

    # -------------------------------------------------------------- memory
    @property
    def memory_budget_bytes(self) -> int | None:
        if self.config.memory_budget_bytes is not None:
            return self.config.memory_budget_bytes
        if self.pool is not None:
            return getattr(self.pool, "max_bytes", None)
        return None

    # --------------------------------------------------------- token bucket
    def _refill(self, now_s: float) -> None:
        """Advance the bucket to ``now_s`` (forward motion only)."""
        rate = self.config.lease_rate_per_s
        if rate is None or rate <= 0:
            return
        if now_s > self._bucket_clock_s:
            self._tokens = min(float(self.config.lease_burst),
                               self._tokens
                               + (now_s - self._bucket_clock_s) * rate)
            self._bucket_clock_s = now_s

    def tokens_at(self, now_s: float) -> float:
        """Tokens the bucket would hold at ``now_s``, without mutating it —
        the distributed reconciler's conservation bookkeeping reads this."""
        rate = self.config.lease_rate_per_s
        if rate is None or rate <= 0 or now_s <= self._bucket_clock_s:
            return self._tokens
        return min(float(self.config.lease_burst),
                   self._tokens + (now_s - self._bucket_clock_s) * rate)

    def lease_wait_s(self, now_s: float, n: int = 1,
                     server_id: str | None = None) -> float:
        """Grant ``n`` lease tokens at modeled time ``now_s``; return the
        modeled wait before the grant fires (0.0 when the bucket covers it).

        Callers charge the wait to their own modeled clock — streams run on
        per-stream clocks, so ``now_s`` may jump backwards between callers;
        the bucket only refills on forward motion. ``server_id`` is for
        interface parity with the sharded controller (ignored here)."""
        self.stats.lease_grants += n
        rate = self.config.lease_rate_per_s
        if rate is None or rate <= 0:
            return 0.0
        self._refill(now_s)
        if self._tokens >= n:
            self._tokens -= n
            return 0.0
        wait = (n - self._tokens) / rate
        self._tokens = 0.0
        self._bucket_clock_s = max(self._bucket_clock_s, now_s) + wait
        self.stats.throttle_wait_s += wait
        return wait
