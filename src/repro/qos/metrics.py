"""QoS observability: per-class service metrics that compose with ClusterStats.

Every granted request carries its own
:class:`~repro.cluster.streams.ClusterStats` (the dataplane decomposition);
:class:`QosStats` is the layer above — queue depth, grant latency, shed
counts and per-class throughput — so a benchmark row can report "interactive
p50 grant latency under heavy batch load" next to "bytes over the wire" from
one object.

Latencies and service times are **modeled seconds** (the gateway's clock),
which keeps every fairness comparison deterministic under any machine load —
the same trick :attr:`ClusterStats.modeled_critical_path_s` uses.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

from ..obs.registry import MetricsRegistry, record_qos

if TYPE_CHECKING:   # avoid a hard qos -> cluster import edge for typing only
    from ..cluster.streams import ClusterStats


def _quantile(values: list[float], q: float) -> float:
    if not values:
        return 0.0
    ordered = sorted(values)
    idx = min(len(ordered) - 1, max(0, int(round(q * (len(ordered) - 1)))))
    return ordered[idx]


@dataclasses.dataclass
class ClassStats:
    """One client class's view of the gateway."""

    name: str
    submitted: int = 0
    granted: int = 0
    shed: int = 0                  # deadline-based rejections
    failed: int = 0                # malformed requests (planner/exec errors)
    grant_latency_s: list[float] = dataclasses.field(default_factory=list)
    service_s: float = 0.0         # modeled service time consumed
    bytes: int = 0                 # from the per-request ClusterStats
    batches: int = 0
    ticket_hits: int = 0           # served by shared-ticket multicast
    preemptions: int = 0           # parked at a lease boundary (sched)

    @property
    def p50_grant_latency_s(self) -> float:
        return _quantile(self.grant_latency_s, 0.5)

    @property
    def p99_grant_latency_s(self) -> float:
        return _quantile(self.grant_latency_s, 0.99)

    @property
    def mean_grant_latency_s(self) -> float:
        """Mean grant latency; 0.0 with no samples (a class that was shed
        wholesale must not take the report down)."""
        if not self.grant_latency_s:
            return 0.0
        return sum(self.grant_latency_s) / len(self.grant_latency_s)

    @property
    def max_grant_latency_s(self) -> float:
        return max(self.grant_latency_s, default=0.0)

    @property
    def throughput_bytes_per_s(self) -> float:
        """Class throughput over the service time it actually consumed."""
        return self.bytes / self.service_s if self.service_s > 0 else 0.0

    def throughput_over(self, duration_s: float) -> float:
        """Bytes per second over an externally chosen modeled window (the
        stress driver's fairness window). A zero-width window — a burst
        whose every request shed before any service ran, or a driver
        queried before its first beat — reports 0.0 rather than dividing
        by zero."""
        return self.bytes / duration_s if duration_s > 0 else 0.0

    def merge(self, other: "ClassStats") -> "ClassStats":
        """Fold another run's view of the same class into this one:
        counters add, the latency samples concatenate (so merged
        percentiles are computed over the union, not averaged)."""
        if other.name != self.name:
            raise ValueError(
                f"cannot merge class {other.name!r} into {self.name!r}")
        self.submitted += other.submitted
        self.granted += other.granted
        self.shed += other.shed
        self.failed += other.failed
        self.grant_latency_s.extend(other.grant_latency_s)
        self.service_s += other.service_s
        self.bytes += other.bytes
        self.batches += other.batches
        self.ticket_hits += other.ticket_hits
        self.preemptions += other.preemptions
        return self


@dataclasses.dataclass
class QosStats:
    """Aggregate gateway metrics across classes + the per-request dataplane
    stats they compose with."""

    classes: dict[str, ClassStats] = dataclasses.field(default_factory=dict)
    queue_depth_max: int = 0
    throttle_wait_s: float = 0.0        # token-bucket wait (admission)
    makespan_s: float = 0.0             # gateway clock when the queue drained
    replans: int = 0                    # freed-slot events that widened a
    #                                     quota-capped in-flight fan-out
    alerts: int = 0                     # SLO burn-rate alerts fired against
    #                                     this gateway's heartbeat snapshots
    cluster: list["ClusterStats"] = dataclasses.field(default_factory=list)
    # admission snapshot (duck-typed: AdmissionStats, or the sharded
    # DistributedStats whose .shards dict carries per-shard grant/denial/
    # borrow/reconcile counters — utils/report.admission_table renders it)
    admission: object = None

    def klass(self, name: str) -> ClassStats:
        if name not in self.classes:
            self.classes[name] = ClassStats(name)
        return self.classes[name]

    @property
    def submitted(self) -> int:
        return sum(c.submitted for c in self.classes.values())

    @property
    def granted(self) -> int:
        return sum(c.granted for c in self.classes.values())

    @property
    def shed(self) -> int:
        return sum(c.shed for c in self.classes.values())

    @property
    def failed(self) -> int:
        return sum(c.failed for c in self.classes.values())

    @property
    def bytes(self) -> int:
        return sum(c.bytes for c in self.classes.values())

    @property
    def ticket_hits(self) -> int:
        """Requests served by shared-ticket multicast (no fan-out ran)."""
        return sum(c.ticket_hits for c in self.classes.values())

    @property
    def preemptions(self) -> int:
        """Lease-boundary parks across every class."""
        return sum(c.preemptions for c in self.classes.values())

    @property
    def steals(self) -> int:
        """Work-stealing range migrations across every granted fan-out."""
        return sum(c.steals for c in self.cluster)

    @property
    def declines(self) -> int:
        """Steals refused because the thief's admission shard was at its
        local quota (shard-aware stealing backing off)."""
        return sum(c.declines for c in self.cluster)

    @property
    def re_steals(self) -> int:
        """Stolen tails reclaimed by their original victim after the thief
        degraded (one per range, by construction)."""
        return sum(c.re_steals for c in self.cluster)

    def merge(self, other: "QosStats") -> "QosStats":
        """Fold another gateway's (or run's) stats into this one. Classes
        merge by name — disjoint class sets union cleanly; overlapping
        classes combine via :meth:`ClassStats.merge`. Gauges take the max
        (queue depth, makespan), durations/counters add, and the
        per-request cluster list concatenates so steal attribution and
        the registry roll-up keep seeing every fan-out. The admission
        snapshot is kept from whichever side has one (self wins when
        both do — admission controllers are shared, not additive)."""
        for name, cstats in other.classes.items():
            if name in self.classes:
                self.classes[name].merge(cstats)
            else:
                self.classes[name] = cstats
        self.queue_depth_max = max(self.queue_depth_max,
                                   other.queue_depth_max)
        self.throttle_wait_s += other.throttle_wait_s
        self.makespan_s = max(self.makespan_s, other.makespan_s)
        self.replans += other.replans
        self.alerts += getattr(other, "alerts", 0)
        self.cluster.extend(other.cluster)
        if self.admission is None:
            self.admission = other.admission
        return self

    def registry(self) -> "MetricsRegistry":
        """This stats object snapshotted into a fresh
        :class:`~repro.obs.MetricsRegistry` (the ``qos.*`` namespace)."""
        reg = MetricsRegistry()
        record_qos(reg, self)
        return reg

    def summary(self) -> str:
        """One benchmark-row string: the acceptance-criteria numbers."""
        parts = [f"depth_max={self.queue_depth_max}", f"shed={self.shed}",
                 f"failed={self.failed}",
                 f"throttle_us={self.throttle_wait_s * 1e6:.1f}",
                 f"makespan_us={self.makespan_s * 1e6:.1f}"]
        if self.steals or self.ticket_hits or self.preemptions:
            parts.append(f"steals={self.steals} "
                         f"ticket_hits={self.ticket_hits} "
                         f"preempt={self.preemptions}")
        if self.declines or self.re_steals:
            parts.append(f"declines={self.declines} "
                         f"re_steals={self.re_steals}")
        if self.replans:
            parts.append(f"replans={self.replans}")
        if self.alerts:
            parts.append(f"alerts={self.alerts}")
        shards = getattr(self.admission, "shards", None)
        if shards:
            agg = self.admission
            parts.append(f"shards={len(shards)} borrows={agg.borrows} "
                         f"reconciles={agg.reconciles} "
                         f"peak={agg.peak_total}")
        for name in sorted(self.classes):
            c = self.classes[name]
            parts.append(
                f"{name}[n={c.granted}/{c.submitted} "
                f"p50_grant_us={c.p50_grant_latency_s * 1e6:.1f} "
                f"tput_MBps={c.throughput_bytes_per_s / 1e6:.1f}]")
        return " ".join(parts)
