"""Distributed admission control: per-server quota shards + reconciliation.

One :class:`~.admission.AdmissionController` is a single process — exactly
the coordination bottleneck the Thallus paper moves off the RDMA data path,
and the reason Flight-scale deployments (arXiv:2204.03032) and RDMA exchange
schedulers (arXiv:1502.07169) shard admission state per server and reconcile
it approximately. This module splits the global budget into per-server
shards and keeps the *global* invariants by construction:

* **shards** — each :class:`AdmissionShard` (one per ``ThallusServer``) owns
  a slice of the per-client stream quota, the global stream cap, and the
  lease token bucket. A grant only ever touches the endpoint's shard — no
  cross-shard lock on the admission fast path.
* **borrowing** — a shard at its local limit borrows bounded slack from the
  least-loaded peer *before* raising :class:`~.admission.Backpressure`.
  Borrows move capacity units between shards conservatively (one shard's
  gain is another's loss), so the cluster-wide quota can never be exceeded:
  for every client, ``sum(shard capacities) == global quota`` at all times.
* **reconciliation** — :meth:`ShardedAdmission.reconcile` runs on the
  modeled clock (periodically via ``reconcile_interval_s``, or explicitly):
  it returns unused borrowed capacity to its lenders, converging back to the
  balanced allocation, and rebalances unused lease tokens between shard
  buckets. Token moves conserve the total — the :class:`ReconcileReport`
  carries before/after sums so tests (and the property suite) can check.
* **partitions** — a shard whose reconciler stops firing
  (:meth:`ShardedAdmission.partition`) degrades to its local reserve: it can
  neither borrow nor lend, so it keeps admitting up to its own capacity and
  can never over-admit. On :meth:`rejoin` the next reconcile rounds fold it
  back into the balanced allocation.

Drop-in: a one-shard :class:`ShardedAdmission` is grant-for-grant,
denial-for-denial, wait-for-wait identical to the centralized controller
(the shard *is* an ``AdmissionController`` with the full budget; the
conformance suite replays recorded op sequences against both). Callers that
know the endpoint pass ``server_id=`` to route; callers that don't are
routed deterministically (least-loaded shard), so the centralized call shape
keeps working.
"""
from __future__ import annotations

import dataclasses

from .admission import (AdmissionConfig, AdmissionController, AdmissionStats,
                        Backpressure)


@dataclasses.dataclass
class DistributedConfig:
    """Knobs for the sharded layer (the admission budget itself lives in
    :class:`~.admission.AdmissionConfig`)."""

    reconcile_interval_s: float = 50e-3   # modeled period of the reconciler
    borrow_limit: int = 4                 # max units a shard holds borrowed

    def __post_init__(self) -> None:
        if self.reconcile_interval_s <= 0:
            raise ValueError("reconcile_interval_s must be > 0")
        if self.borrow_limit < 0:
            raise ValueError("borrow_limit must be >= 0")


@dataclasses.dataclass
class ShardStats(AdmissionStats):
    """One shard's :class:`AdmissionStats` plus the distributed counters."""

    borrows: int = 0             # capacity units borrowed in from peers
    lends: int = 0               # capacity units lent out to peers
    reconciles: int = 0          # rebalance rounds participated in
    tokens_in: float = 0.0       # lease tokens received in rebalances
    tokens_out: float = 0.0      # lease tokens given up in rebalances


@dataclasses.dataclass
class DistributedStats(AdmissionStats):
    """Aggregate over every shard, plus the per-shard breakdown. The
    inherited fields sum the shards', so anything reading a centralized
    controller's ``stats`` (the gateway, the report tables) keeps working."""

    borrows: int = 0
    lends: int = 0
    reconciles: int = 0
    tokens_rebalanced: float = 0.0     # total tokens moved between buckets
    peak_total: int = 0                # cluster-wide concurrent-stream peak
    shards: dict = dataclasses.field(default_factory=dict)  # sid -> ShardStats


@dataclasses.dataclass
class ReconcileReport:
    """What one reconcile round did — and proof it conserved the budget."""

    now_s: float
    participants: tuple[str, ...]
    capacity_returned: int = 0         # borrowed units handed back to lenders
    tokens_moved: float = 0.0          # abs lease tokens shifted into buckets
    tokens_before: float = 0.0         # sum over participants, post-refill
    tokens_after: float = 0.0          # must equal tokens_before


class AdmissionShard(AdmissionController):
    """One server's slice of the admission budget.

    The base controller does all the real work; the shard only re-reads its
    limits through the ``_client_quota`` / ``_total_cap`` hooks so borrowed
    capacity (``_client_adjust`` / ``_total_adjust``, maintained by the
    parent) is honored without forking the grant path. Invariant: for every
    client, adjustments across shards sum to zero.
    """

    def __init__(self, server_id: str, config: AdmissionConfig, pool=None):
        super().__init__(config, pool=pool)
        self.server_id = server_id
        self.stats = ShardStats()
        self._client_adjust: dict[str, int] = {}   # client -> net borrowed
        self._total_adjust = 0                     # net borrowed (global cap)

    def _client_quota(self, client_id: str) -> int | None:
        base = self.config.max_streams_per_client
        if base is None:
            return None
        return base + self._client_adjust.get(client_id, 0)

    def _total_cap(self) -> int | None:
        base = self.config.max_streams_total
        if base is None:
            return None
        return base + self._total_adjust

    # -------------------------------------------------------- borrow slack
    def client_slack(self, client_id: str) -> int | None:
        quota = self._client_quota(client_id)
        return (None if quota is None
                else quota - self.active_streams(client_id))

    def total_slack(self) -> int | None:
        cap = self._total_cap()
        return None if cap is None else cap - self.active_total()


def _split(total: int, n: int) -> list[int]:
    """Deal ``total`` units across ``n`` shards, remainder to the first."""
    base, rem = divmod(total, n)
    return [base + (1 if i < rem else 0) for i in range(n)]


def _water_fill(shards: list[AdmissionShard],
                total: float) -> dict[str, float]:
    """Level ``total`` lease tokens toward equal shares across ``shards``,
    capped at each bucket's burst; any spill re-levels among buckets with
    headroom (``total <= sum of bursts``, so it always fits). Returns
    server_id → target token count. Shared by the reconciler's periodic
    rebalance and the membership re-split."""
    targets = {s.server_id: 0.0 for s in shards}
    remaining = total
    pool = list(shards)
    while pool and remaining > 1e-12:
        share = remaining / len(pool)
        spill = [s for s in pool
                 if float(s.config.lease_burst) - targets[s.server_id]
                 <= share]
        if not spill:
            for s in pool:
                targets[s.server_id] += share
            break
        for s in spill:
            add = float(s.config.lease_burst) - targets[s.server_id]
            targets[s.server_id] += add
            remaining -= add
            pool.remove(s)
    return targets


class ShardedAdmission:
    """Per-server admission shards under one global budget.

    ``config`` is the *global* budget (same dataclass the centralized
    controller takes); it is split across ``server_ids`` — per-client quota
    and global cap dealt as integers, lease rate and burst divided — so the
    shards jointly hold exactly the centralized budget. ``pool`` (the
    registered-memory budget) is a genuinely global resource and every shard
    checks the same one.
    """

    #: the coordinator and gateway route per-endpoint when they see this
    per_server = True

    def __init__(self, config: AdmissionConfig | None = None,
                 server_ids: list[str] | tuple[str, ...] = ("s0",),
                 pool=None, dist: DistributedConfig | None = None):
        if not server_ids:
            raise ValueError("need at least one server_id to shard over")
        if len(set(server_ids)) != len(server_ids):
            raise ValueError("duplicate server_ids")
        self.config = config or AdmissionConfig()
        self.dist = dist or DistributedConfig()
        self.pool = pool
        ids = list(server_ids)
        n = len(ids)
        quotas = (_split(self.config.max_streams_per_client, n)
                  if self.config.max_streams_per_client is not None
                  else [None] * n)
        caps = (_split(self.config.max_streams_total, n)
                if self.config.max_streams_total is not None
                else [None] * n)
        bursts = _split(self.config.lease_burst, n)
        rate = self.config.lease_rate_per_s
        self.shards: dict[str, AdmissionShard] = {}
        for i, sid in enumerate(ids):
            local = dataclasses.replace(
                self.config, max_streams_per_client=quotas[i],
                max_streams_total=caps[i],
                lease_rate_per_s=None if rate is None else rate / n,
                lease_burst=bursts[i])
            self.shards[sid] = AdmissionShard(sid, local, pool=pool)
        self._partitioned: set[str] = set()
        # evicted shards kept as tombstones so late releases from leases
        # that were in flight when the server died settle against the dead
        # ledger instead of mis-routing onto a live shard (see remove_shard)
        self._retired: dict[str, AdmissionShard] = {}
        self._release_cbs: list = []
        self._last_reconcile_s = 0.0
        self._reconciles = 0
        self._tokens_rebalanced = 0.0
        self._peak_total = 0
        self._client_peaks: dict[str, int] = {}
        # optional obs.FlightRecorder (duck-typed): borrow/reconcile events
        # land in the postmortem ring when one is attached
        self.recorder = None

    @classmethod
    def for_coordinator(cls, coordinator,
                        config: AdmissionConfig | None = None,
                        pool=None, dist: DistributedConfig | None = None
                        ) -> "ShardedAdmission":
        """One shard per registered server, in registry order (duck-typed:
        anything with a ``servers`` mapping works)."""
        return cls(config, sorted(coordinator.servers), pool=pool, dist=dist)

    # ------------------------------------------------------------- routing
    def shard(self, server_id: str) -> AdmissionShard:
        if server_id not in self.shards:
            raise KeyError(f"unknown shard {server_id!r}")
        return self.shards[server_id]

    def _route_acquire(self, client_id: str,
                       server_id: str | None) -> AdmissionShard:
        if server_id is not None and server_id in self.shards:
            return self.shards[server_id]
        # endpoint unknown: deterministic least-loaded routing (the shard
        # with the most per-client headroom; ties break by server id)
        def headroom(item):
            sid, shard = item
            slack = shard.client_slack(client_id)
            return (-(10**9 if slack is None else slack), sid)
        return min(self.shards.items(), key=headroom)[1]

    def _route_release(self, client_id: str,
                       server_id: str | None) -> AdmissionShard | None:
        if server_id is not None and server_id in self.shards:
            return self.shards[server_id]
        # release where the slot is actually held, or it would leak
        holding = [(sid, s) for sid, s in self.shards.items()
                   if s.active_streams(client_id) > 0]
        if not holding:
            return None
        return max(holding, key=lambda kv: (kv[1].active_streams(client_id),
                                            kv[0]))[1]

    def headroom(self, server_id: str,
                 client_id: str = "default") -> int | None:
        """The shard's **local** free capacity for one more of
        ``client_id``'s streams: the tighter of its per-client quota slack
        and its total-cap slack (``None`` == both unlimited). Deliberately
        blind to borrowable peer slack — the caller (the steal scheduler's
        thief-side check, via ``ClusterCoordinator.admission_headroom``)
        wants to know whether an extra grant would stall on admission or
        force a borrow, and a borrow is exactly the stall it is avoiding.
        Unknown servers answer from the shard an acquire would route to."""
        shard = self._route_acquire(client_id, server_id)
        slacks = [s for s in (shard.client_slack(client_id),
                              shard.total_slack()) if s is not None]
        return min(slacks) if slacks else None

    # ------------------------------------------------------------- streams
    def active_streams(self, client_id: str = "default") -> int:
        return sum(s.active_streams(client_id) for s in self.shards.values())

    def active_total(self) -> int:
        return sum(s.active_total() for s in self.shards.values())

    def acquire_stream(self, client_id: str = "default",
                       server_id: str | None = None) -> None:
        """Admission-check against the endpoint's shard; on a local limit,
        borrow bounded slack from the least-loaded peer before denying."""
        shard = self._route_acquire(client_id, server_id)
        # a grant may be blocked on BOTH the per-client quota slice and the
        # shard's total-cap slice: borrow for each binding reason until the
        # grant clears or a borrow makes no progress (no peer slack / limit)
        borrowed: list[tuple[AdmissionShard, str]] = []
        reason = shard._deny_reason(client_id)
        while reason in ("quota", "total"):
            lender = self._borrow(shard, client_id, reason)
            if lender is not None:
                borrowed.append((lender, reason))
            cleared = shard._deny_reason(client_id)
            if cleared == reason:              # borrow failed: deny below
                break
            reason = cleared
        try:
            shard.acquire_stream(client_id)    # raises if still over limit
        except Backpressure:
            # a borrow that cleared one reason while the other still denies
            # must not strand capacity at a shard that didn't use it
            for lender, kind in reversed(borrowed):
                self._unborrow(shard, lender, client_id, kind)
            raise
        self._peak_total = max(self._peak_total, self.active_total())
        self._client_peaks[client_id] = max(
            self._client_peaks.get(client_id, 0),
            self.active_streams(client_id))

    def release_stream(self, client_id: str = "default",
                       server_id: str | None = None,
                       now_s: float | None = None) -> None:
        if server_id is not None and server_id in self._retired:
            # the slot was held on a shard that has since been absorbed
            # (server evicted); settle the dead ledger quietly — the
            # capacity already moved to the survivors, so no live slot
            # frees and no freed-slot callback fires
            tomb = self._retired[server_id]
            if tomb.active_streams(client_id) > 0:
                tomb.release_stream(client_id, server_id=server_id,
                                    now_s=now_s)
            return
        shard = self._route_release(client_id, server_id)
        if shard is None or shard.active_streams(client_id) == 0:
            return       # nothing held: no decrement, no phantom event
        shard.release_stream(client_id, server_id=shard.server_id,
                             now_s=now_s)
        for cb in self._release_cbs:
            cb(shard.server_id, client_id, now_s)

    def subscribe_release(self, callback) -> None:
        """``callback(server_id, client_id, now_s)`` on every freed slot —
        the gateway's ``replan_on_release`` hook and the steal scheduler's
        declined-shard retry plug in here."""
        self._release_cbs.append(callback)

    def unsubscribe_release(self, callback) -> None:
        """Remove a freed-slot listener (idempotent) — per-scan subscribers
        unsubscribe on drain so the list doesn't grow with scan count."""
        try:
            self._release_cbs.remove(callback)
        except ValueError:
            pass

    # ------------------------------------------------------------ borrowing
    def _peers(self, shard: AdmissionShard) -> list[AdmissionShard]:
        if shard.server_id in self._partitioned:
            return []              # partitioned: degraded to local reserve
        return [s for sid, s in sorted(self.shards.items())
                if s is not shard and sid not in self._partitioned]

    def _borrow(self, shard: AdmissionShard, client_id: str,
                reason: str) -> AdmissionShard | None:
        """Move one capacity unit from the least-loaded peer to ``shard``;
        returns the lender (``None`` when no borrow happened). Bounded: a
        shard never holds more than ``dist.borrow_limit`` net borrowed
        units, and a lender never gives up in-use capacity. A failed
        borrow is a no-op — the caller's acquire raises the denial."""
        if reason == "quota":
            held = shard._client_adjust.get(client_id, 0)
            slack_of = lambda peer: peer.client_slack(client_id)  # noqa: E731
        else:
            held = shard._total_adjust
            slack_of = lambda peer: peer.total_slack()            # noqa: E731
        if held >= self.dist.borrow_limit:
            return None
        candidates = [(p, slack_of(p)) for p in self._peers(shard)]
        candidates = [(p, s) for p, s in candidates
                      if s is not None and s > 0]
        if not candidates:
            return None
        lender = max(candidates, key=lambda ps: (ps[1], ps[0].server_id))[0]
        if reason == "quota":
            lender._client_adjust[client_id] = \
                lender._client_adjust.get(client_id, 0) - 1
            shard._client_adjust[client_id] = held + 1
        else:
            lender._total_adjust -= 1
            shard._total_adjust = held + 1
        lender.stats.lends += 1
        shard.stats.borrows += 1
        if self.recorder is not None:
            self.recorder.record("admission.borrow",
                                 server_id=shard.server_id,
                                 lender=lender.server_id, reason=reason)
        return lender

    def _unborrow(self, shard: AdmissionShard, lender: AdmissionShard,
                  client_id: str, reason: str) -> None:
        """Reverse one :meth:`_borrow` whose grant was ultimately denied.
        The stats counters are rolled back too — ``borrows``/``lends``
        count capacity that actually moved for a grant, not probes."""
        if reason == "quota":
            shard._client_adjust[client_id] -= 1
            if shard._client_adjust[client_id] == 0:
                del shard._client_adjust[client_id]
            lender._client_adjust[client_id] = \
                lender._client_adjust.get(client_id, 0) + 1
            if lender._client_adjust.get(client_id) == 0:
                del lender._client_adjust[client_id]
        else:
            shard._total_adjust -= 1
            lender._total_adjust += 1
        lender.stats.lends -= 1
        shard.stats.borrows -= 1

    # --------------------------------------------------------- token bucket
    def _maybe_reconcile(self, now_s: float) -> None:
        if now_s - self._last_reconcile_s >= self.dist.reconcile_interval_s:
            self.reconcile(now_s)

    def lease_wait_s(self, now_s: float, n: int = 1,
                     server_id: str | None = None) -> float:
        """Meter ``n`` lease tokens against the endpoint shard's bucket
        (or the richest bucket when the caller doesn't know the endpoint).
        Piggybacks the periodic reconciler on the modeled clock."""
        self._maybe_reconcile(now_s)
        if server_id is not None and server_id in self.shards:
            shard = self.shards[server_id]
        else:
            shard = max(sorted(self.shards.items()),
                        key=lambda kv: kv[1].tokens_at(now_s))[1]
        return shard.lease_wait_s(now_s, n)

    def lease_wait_for_counts(self, now_s: float,
                              counts: dict[str, int]) -> float:
        """Meter a fan-out's per-server token demand: group by the shard
        that actually serves each server (unknown servers fall back to the
        richest bucket), charge every shard **once** with its whole demand,
        and return the slowest wait — per-shard grants run concurrently,
        but one shard's demand serializes on its own bucket. With one
        shard this collapses to a single n-token grant, exactly the
        centralized controller's call shape (drop-in conformance)."""
        self._maybe_reconcile(now_s)
        by_shard: dict[str, int] = {}
        for sid, n in sorted(counts.items()):
            if sid not in self.shards:
                sid = max(sorted(self.shards.items()),
                          key=lambda kv: kv[1].tokens_at(now_s))[0]
            by_shard[sid] = by_shard.get(sid, 0) + n
        return max((self.shards[sid].lease_wait_s(now_s, n)
                    for sid, n in sorted(by_shard.items())), default=0.0)

    # ------------------------------------------------------- reconciliation
    def partition(self, server_id: str) -> None:
        """The shard's reconciler stopped firing: exclude it from borrow
        and rebalance rounds. It keeps admitting against its local reserve
        (capacity it already holds), so it can never over-admit."""
        self.shard(server_id)      # KeyError on unknown
        self._partitioned.add(server_id)

    def rejoin(self, server_id: str) -> None:
        self._partitioned.discard(server_id)

    def partitioned(self, server_id: str) -> bool:
        return server_id in self._partitioned

    # ----------------------------------------------------------- membership
    def remove_shard(self, server_id: str, now_s: float = 0.0) -> None:
        """Absorb a dead/evicted server's quota shard into the survivors.

        The shard's bucket is refilled to ``now_s`` and its tokens join the
        re-split (conserved, never destroyed); the base budget is re-dealt
        across the surviving shards so the cluster-wide quota is unchanged
        by the membership change. The shard itself is kept as a tombstone:
        leases that were in flight when the server died release against it
        later without touching a live shard's ledger."""
        shard = self.shard(server_id)
        if len(self.shards) == 1:
            raise ValueError("cannot remove the last admission shard")
        shard._refill(now_s)
        orphan_tokens = shard._tokens
        shard._tokens = 0.0
        del self.shards[server_id]
        self._partitioned.discard(server_id)
        self._retired[server_id] = shard
        self._resplit(now_s, extra_tokens=orphan_tokens)
        if self.recorder is not None:
            self.recorder.record("admission.shard_absorbed", now_s=now_s,
                                 server_id=server_id,
                                 tokens_absorbed=orphan_tokens,
                                 survivors=len(self.shards))

    def add_shard(self, server_id: str, now_s: float = 0.0) -> None:
        """Spawn a quota shard for a joining (or re-admitted) server by
        re-splitting the base budget across the grown membership. A
        re-admitted server starts with a clean ledger — its pre-eviction
        leases died with the process (or migrated and settled against the
        tombstone, which is dropped here)."""
        if server_id in self.shards:
            raise ValueError(f"shard {server_id!r} already exists")
        self._retired.pop(server_id, None)
        local = dataclasses.replace(self.config, lease_burst=0,
                                    lease_rate_per_s=None)
        self.shards[server_id] = AdmissionShard(server_id, local,
                                                pool=self.pool)
        self._resplit(now_s)
        if self.recorder is not None:
            self.recorder.record("admission.shard_spawned", now_s=now_s,
                                 server_id=server_id,
                                 members=len(self.shards))

    def _resplit(self, now_s: float, extra_tokens: float = 0.0) -> None:
        """Re-deal the base budget across the current shard set.

        Every borrow adjustment is cleared (all-zero trivially satisfies
        the zero-sum invariant) and each shard's config becomes its fresh
        slice of the global budget; a shard holding more in-use streams
        than its new slice simply denies new grants until it drains, so
        the global caps are never exceeded. Tokens (current holdings plus
        ``extra_tokens`` from an absorbed shard) are re-leveled by the
        same water-fill the reconciler uses — conserved by construction."""
        ids = sorted(self.shards)
        n = len(ids)
        quotas = (_split(self.config.max_streams_per_client, n)
                  if self.config.max_streams_per_client is not None
                  else [None] * n)
        caps = (_split(self.config.max_streams_total, n)
                if self.config.max_streams_total is not None
                else [None] * n)
        bursts = _split(self.config.lease_burst, n)
        rate = self.config.lease_rate_per_s
        shards = [self.shards[sid] for sid in ids]
        for shard in shards:
            shard._refill(now_s)
        total_tokens = sum(s._tokens for s in shards) + extra_tokens
        for i, (sid, shard) in enumerate(zip(ids, shards)):
            shard.config = dataclasses.replace(
                shard.config, max_streams_per_client=quotas[i],
                max_streams_total=caps[i],
                lease_rate_per_s=None if rate is None else rate / n,
                lease_burst=bursts[i])
            shard._client_adjust.clear()
            shard._total_adjust = 0
            # a joiner's bucket clock starts at the re-split (its placeholder
            # config had no rate, so _refill above didn't advance it)
            shard._bucket_clock_s = max(shard._bucket_clock_s, now_s)
        targets = _water_fill(shards, total_tokens)
        for shard in shards:
            shard._tokens = min(targets[shard.server_id],
                                float(shard.config.lease_burst))

    def reconcile(self, now_s: float) -> ReconcileReport:
        """One rebalance round over the non-partitioned shards.

        1. *Capacity*: every borrowed unit not pinned by in-use streams goes
           back to its lenders — repeated rounds converge to the balanced
           (base) allocation once load drops.
        2. *Lease tokens*: refill every participating bucket to ``now_s``,
           then level tokens across buckets (water-filling capped at each
           bucket's burst). Conserves the total — no shard pair creates or
           destroys tokens; the report proves it.
        """
        ids = tuple(sid for sid in sorted(self.shards)
                    if sid not in self._partitioned)
        report = ReconcileReport(now_s=now_s, participants=ids)
        self._last_reconcile_s = now_s
        self._reconciles += 1
        shards = [self.shards[sid] for sid in ids]
        for shard in shards:
            shard.stats.reconciles += 1
        if len(shards) >= 2:
            report.capacity_returned = self._rebalance_capacity(shards)
            self._rebalance_tokens(shards, now_s, report)
        else:
            report.tokens_before = report.tokens_after = sum(
                s.tokens_at(now_s) for s in shards)
        if self.recorder is not None:
            self.recorder.record(
                "admission.reconcile", now_s=now_s,
                participants=len(ids),
                capacity_returned=report.capacity_returned,
                tokens_moved=report.tokens_moved)
        return report

    def _rebalance_capacity(self, shards: list[AdmissionShard]) -> int:
        returned = 0
        # per-client quota adjustments: borrowers return what in-use
        # streams don't pin; lenders with the largest debt are repaid first
        clients = sorted({c for s in shards for c in s._client_adjust})
        for client in clients:
            for borrower in shards:
                held = borrower._client_adjust.get(client, 0)
                if held <= 0:
                    continue
                slack = borrower.client_slack(client)
                give = min(held, max(0, slack if slack is not None else 0))
                while give > 0:
                    lenders = [s for s in shards
                               if s._client_adjust.get(client, 0) < 0]
                    if not lenders:
                        break
                    lender = min(lenders, key=lambda s: (
                        s._client_adjust.get(client, 0), s.server_id))
                    lender._client_adjust[client] += 1
                    if lender._client_adjust[client] == 0:
                        del lender._client_adjust[client]
                    borrower._client_adjust[client] -= 1
                    give -= 1
                    returned += 1
                if borrower._client_adjust.get(client, 0) == 0:
                    borrower._client_adjust.pop(client, None)
        # global-cap adjustments: same settlement, one ledger
        for borrower in shards:
            if borrower._total_adjust <= 0:
                continue
            slack = borrower.total_slack()
            give = min(borrower._total_adjust,
                       max(0, slack if slack is not None else 0))
            while give > 0:
                lenders = [s for s in shards if s._total_adjust < 0]
                if not lenders:
                    break
                lender = min(lenders,
                             key=lambda s: (s._total_adjust, s.server_id))
                lender._total_adjust += 1
                borrower._total_adjust -= 1
                give -= 1
                returned += 1
        return returned

    def _rebalance_tokens(self, shards: list[AdmissionShard], now_s: float,
                          report: ReconcileReport) -> None:
        rate = self.config.lease_rate_per_s
        if rate is None or rate <= 0:
            return
        for shard in shards:
            shard._refill(now_s)
        total = sum(s._tokens for s in shards)
        report.tokens_before = total
        targets = _water_fill(shards, total)
        for shard in shards:
            delta = targets[shard.server_id] - shard._tokens
            if delta > 1e-12:
                shard.stats.tokens_in += delta
                report.tokens_moved += delta
            elif delta < -1e-12:
                shard.stats.tokens_out += -delta
            shard._tokens = targets[shard.server_id]
        self._tokens_rebalanced += report.tokens_moved
        report.tokens_after = sum(s._tokens for s in shards)

    # --------------------------------------------------------------- stats
    @property
    def memory_budget_bytes(self) -> int | None:
        if self.config.memory_budget_bytes is not None:
            return self.config.memory_budget_bytes
        if self.pool is not None:
            return getattr(self.pool, "max_bytes", None)
        return None

    @property
    def peak_total(self) -> int:
        return self._peak_total

    def peak_streams(self, client_id: str = "default") -> int:
        """High-water mark of one client's concurrent streams, cluster-wide.
        Summing shard peaks would over-count (they need not be simultaneous),
        so the exact global peak is tracked at acquire time instead."""
        return self._client_peaks.get(client_id, 0)

    @property
    def stats(self) -> DistributedStats:
        agg = DistributedStats(peak_total=self._peak_total,
                               reconciles=self._reconciles,
                               tokens_rebalanced=self._tokens_rebalanced)
        for sid in sorted(self.shards):
            s = self.shards[sid].stats
            agg.shards[sid] = s
            agg.stream_grants += s.stream_grants
            agg.stream_denials += s.stream_denials
            agg.total_denials += s.total_denials
            agg.memory_denials += s.memory_denials
            agg.lease_grants += s.lease_grants
            agg.throttle_wait_s += s.throttle_wait_s
            agg.borrows += s.borrows
            agg.lends += s.lends
            agg.peak_active = max(agg.peak_active, s.peak_active)
        return agg
