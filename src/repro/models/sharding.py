"""Logical-axis sharding: rules, divisibility cascade, activation constraints.

Weights and activations are annotated with *logical* axis names; a rule table
maps them onto mesh axes with divisibility checks (e.g. gemma's 8 query heads
cannot shard over a 16-way ``model`` axis, so attention falls back to
sharding ``head_dim`` — 256 lanes — instead; whisper's 12 heads likewise).

``set_mesh_context`` installs a (mesh, rules) pair consulted by
:func:`constrain` inside model code — a no-op when unset so smoke tests run
unsharded on one CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

_local = threading.local()


def _mesh_axis_size(mesh: Mesh, axis: str | tuple[str, ...]) -> int:
    if isinstance(axis, str):
        return mesh.shape[axis]
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axis: str | tuple[str, ...] | None) -> bool:
    if axis is None:
        return True
    return dim % _mesh_axis_size(mesh, axis) == 0


DEFAULT_OPTIONS: dict[str, Any] = {
    # what to do when query heads don't divide the model axis:
    #   "replicate" — attention weights replicate over `model` (FSDP on
    #                 `data` still shards storage); attention compute is
    #                 local, zero attention collectives.  [optimized default]
    #   "head_dim"  — contraction-shard head_dim; QK^T/PV carry a psum of
    #                 the score tensor per KV chunk.      [paper-baseline]
    "attn_fallback": "replicate",
    # MoE dispatch scope: True = sort/capacity per batch-shard group (all
    # routing ops SPMD-local); False = one global sort (baseline).
    "moe_local_dispatch": True,
    # "tp2d" (default): data×model 2D layout. "fsdp": pure ZeRO-3 — batch
    # spans every mesh axis whose prefix product divides global_batch,
    # weights fully sharded over those axes, NO tensor parallelism (zero
    # activation collectives; weight all-gathers + grad reduce-scatters
    # only). Wins for dense train shapes with per-chip batch >= 1.
    "layout": "tp2d",
    "global_batch": None,            # consulted by the fsdp layout
}


def make_rules(cfg: ArchConfig, mesh: Mesh,
               options: dict[str, Any] | None = None) -> dict[str, Any]:
    """Concrete logical-axis → mesh-axis assignment for this arch × mesh.

    The attention cascade: shard query heads on ``model`` when divisible,
    otherwise fall back per ``options['attn_fallback']`` (see
    DEFAULT_OPTIONS; the head_dim mode is kept selectable because it is the
    §Perf baseline).
    """
    opts = dict(DEFAULT_OPTIONS)
    if options:
        opts.update(options)
    axes = mesh.axis_names
    dp: tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
    model = "model" if "model" in axes else None
    hd = cfg.resolved_head_dim

    rules: dict[str, Any] = {
        "batch": dp if dp else None,
        "fsdp": "data" if "data" in axes else None,
        "model": model,
        "heads": None, "kv": None, "head_dim": None,
        "moe_local_dispatch": bool(opts["moe_local_dispatch"]),
        "attn_fallback": opts["attn_fallback"],
    }
    if model is not None:
        msize = mesh.shape[model]
        if cfg.eff_heads and cfg.eff_heads % msize == 0:
            rules["heads"] = model
            if cfg.eff_kv and cfg.eff_kv % msize == 0:
                rules["kv"] = model
            # else: kv replicated (GQA with few kv heads) — q-sharded mode
        elif (cfg.num_heads and opts["attn_fallback"] == "head_dim"
              and hd % msize == 0):
            rules["head_dim"] = model          # contraction-sharded attention
        if cfg.d_ff and cfg.d_ff % msize != 0:
            rules["model_ffn"] = None
        else:
            rules["model_ffn"] = model
        rules["vocab"] = model if cfg.padded_vocab % msize == 0 else None
        if cfg.moe is not None:
            rules["experts"] = model if cfg.moe.num_experts % msize == 0 else None
            rules["model_ffe"] = (model if cfg.moe.d_ff_expert % msize == 0
                                  and rules.get("experts") is None else None)
        if cfg.ssm is not None:
            d_in = cfg.ssm.expand * cfg.d_model
            nheads = d_in // cfg.ssm.head_dim
            rules["ssm_heads"] = model if nheads % msize == 0 else None
            rules["d_inner"] = model if d_in % msize == 0 else None
        # residual-stream activation sharding (megatron-SP style): saves
        # (L × B × S × D) checkpointed activations sharded over model
        rules["residual"] = model if cfg.d_model % msize == 0 else None
    else:
        rules["model_ffn"] = None
        rules["vocab"] = None
        rules["residual"] = None
        if cfg.moe is not None:
            rules["experts"] = None
            rules["model_ffe"] = None
        if cfg.ssm is not None:
            rules["ssm_heads"] = None
            rules["d_inner"] = None

    # -- pure-FSDP / ZeRO-3 layout override --------------------------------
    if opts.get("layout") == "fsdp":
        gb = opts.get("global_batch")
        chosen: list[str] = []
        prod = 1
        for a in ("pod", "data", "model"):
            if a not in axes:
                continue
            nxt = prod * mesh.shape[a]
            if gb is not None and gb % nxt != 0:
                break
            chosen.append(a)
            prod = nxt
        shard_axes = tuple(chosen) if chosen else (dp or None)
        rules["batch"] = shard_axes
        rules["fsdp"] = shard_axes
        for k in ("heads", "kv", "head_dim", "model_ffn", "vocab",
                  "residual", "experts", "model_ffe", "ssm_heads", "d_inner"):
            if k in rules:
                rules[k] = None
        rules["layout"] = "fsdp"

    return rules


def spec_of(logical: Sequence[str | None], rules: Mapping[str, Any],
            shape: Sequence[int] | None = None,
            mesh: Mesh | None = None) -> P:
    """Map logical axis names to a PartitionSpec (with divisibility guard
    when shape+mesh provided)."""
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        axis = rules.get(name)
        if axis is None:
            out.append(None)
            continue
        if shape is not None and mesh is not None and not _fits(shape[i], mesh, axis):
            out.append(None)
            continue
        out.append(axis)
    return P(*out)


# ---------------------------------------------------------------------------
# activation-constraint context
# ---------------------------------------------------------------------------


def set_mesh_context(mesh: Mesh | None, rules: Mapping[str, Any] | None) -> None:
    _local.mesh = mesh
    _local.rules = rules


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Mapping[str, Any]):
    prev = (getattr(_local, "mesh", None), getattr(_local, "rules", None))
    set_mesh_context(mesh, rules)
    try:
        yield
    finally:
        set_mesh_context(*prev)


def dispatch_groups() -> int:
    """MoE local-dispatch group count = number of batch shards (1 when no
    mesh context or local dispatch disabled — CPU smoke tests)."""
    mesh = getattr(_local, "mesh", None)
    rules = getattr(_local, "rules", None)
    if mesh is None or rules is None or not rules.get("moe_local_dispatch"):
        return 1
    batch = rules.get("batch")
    if not batch:
        return 1
    axes = (batch,) if isinstance(batch, str) else batch
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without context."""
    mesh = getattr(_local, "mesh", None)
    rules = getattr(_local, "rules", None)
    if mesh is None or rules is None:
        return x
    spec = spec_of(logical, rules, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
