"""Model zoo: dense / MoE / SSM / hybrid / VLM / enc-dec in pure JAX."""
from .model import (  # noqa: F401
    batch_pspecs, cache_pspecs, cache_spec, decode, forward, init_cache,
    init_params, loss_fn, param_shapes, param_specs, prefill,
)
from .sharding import constrain, make_rules, mesh_context, set_mesh_context  # noqa: F401
