"""Mamba2 — SSD (state-space duality) blocks, chunked scan + O(1) decode.

The chunked SSD algorithm (Dao & Gu 2024): sequence split into chunks of
``Q``; within a chunk the output is a masked quadratic form (the "attention
duality"), across chunks a small (H, P, N) state is carried by a scan. Decode
is a single-token state update — this is what makes `long_500k` runnable for
the ssm/hybrid archs while full-attention families skip it.

State math runs in fp32 (dt/decay/cumsum paths), matmuls in the param dtype.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, SSMConfig
from .layers import rms_norm
from .sharding import constrain

Params = dict[str, Any]


def ssm_dims(cfg: ArchConfig) -> tuple[int, int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.ngroups * s.state_dim
    return d_inner, nheads, s.head_dim, s.state_dim, conv_ch


def init_mamba_layer_params(cfg: ArchConfig, key: jax.Array, L: int,
                            dtype=jnp.float32) -> Params:
    """Stacked (L, ...) params for L mamba2 blocks."""
    D = cfg.d_model
    s = cfg.ssm
    d_inner, H, P, N, conv_ch = ssm_dims(cfg)
    in_dim = 2 * d_inner + 2 * s.ngroups * N + H
    ks = iter(jax.random.split(key, 8))
    s_d = 1.0 / math.sqrt(D)
    return {
        "in_proj": jax.random.normal(next(ks), (L, D, in_dim), dtype) * s_d,
        "conv_w": jax.random.normal(next(ks), (L, s.conv_width, conv_ch), dtype)
                  * (1.0 / math.sqrt(s.conv_width)),
        "conv_b": jnp.zeros((L, conv_ch), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)[None], (L, H)).copy()),
        "D": jnp.ones((L, H), jnp.float32),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "norm": jnp.zeros((L, d_inner), dtype),
        "out_proj": jax.random.normal(next(ks), (L, d_inner, D), dtype)
                    * (1.0 / math.sqrt(d_inner)),
        "ln": jnp.zeros((L, D), dtype),
    }


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: xBC (B,S,C), w (W,C) -> (B,S,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(W):
        out = out + pad[:, i : i + xBC.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _split_zxbcdt(cfg: ArchConfig, zxbcdt: jax.Array):
    d_inner, H, P, N, _ = ssm_dims(cfg)
    G = cfg.ssm.ngroups
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * G * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * G * N :]
    return z, xBC, dt


def _split_xbc(cfg: ArchConfig, xBC: jax.Array):
    d_inner, H, P, N, _ = ssm_dims(cfg)
    G = cfg.ssm.ngroups
    x = xBC[..., :d_inner]
    B_ = xBC[..., d_inner : d_inner + G * N]
    C_ = xBC[..., d_inner + G * N :]
    return x, B_, C_


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
                C_: jax.Array, D_skip: jax.Array, chunk: int,
                return_final_state: bool = False):
    """Chunked SSD scan.

    x (B,S,H,P); dt (B,S,H) fp32 post-softplus; A (H,) negative; B_/C_
    (B,S,G,N); D_skip (H,). Returns (B,S,H,P) in x.dtype
    (+ final (B,H,P,N) state when requested — prefill hands it to decode).
    """
    Bb, S, H, P = x.shape
    G = B_.shape[2]
    Q = math.gcd(S, chunk) if S % chunk else chunk
    nc = S // Q
    hpg = H // G

    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B_.astype(jnp.float32).reshape(Bb, nc, Q, G, N := B_.shape[-1])
    Cc = C_.astype(jnp.float32).reshape(Bb, nc, Q, G, N)

    # vmem_fused: the intra-chunk quadratic form (the "attention duality")
    # runs as a fused SSD kernel on TPU — Lmat/CB/scores are VMEM tiles.
    with jax.named_scope("vmem_fused_attention"):
        dA = dtc * A[None, None, None, :]                  # (B,nc,Q,H) <= 0
        dAcum = jnp.cumsum(dA, axis=2)                     # within-chunk
        seg = dAcum[:, :, :, None, :] - dAcum[:, :, None, :, :]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

        # intra-chunk (duality: masked attention within the chunk)
        CB = jnp.einsum("bclgn,bcsgn->bclsg", Cc, Bc)      # (B,nc,l,s,G)
        CB = jnp.repeat(CB, hpg, axis=-1)                  # g -> h
        scores = CB * Lmat * dtc[:, :, None, :, :]         # (B,nc,l,s,H)
        y_diag = jnp.einsum("bclsh,bcshp->bclhp", scores, xf)

        # chunk-final states
        decay_end = jnp.exp(dAcum[:, :, -1:, :] - dAcum)   # (B,nc,Q,H)
        Bx = jnp.einsum("bcsgn,bcsh,bcshp->bchpn",
                        Bc, decay_end * dtc, xf)           # (B,nc,H,P,N)

    # inter-chunk recurrence over nc (sequential scan, small state)
    chunk_decay = jnp.exp(dAcum[:, :, -1, :])              # (B,nc,H)

    def step(state, inputs):
        dec, bx = inputs                                   # (B,H), (B,H,P,N)
        new = state * dec[..., None, None] + bx
        return new, state                                  # emit state ENTERING chunk

    s0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    final_state, states_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(Bx, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)              # (B,nc,H,P,N)

    # inter-chunk contribution: decay from chunk start then read with C
    decay_in = jnp.exp(dAcum)                              # (B,nc,Q,H)
    Ch = jnp.repeat(Cc, hpg, axis=-2)                      # (B,nc,Q,H,N)
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp", Ch, decay_in, states_in)

    y = y_diag + y_off + xf * D_skip[None, None, None, :, None]
    y = y.reshape(Bb, S, H, P).astype(x.dtype)
    if return_final_state:
        return y, final_state
    return y


def mamba_block(cfg: ArchConfig, p: Params, u: jax.Array,
                return_cache: bool = False):
    """One mamba2 block, full sequence. u (B,S,D) -> (B,S,D)
    (+ (state, conv_cache) when return_cache — the prefill path)."""
    s = cfg.ssm
    d_inner, H, P, N, _ = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xBC_raw, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    x, B_, C_ = _split_xbc(cfg, xBC)
    x = constrain(x.reshape(*x.shape[:2], H, P), ("batch", None, "ssm_heads", None))
    B_ = B_.reshape(*B_.shape[:2], s.ngroups, N)
    C_ = C_.reshape(*C_.shape[:2], s.ngroups, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    res = ssd_chunked(x, dt, A, B_, C_, p["D"], s.chunk,
                      return_final_state=return_cache)
    y, final_state = res if return_cache else (res, None)
    y = y.reshape(*y.shape[:2], d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"],
                 cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_cache:
        conv_cache = xBC_raw[:, -(s.conv_width - 1):, :]   # pre-activation taps
        return out, (final_state, conv_cache)
    return out


def mamba_decode_block(cfg: ArchConfig, p: Params, u: jax.Array,
                       state: jax.Array, conv_cache: jax.Array):
    """One block, one token. u (B,1,D); state (B,H,P,N); conv_cache
    (B,W-1,conv_ch). Returns (out (B,1,D), new_state, new_conv_cache)."""
    s = cfg.ssm
    d_inner, H, P, N, conv_ch = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xBC, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    # conv over (cache ++ new token)
    window = jnp.concatenate([conv_cache, xBC[:, 0:1, :].astype(conv_cache.dtype)],
                             axis=1)                      # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv_cache = window[:, 1:, :]
    x, B_, C_ = _split_xbc(cfg, conv_out[:, None, :].astype(u.dtype))
    x = x.reshape(-1, H, P).astype(jnp.float32)            # (B,H,P)
    B_ = B_.reshape(-1, s.ngroups, N).astype(jnp.float32)
    C_ = C_.reshape(-1, s.ngroups, N).astype(jnp.float32)
    hpg = H // s.ngroups
    Bh = jnp.repeat(B_, hpg, axis=1)                       # (B,H,N)
    Ch = jnp.repeat(C_, hpg, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None])                             # (B,H)
    state = state * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, x, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + x * p["D"][None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), state, new_conv_cache


# ---------------------------------------------------------------------------
# full model (family == "ssm")
# ---------------------------------------------------------------------------


def init_mamba_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    params: Params = {
        "embed": jax.random.normal(k1, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": init_mamba_layer_params(cfg, k2, cfg.num_layers, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k3, (cfg.d_model, cfg.padded_vocab),
                                               dtype)
                             * (1.0 / math.sqrt(cfg.d_model)))
    return params


def mamba_forward(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
                  remat: str = "full") -> jax.Array:
    from .transformer import _maybe_remat, embed_tokens, logits_fn

    x = embed_tokens(cfg, params, tokens)

    def body(carry, layer_p):
        h = rms_norm(carry, layer_p["ln"], cfg.norm_eps)
        out = carry + mamba_block(cfg, layer_p, h)
        out = constrain(out, ("batch", None, "residual"))
        return out, None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return logits_fn(cfg, params, x)


def mamba_cache_spec(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d_inner, H, P, N, conv_ch = ssm_dims(cfg)
    L, W = cfg.num_layers, cfg.ssm.conv_width
    return {
        "state": jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((L, batch, W - 1, conv_ch), dtype),
    }


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    spec = mamba_cache_spec(cfg, batch, dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def mamba_prefill(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
                  remat: str = "full"):
    """Process the prompt, returning (logits, decode cache)."""
    from .transformer import _maybe_remat, embed_tokens, logits_fn

    x = embed_tokens(cfg, params, tokens)

    def body(carry, layer_p):
        h = rms_norm(carry, layer_p["ln"], cfg.norm_eps)
        out, (state, conv) = mamba_block(cfg, layer_p, h, return_cache=True)
        new = constrain(carry + out, ("batch", None, "residual"))
        return new, (state, conv)

    body = _maybe_remat(body, remat)
    x, (states, convs) = jax.lax.scan(body, x, params["layers"])
    logits = logits_fn(cfg, params, x)
    return logits, {"state": states, "conv": convs}


def mamba_decode(cfg: ArchConfig, params: Params, cache: Params,
                 tokens: jax.Array, position: jax.Array):
    """One decode step (position unused by the SSM state but kept for API
    parity with attention decode)."""
    from .transformer import embed_tokens, logits_fn

    x = embed_tokens(cfg, params, tokens)

    def body(carry, inputs):
        x = carry
        layer_p, state, conv = inputs
        h = rms_norm(x, layer_p["ln"], cfg.norm_eps)
        out, state, conv = mamba_decode_block(cfg, layer_p, h, state, conv)
        return x + out, (state, conv)

    x, (new_state, new_conv) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["conv"]))
    logits = logits_fn(cfg, params, x)
    return logits, {"state": new_state, "conv": new_conv}
