"""Decoder-only transformer (dense / MoE / VLM families).

Layers are stacked along a leading ``L`` axis and driven by ``lax.scan`` so
the lowered HLO is one layer body regardless of depth (compile time and HLO
size stay flat from gemma-2b to deepseek-67b). Remat policy wraps the scan
body. All activations pass through :func:`repro.models.sharding.constrain`
with logical names, so the same code lowers unsharded on one CPU device and
2D-sharded on a 512-chip mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (apply_rope, chunked_attention, decode_attention,
                     gated_mlp, rms_norm)
from .moe import init_moe_params, moe_ffn
from .sharding import constrain

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_transformer_params(cfg: ArchConfig, key: jax.Array,
                            dtype=jnp.float32) -> Params:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, L, F, V = (cfg.eff_heads, cfg.eff_kv, cfg.num_layers,
                      cfg.d_ff, cfg.padded_vocab)
    ks = iter(jax.random.split(key, 16))
    s_d = 1.0 / math.sqrt(D)

    attn = {
        "wq": jax.random.normal(next(ks), (L, D, H, hd), dtype) * s_d,
        "wk": jax.random.normal(next(ks), (L, D, KV, hd), dtype) * s_d,
        "wv": jax.random.normal(next(ks), (L, D, KV, hd), dtype) * s_d,
        "wo": jax.random.normal(next(ks), (L, H, hd, D), dtype)
              * (1.0 / math.sqrt(H * hd)),
    }
    if cfg.qk_norm:
        attn["q_norm"] = jnp.zeros((L, hd), dtype)
        attn["k_norm"] = jnp.zeros((L, hd), dtype)

    layers: Params = {
        "attn": attn,
        "ln1": jnp.zeros((L, D), dtype),
        "ln2": jnp.zeros((L, D), dtype),
    }
    if cfg.moe is not None:
        moe_keys = jax.random.split(next(ks), L)
        stacked = jax.vmap(lambda k: init_moe_params(k, D, cfg.moe, dtype))(moe_keys)
        layers["moe"] = stacked
    else:
        layers["mlp"] = {
            "wg": jax.random.normal(next(ks), (L, D, F), dtype) * s_d,
            "wu": jax.random.normal(next(ks), (L, D, F), dtype) * s_d,
            "wd": jax.random.normal(next(ks), (L, F, D), dtype)
                  * (1.0 / math.sqrt(F)),
        }

    params: Params = {
        "embed": jax.random.normal(next(ks), (V, D), dtype),
        "layers": layers,
        "final_norm": jnp.zeros((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(next(ks), (D, V), dtype) * s_d
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _qkv(cfg: ArchConfig, p: Params, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", "head_dim"))
    k = constrain(k, ("batch", None, "kv", "head_dim"))
    v = constrain(v, ("batch", None, "kv", "head_dim"))
    return q, k, v


def _attention_block(cfg: ArchConfig, p: Params, x: jax.Array,
                     positions: jax.Array) -> tuple[jax.Array, tuple]:
    q, k, v = _qkv(cfg, p, x, positions)
    out = chunked_attention(q, k, v, causal=True, q_positions=positions,
                            k_positions=positions,
                            logit_softcap=cfg.logit_softcap)
    out = constrain(out, ("batch", None, "heads", "head_dim"))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def _ffn_block(cfg: ArchConfig, layer_p: Params, x: jax.Array) -> jax.Array:
    if cfg.moe is not None:
        return moe_ffn(x, layer_p["moe"], cfg.moe, cfg.activation)
    m = layer_p["mlp"]
    h = gated_mlp(x, m["wg"], m["wu"], m["wd"], cfg.activation)
    return h


def _decoder_layer(cfg: ArchConfig, layer_p: Params, x: jax.Array,
                   positions: jax.Array) -> tuple[jax.Array, tuple]:
    # Megatron-SP schedule: norm on the sharded residual (fp32 interior
    # stays sharded), gather the bf16 NORM OUTPUT for the block, and pin
    # block outputs back to residual sharding so the heads-contraction psum
    # lowers as a reduce-scatter instead of a full all-reduce.
    # (Gather-before-norm was tried and REFUTED: the gathered bf16 residual
    # becomes a saved activation and X/M both regressed — EXPERIMENTS §Perf.)
    h = rms_norm(x, layer_p["ln1"], cfg.norm_eps, cfg.zero_centered_norm)
    h = constrain(h, ("batch", None, None))            # AG, bf16
    attn_out, kv = _attention_block(cfg, layer_p["attn"], h, positions)
    attn_out = constrain(attn_out, ("batch", None, "residual"))   # RS, bf16
    x = x + attn_out
    h = rms_norm(x, layer_p["ln2"], cfg.norm_eps, cfg.zero_centered_norm)
    h = constrain(h, ("batch", None, None))            # AG, bf16
    ffn = constrain(_ffn_block(cfg, layer_p, h), ("batch", None, "residual"))
    x = x + ffn
    x = constrain(x, ("batch", None, "residual"))
    return x, kv


_REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _maybe_remat(fn, remat: str):
    policy = _REMAT_POLICIES[remat]
    if remat == "none":
        return fn
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# embed / logits
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ArchConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, ("batch", None, "residual"))


def mask_padded_vocab(cfg: ArchConfig, logits: jax.Array) -> jax.Array:
    """Embedding tables are padded to a 256-multiple (see
    ArchConfig.padded_vocab); the padded rows must never win: -inf them."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    valid = jnp.arange(logits.shape[-1]) < cfg.vocab_size
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def logits_fn(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.zero_centered_norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = mask_padded_vocab(cfg, logits)
    return constrain(logits, ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# forward (train / prefill) and decode
# ---------------------------------------------------------------------------


def transformer_forward(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
                        extra_embeds: jax.Array | None = None,
                        remat: str = "full",
                        collect_cache: bool = False):
    """Full-sequence forward. Returns logits, and the per-layer (k, v) cache
    stacked (L, B, S, KV, hd) when ``collect_cache`` (prefill)."""
    x = embed_tokens(cfg, params, tokens)
    if extra_embeds is not None:       # VLM: prepend visual tokens
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, layer_p):
        y, kv = _decoder_layer(cfg, layer_p, carry, positions)
        return y, kv if collect_cache else None

    body = _maybe_remat(body, remat)
    x, kvs = jax.lax.scan(body, x, params["layers"])
    logits = logits_fn(cfg, params, x)
    if collect_cache:
        return logits, {"k": kvs[0], "v": kvs[1]}
    return logits


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.eff_kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.eff_kv, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def transformer_decode(cfg: ArchConfig, params: Params, cache: Params,
                       tokens: jax.Array, position: jax.Array):
    """One decode step. tokens (B, 1); position: scalar int32 index of the
    new token (batch-uniform decode — the batcher aligns requests).
    Returns (logits (B, 1, V), updated cache). The cache write is a
    dynamic_update_slice so each step touches one position, keeping the
    decode memory roofline at cache-read + single-slot-write."""
    x = embed_tokens(cfg, params, tokens)
    B = x.shape[0]
    S_max = cache["k"].shape[2]
    pos2d = jnp.broadcast_to(position[None, None], (B, 1)).astype(jnp.int32)
    k_positions = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None],
                                   (B, S_max))
    pos_b = jnp.broadcast_to(position[None], (B,)).astype(jnp.int32)

    def body(carry, layer_p):
        # The FULL cache rides the carry and is updated at (layer, position)
        # in place — XLA aliases while-loop carries, so the cache has single
        # residency (scan-ys stacking would double-buffer ~the whole cache).
        x, kc, vc, li = carry
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps, cfg.zero_centered_norm)
        q, k_new, v_new = _qkv(cfg, layer_p["attn"], h, pos2d)
        kc = jax.lax.dynamic_update_slice(
            kc, k_new.astype(kc.dtype)[None], (li, 0, position, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v_new.astype(vc.dtype)[None], (li, 0, position, 0, 0))
        k_l = jax.lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
        out = decode_attention(q, k_l, v_l, position=pos_b,
                               k_positions=k_positions,
                               logit_softcap=cfg.logit_softcap)
        out = jnp.einsum("bshk,hkd->bsd", out, layer_p["attn"]["wo"])
        x = x + out
        h = rms_norm(x, layer_p["ln2"], cfg.norm_eps, cfg.zero_centered_norm)
        x = x + _ffn_block(cfg, layer_p, h)
        return (x, kc, vc, li + 1), None

    (x, k_new, v_new, _), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"], jnp.int32(0)), params["layers"])
    logits = logits_fn(cfg, params, x)
    return logits, {"k": k_new, "v": v_new}
