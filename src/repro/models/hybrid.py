"""Zamba2-style hybrid: Mamba2 backbone + a shared attention block.

The backbone is ``L`` mamba2 layers; after every ``shared_every`` of them a
*shared* transformer block runs on ``concat(hidden, original_embedding)``
(width 2·D) and projects back to D. The block's weights are shared across
invocations (one set of params), but each invocation keeps its own KV cache
(caches depend on activations). Zamba2's per-invocation LoRA deltas are
omitted — noted in DESIGN.md §8.

Structure for scan-ability: layers are grouped as ``G = L // every`` groups
of ``every`` mamba layers each followed by one shared-block invocation, plus
``L % every`` trailing mamba layers.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (apply_rope, chunked_attention, decode_attention,
                     gated_mlp, rms_norm)
from .mamba2 import (init_mamba_layer_params, mamba_block, mamba_decode_block,
                     ssm_dims)
from .sharding import constrain

Params = dict[str, Any]


def hybrid_structure(cfg: ArchConfig) -> tuple[int, int, int]:
    every = cfg.hybrid.shared_every
    groups = cfg.num_layers // every
    tail = cfg.num_layers % every
    return groups, every, tail


def init_hybrid_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, F = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ks = iter(jax.random.split(k3, 12))
    s2d = 1.0 / math.sqrt(2 * D)
    shared = {
        "attn": {
            "wq": jax.random.normal(next(ks), (2 * D, H, hd), dtype) * s2d,
            "wk": jax.random.normal(next(ks), (2 * D, KV, hd), dtype) * s2d,
            "wv": jax.random.normal(next(ks), (2 * D, KV, hd), dtype) * s2d,
            "wo": jax.random.normal(next(ks), (H, hd, 2 * D), dtype)
                  * (1.0 / math.sqrt(H * hd)),
        },
        "mlp": {
            "wg": jax.random.normal(next(ks), (2 * D, F), dtype) * s2d,
            "wu": jax.random.normal(next(ks), (2 * D, F), dtype) * s2d,
            "wd": jax.random.normal(next(ks), (F, 2 * D), dtype)
                  * (1.0 / math.sqrt(F)),
        },
        "ln1": jnp.zeros((2 * D,), dtype),
        "ln2": jnp.zeros((2 * D,), dtype),
        "down": jax.random.normal(next(ks), (2 * D, D), dtype) * s2d,
    }
    params: Params = {
        "embed": jax.random.normal(k1, (cfg.padded_vocab, D), dtype),
        "mamba_layers": init_mamba_layer_params(cfg, k2, cfg.num_layers, dtype),
        "shared": shared,
        "final_norm": jnp.zeros((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k4, (D, cfg.padded_vocab), dtype)
                             * (1.0 / math.sqrt(D)))
    return params


def _shared_qkv(cfg: ArchConfig, p: Params, h2: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", h2, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h2, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h2, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", "head_dim"))
    k = constrain(k, ("batch", None, "kv", "head_dim"))
    v = constrain(v, ("batch", None, "kv", "head_dim"))
    return q, k, v


def shared_block(cfg: ArchConfig, p: Params, x: jax.Array, x0: jax.Array,
                 positions: jax.Array, collect_cache: bool = False):
    """x, x0: (B,S,D). Returns delta (B,S,D) (+ (k, v) cache)."""
    h2 = jnp.concatenate([x, x0], axis=-1)                 # (B,S,2D)
    h = rms_norm(h2, p["ln1"], cfg.norm_eps)
    q, k, v = _shared_qkv(cfg, p["attn"], h, positions)
    attn = chunked_attention(q, k, v, causal=True, q_positions=positions,
                             k_positions=positions)
    attn = jnp.einsum("bshk,hkd->bsd", attn, p["attn"]["wo"])
    h2 = h2 + attn
    h = rms_norm(h2, p["ln2"], cfg.norm_eps)
    h2 = h2 + gated_mlp(h, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"],
                        cfg.activation)
    delta = jnp.einsum("bsd,de->bse", h2, p["down"])
    if collect_cache:
        return delta, (k, v)
    return delta


def _mamba_stack(cfg: ArchConfig, layers: Params, x: jax.Array, remat: str,
                 collect_cache: bool = False):
    from .transformer import _maybe_remat

    def body(carry, layer_p):
        h = rms_norm(carry, layer_p["ln"], cfg.norm_eps)
        if collect_cache:
            out, (state, conv) = mamba_block(cfg, layer_p, h, return_cache=True)
            new = constrain(carry + out, ("batch", None, "residual"))
            return new, (state, conv)
        out = mamba_block(cfg, layer_p, h)
        new = constrain(carry + out, ("batch", None, "residual"))
        return new, None

    body = _maybe_remat(body, remat)
    return jax.lax.scan(body, x, layers)


def _split_groups(cfg: ArchConfig, layers: Params):
    groups, every, tail = hybrid_structure(cfg)
    head = jax.tree.map(lambda a: a[: groups * every].reshape(
        (groups, every) + a.shape[1:]), layers)
    tail_p = jax.tree.map(lambda a: a[groups * every :], layers) if tail else None
    return head, tail_p


def hybrid_forward(cfg: ArchConfig, params: Params, tokens: jax.Array, *,
                   remat: str = "full", collect_cache: bool = False):
    from .transformer import embed_tokens, logits_fn

    x0 = embed_tokens(cfg, params, tokens)
    B, S, _ = x0.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    groups, every, tail = hybrid_structure(cfg)
    head, tail_p = _split_groups(cfg, params["mamba_layers"])

    caches = {"state": [], "conv": [], "k": [], "v": []}
    x = x0
    # scan over groups would close over per-group caches awkwardly; groups is
    # small (6 for zamba2) so a python loop is fine — the *inner* stacks scan.
    for g in range(groups):
        grp = jax.tree.map(lambda a, g=g: a[g], head)
        x, mc = _mamba_stack(cfg, grp, x, remat, collect_cache)
        if collect_cache:
            caches["state"].append(mc[0])
            caches["conv"].append(mc[1])
            delta, (k, v) = shared_block(cfg, params["shared"], x, x0,
                                         positions, collect_cache=True)
            caches["k"].append(k)
            caches["v"].append(v)
        else:
            delta = shared_block(cfg, params["shared"], x, x0, positions)
        x = constrain(x + delta, ("batch", None, "residual"))
    if tail_p is not None:
        x, mc = _mamba_stack(cfg, tail_p, x, remat, collect_cache)
        if collect_cache:
            caches["state"].append(mc[0])
            caches["conv"].append(mc[1])
    logits = logits_fn(cfg, params, x)
    if not collect_cache:
        return logits
    cache = {
        "state": jnp.concatenate(caches["state"], axis=0),
        "conv": jnp.concatenate(caches["conv"], axis=0),
        "k": jnp.stack(caches["k"], axis=0),     # (G, B, S, KV, hd)
        "v": jnp.stack(caches["v"], axis=0),
    }
    return logits, cache


def hybrid_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    d_inner, H, P, N, conv_ch = ssm_dims(cfg)
    groups, every, tail = hybrid_structure(cfg)
    L, W, hd = cfg.num_layers, cfg.ssm.conv_width, cfg.resolved_head_dim
    return {
        "state": jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((L, batch, W - 1, conv_ch), dtype),
        "k": jax.ShapeDtypeStruct((groups, batch, max_len, cfg.num_kv_heads, hd),
                                  dtype),
        "v": jax.ShapeDtypeStruct((groups, batch, max_len, cfg.num_kv_heads, hd),
                                  dtype),
    }


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        hybrid_cache_spec(cfg, batch, max_len, dtype))


def hybrid_decode(cfg: ArchConfig, params: Params, cache: Params,
                  tokens: jax.Array, position: jax.Array):
    from .transformer import embed_tokens, logits_fn

    x0 = embed_tokens(cfg, params, tokens)
    B = x0.shape[0]
    S_max = cache["k"].shape[2]
    pos2d = jnp.broadcast_to(position[None, None], (B, 1)).astype(jnp.int32)
    pos_b = jnp.broadcast_to(position[None], (B,)).astype(jnp.int32)
    k_positions = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None],
                                   (B, S_max))
    groups, every, tail = hybrid_structure(cfg)

    def mamba_step(x, layer_p, state, conv):
        h = rms_norm(x, layer_p["ln"], cfg.norm_eps)
        out, state, conv = mamba_decode_block(cfg, layer_p, h, state, conv)
        return x + out, state, conv

    new_states, new_convs, new_ks, new_vs = [], [], [], []
    x = x0
    li = 0
    for g in range(groups):
        for i in range(every):
            layer_p = jax.tree.map(lambda a, li=li: a[li], params["mamba_layers"])
            x, st, cv = mamba_step(x, layer_p,
                                   cache["state"][li], cache["conv"][li])
            new_states.append(st)
            new_convs.append(cv)
            li += 1
        # shared block invocation g
        p = params["shared"]
        h2 = jnp.concatenate([x, x0], axis=-1)
        h = rms_norm(h2, p["ln1"], cfg.norm_eps)
        q, k_new, v_new = _shared_qkv(cfg, p["attn"], h, pos2d)
        k_l = jax.lax.dynamic_update_slice_in_dim(
            cache["k"][g], k_new.astype(cache["k"].dtype), position, axis=1)
        v_l = jax.lax.dynamic_update_slice_in_dim(
            cache["v"][g], v_new.astype(cache["v"].dtype), position, axis=1)
        new_ks.append(k_l)
        new_vs.append(v_l)
        attn = decode_attention(q, k_l, v_l, position=pos_b,
                                k_positions=k_positions)
        h2 = h2 + jnp.einsum("bshk,hkd->bsd", attn, p["attn"]["wo"])
        h = rms_norm(h2, p["ln2"], cfg.norm_eps)
        h2 = h2 + gated_mlp(h, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"],
                            cfg.activation)
        x = x + jnp.einsum("bsd,de->bse", h2, p["down"])
    for i in range(tail):
        layer_p = jax.tree.map(lambda a, li=li: a[li], params["mamba_layers"])
        x, st, cv = mamba_step(x, layer_p, cache["state"][li], cache["conv"][li])
        new_states.append(st)
        new_convs.append(cv)
        li += 1
    logits = logits_fn(cfg, params, x)
    new_cache = {
        "state": jnp.stack(new_states, axis=0),
        "conv": jnp.stack(new_convs, axis=0),
        "k": jnp.stack(new_ks, axis=0),
        "v": jnp.stack(new_vs, axis=0),
    }
    return logits, new_cache
