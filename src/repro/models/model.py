"""Unified model API: init / forward / loss / prefill / decode per family,
plus the logical→mesh sharding spec builders used by the launcher.

Batch dict formats:
  dense/moe/ssm/hybrid train: {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm train:  + {"patch_embeds": (B, P, D)}; loss over text positions
  audio train: {"frames": (B,T,D), "tokens": (B,S), "labels": (B,S)}
  decode: tokens (B,1) + scalar position against a cache pytree
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig
from . import encdec, hybrid, mamba2, transformer
from .layers import softmax_cross_entropy
from .sharding import make_rules, spec_of

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init / shapes
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_transformer_params(cfg, key, dtype)
    if cfg.family == "ssm":
        return mamba2.init_mamba_params(cfg, key, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid_params(cfg, key, dtype)
    if cfg.family == "audio":
        return encdec.init_encdec_params(cfg, key, dtype)
    raise ValueError(cfg.family)


def param_shapes(cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    """Abstract param pytree (ShapeDtypeStruct) — no allocation."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype), key)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params: Params, batch: dict, *,
            remat: str = "full") -> jax.Array:
    if cfg.family in ("dense", "moe"):
        return transformer.transformer_forward(cfg, params, batch["tokens"],
                                               remat=remat)
    if cfg.family == "vlm":
        return transformer.transformer_forward(
            cfg, params, batch["tokens"],
            extra_embeds=batch["patch_embeds"], remat=remat)
    if cfg.family == "ssm":
        return mamba2.mamba_forward(cfg, params, batch["tokens"], remat=remat)
    if cfg.family == "hybrid":
        return hybrid.hybrid_forward(cfg, params, batch["tokens"], remat=remat)
    if cfg.family == "audio":
        return encdec.encdec_forward(cfg, params, batch["frames"],
                                     batch["tokens"], remat=remat)
    raise ValueError(cfg.family)


def loss_fn(cfg: ArchConfig, params: Params, batch: dict, *,
            remat: str = "full") -> jax.Array:
    logits = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.family == "vlm":                      # text positions only
        logits = logits[:, cfg.vlm.num_patches :, :]
    mask = (labels >= 0).astype(jnp.float32)
    return softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(cfg: ArchConfig, params: Params, batch: dict, *,
            remat: str = "full"):
    if cfg.family in ("dense", "moe"):
        return transformer.transformer_forward(cfg, params, batch["tokens"],
                                               remat=remat, collect_cache=True)
    if cfg.family == "vlm":
        return transformer.transformer_forward(
            cfg, params, batch["tokens"], extra_embeds=batch["patch_embeds"],
            remat=remat, collect_cache=True)
    if cfg.family == "ssm":
        return mamba2.mamba_prefill(cfg, params, batch["tokens"], remat=remat)
    if cfg.family == "hybrid":
        return hybrid.hybrid_forward(cfg, params, batch["tokens"], remat=remat,
                                     collect_cache=True)
    if cfg.family == "audio":
        return encdec.encdec_prefill(cfg, params, batch["frames"],
                                     batch["tokens"], remat=remat)
    raise ValueError(cfg.family)


def decode(cfg: ArchConfig, params: Params, cache: Params, tokens: jax.Array,
           position: jax.Array):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.transformer_decode(cfg, params, cache, tokens, position)
    if cfg.family == "ssm":
        return mamba2.mamba_decode(cfg, params, cache, tokens, position)
    if cfg.family == "hybrid":
        return hybrid.hybrid_decode(cfg, params, cache, tokens, position)
    if cfg.family == "audio":
        return encdec.encdec_decode(cfg, params, cache, tokens, position)
    raise ValueError(cfg.family)


def cache_spec(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.cache_spec(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        return mamba2.mamba_cache_spec(cfg, batch, dtype)
    if cfg.family == "hybrid":
        return hybrid.hybrid_cache_spec(cfg, batch, max_len, dtype)
    if cfg.family == "audio":
        return encdec.encdec_cache_spec(cfg, batch, max_len, dtype)
    raise ValueError(cfg.family)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------

# trailing-dims logical axes by leaf name (left-padded with None for layer /
# group stacking); MoE experts override below.
_LEAF_RULES: dict[str, tuple] = {
    "embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "enc_pos": (None, "fsdp"),
    "dec_pos": (None, "fsdp"),
    "wq": ("fsdp", "heads", "head_dim"),
    "wk": ("fsdp", "kv", "head_dim"),
    "wv": ("fsdp", "kv", "head_dim"),
    "wo": ("heads", "head_dim", "fsdp"),
    "wi": ("fsdp", "model_ffn"),
    "wg": ("fsdp", "model_ffn"),
    "wu": ("fsdp", "model_ffn"),
    "wd": ("model_ffn", "fsdp"),
    "router": ("fsdp", None),
    "in_proj": ("fsdp", "d_inner"),
    "out_proj": ("d_inner", "fsdp"),
    "conv_w": (None, None),
    "conv_b": (None,),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "dt_bias": ("ssm_heads",),
    "down": ("fsdp", None),
}
_MOE_OVERRIDES: dict[str, tuple] = {
    "wg": ("experts", "fsdp", "model_ffe"),
    "wu": ("experts", "fsdp", "model_ffe"),
    "wd": ("experts", "model_ffe", "fsdp"),
}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def param_specs(cfg: ArchConfig, shapes: Params, mesh: Mesh,
                options: dict | None = None) -> Params:
    """PartitionSpec pytree matching the param pytree."""
    rules = make_rules(cfg, mesh, options)

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        table = _LEAF_RULES
        if "moe" in names and "shared" not in names and name in _MOE_OVERRIDES:
            table = _MOE_OVERRIDES
        logical = table.get(name)
        if logical is None:
            return P()                                # norms, scalars: replicate
        shape = leaf.shape
        pad = len(shape) - len(logical)
        if pad < 0:
            logical = logical[-len(shape):]
            pad = 0
        logical = (None,) * pad + tuple(logical)
        return spec_of(logical, rules, shape=shape, mesh=mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def cache_pspecs(cfg: ArchConfig, shapes: Params, mesh: Mesh,
                 options: dict | None = None) -> Params:
    """KV/state cache sharding cascade: kv heads when divisible; else
    head_dim (q is tiny to reshard, scores psum over hd shards); else the
    sequence dim rides the model axis."""
    rules = make_rules(cfg, mesh, options)
    model = rules.get("model")
    msize = mesh.shape["model"] if model is not None else 1

    def leaf_spec(path, leaf):
        name = _path_names(path)[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # (L_or_G, B, S, KV, hd)
            logical = (None, "batch", None, "kv", None)
            spec = list(spec_of(logical, rules, shape=leaf.shape, mesh=mesh))
            if model is not None and spec[3] is None:
                if leaf.shape[4] % msize == 0:
                    spec[4] = model                      # head_dim shards
                elif leaf.shape[2] % msize == 0:
                    spec[2] = model                      # seq shards
            return P(*spec)
        if name == "state":               # (L, B, H, P, N)
            return spec_of((None, "batch", "ssm_heads", None, None), rules,
                           shape=leaf.shape, mesh=mesh)
        if name == "conv":                # (L, B, W-1, conv_ch)
            return spec_of((None, "batch", None, None), rules,
                           shape=leaf.shape, mesh=mesh)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def batch_pspecs(cfg: ArchConfig, batch_shapes: dict, mesh: Mesh,
                 options: dict | None = None) -> dict:
    rules = make_rules(cfg, mesh, options)
    out = {}
    for k, v in batch_shapes.items():
        nd = len(v.shape)
        if k == "position":
            out[k] = P()
        elif nd >= 1:
            out[k] = spec_of(("batch",) + (None,) * (nd - 1), rules,
                             shape=v.shape, mesh=mesh)
        else:
            out[k] = P()
    return out
